"""Chaos-robustness report: seeded fault schedules against the serving
engine's offload plane (DESIGN.md §10).

Serves the seeded smoke workload under injected fault schedules — the
same probabilistic-plus-scripted-burst shape as tests/test_chaos.py —
across the two fullest serving modes (kv-paged, and expert-paged ×
module-batch × kv-paged), and reports per (mode, seed):

  * the transcript-identity verdict vs the fault-free run (the north
    star: faults may cost throughput, never tokens),
  * injected fault counts by site/kind, retry / abort / stall totals,
  * degradation-ladder events and the final rung,
  * wall-clock tokens/s under chaos vs fault-free (labeled a wall rate
    off-TPU, never device throughput).

Asserting nothing (the acceptance gate is tests/test_chaos.py); the
nightly CI job runs three fixed seeds plus one random seed — printed so
a failing schedule can be replayed exactly — and uploads the emitted
``BENCH_faults.json`` as a workflow artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.common import backend_info, emit
from repro.configs import get_config
from repro.models.params import init_params
from repro.runtime.faults import FaultEvent, FaultPlan
from repro.serving.engine import Engine, EngineConfig

SITES = ("kv_spill", "kv_fetch", "kv_pool", "expert_copy", "plan_drain",
         "host_alloc", "dispatch")

MODES = {
    "kv_paged": dict(kv_paged=True, kv_gpu_ratio=0.25, kv_prefetch=True),
    "expert_module_kv": dict(expert_paged=True, w_gpu_ratio=0.5,
                             prefetch=True, predict=True, module_batch=True,
                             kv_paged=True, kv_gpu_ratio=0.25,
                             kv_prefetch=True),
}


def _work(cfg, seed=0, n=8):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 20))),
             4 if i % 2 == 0 else 12) for i in range(n)]


def _schedule(seed: int) -> FaultPlan:
    """One seeded chaos schedule (mirrors tests/test_chaos.py): scattered
    probabilistic faults over every site plus a scripted burst drawn from
    the seed, so each run sees at least one concentrated fault window."""
    rng = np.random.default_rng(seed)
    site = SITES[int(rng.integers(0, len(SITES)))]
    kind = ("fail", "stall", "partial", "exhaust")[int(rng.integers(0, 4))]
    return FaultPlan(
        seed=seed,
        probs={"*": {"fail": 0.06, "stall": 0.04, "partial": 0.04,
                     "exhaust": 0.03, "hostmem": 0.01}},
        trace=[FaultEvent(site, kind, after=int(rng.integers(0, 10)),
                          count=int(rng.integers(1, 6)))],
        stall_ms=float(rng.integers(50, 5000)),
        max_faults=int(rng.integers(40, 200)))


def _serve(cfg, params, requests, **kw):
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4, **kw))
    for prompt, gen in requests:
        eng.submit(prompt, gen)
    t0 = time.perf_counter()
    out = eng.run_until_idle()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    return eng, out, toks, dt


def run(seeds=(0, 1, 2), random_seed: bool = False, smoke: bool = False,
        out_path: str = "BENCH_faults.json"):
    seeds = list(seeds)
    if random_seed:
        extra = int(np.random.default_rng().integers(0, 2**31 - 1))
        print(f"bench_faults: random chaos seed {extra} "
              f"(replay: --seeds {extra})")
        seeds.append(extra)
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(1))
    work = _work(cfg, n=6 if smoke else 8)

    info = backend_info()
    tok_key = ("tokens_per_s" if not info["interpret"]
               else "wall_tokens_per_s_not_device_rate")
    report = {"config": cfg.name, "seeds": seeds, **info, "modes": {}}
    all_identical = True
    for mode, kw in MODES.items():
        _, baseline, toks0, dt0 = _serve(cfg, params, work, **kw)
        rows = {"fault_free": {"tokens": toks0, tok_key: toks0 / dt0},
                "chaos": {}}
        for seed in seeds:
            eng, out, toks, dt = _serve(cfg, params, work,
                                        fault_plan=_schedule(seed),
                                        degrade_down_after=2,
                                        degrade_up_after=5, **kw)
            ft = eng.fault_traffic()
            identical = out == baseline
            all_identical &= identical
            rows["chaos"][str(seed)] = {
                "transcripts_identical": identical,
                "tokens": toks,
                tok_key: toks / dt,
                "slowdown_vs_fault_free": dt / max(dt0, 1e-9),
                "injected": ft["injected"],
                "injected_total": ft["injected_total"],
                "retries": ft["retries"],
                "aborts": ft["aborts"],
                "stalls": ft["stalls"],
                "hostmem_faults": ft["hostmem_faults"],
                "shed_requests": ft["shed_requests"],
                "final_level": ft["level_name"],
                "demotions": ft["demotions"],
                "promotions": ft["promotions"],
                "degradation_events": ft["degradation_events"],
            }
            emit(f"chaos_{mode}_s{seed}", dt * 1e6,
                 f"identical={identical},injected={ft['injected_total']},"
                 f"retries={ft['retries']},level={ft['level_name']},"
                 f"slowdown={dt / max(dt0, 1e-9):.2f}x")
        report["modes"][mode] = rows

    report["all_transcripts_identical"] = all_identical
    emit("chaos_verdict", 0.0,
         f"seeds={len(seeds)},modes={len(MODES)},"
         f"all_transcripts_identical={all_identical}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated fixed chaos seeds")
    ap.add_argument("--random-seed", action="store_true",
                    help="add one random seed (printed, for replay)")
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk workload for the nightly CI job")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    run(seeds=[int(s) for s in args.seeds.split(",") if s != ""],
        random_seed=args.random_seed, smoke=args.smoke, out_path=args.out)

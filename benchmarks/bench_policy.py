"""Paper Tab. 5 — optimizer-policy ablation (MTBench @ S1, gen 128):
FlexGen with its own policy vs FlexGen with OUR policy vs our policy with
larger N vs MoE-Lightning.  Reproduces the paper's finding that the HRM
policy alone speeds FlexGen up (1.77× in the paper) and CGOPipe adds the
rest (3.17× total)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cgopipe as CG
from repro.core import hrm as H
from repro.core import policy as P


def _thr(cfg, hw, wl, pol, schedule):
    t = CG.times_from_policy(cfg, hw, wl, pol)
    lat = CG.per_layer_latency(schedule, t, 16)
    est = P.estimate(cfg, hw, wl, pol)
    total = est["t_prefill"] + lat * cfg.num_layers * wl.gen_len
    return pol.batch * wl.gen_len / total


def run():
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("t4")
    wl = P.Workload(prompt_len=77, gen_len=128)

    # FlexGen's own policy (paper Tab. 5: μ=8, N=1112, GPU attention)
    theirs = P.Policy(batch=1112, ubatch=8, attn_on_gpu=True,
                      ffn_on_gpu=True, w_gpu_ratio=0.0, kv_gpu_ratio=0.0)
    # our HRM policy (search, CPU attention)
    res = P.search(cfg, hw, wl)
    ours = res["best"]["policy"]
    # our policy but keeping FlexGen's schedule; larger-N variant
    import dataclasses
    ours_bigN = P.Policy(ours.batch * 2, ours.ubatch, ours.attn_on_gpu,
                         ours.ffn_on_gpu, ours.w_gpu_ratio,
                         ours.kv_gpu_ratio)

    rows = {
        "flexgen_their_policy": _thr(cfg, hw, wl, theirs, "s4"),
        "flexgen_our_policy": _thr(cfg, hw, wl, ours, "s3"),
        "flexgen_our_policy_largerN": _thr(cfg, hw, wl, ours_bigN, "s3"),
        "moe_lightning": _thr(cfg, hw, wl, ours, "cgopipe"),
    }
    base = rows["flexgen_their_policy"]
    for k, v in rows.items():
        emit(f"tab5_{k}", 1e6 / max(v, 1e-9),
             f"thr={v:.1f}tok/s,x{v / base:.2f}_vs_flexgen")
    return rows


if __name__ == "__main__":
    run()

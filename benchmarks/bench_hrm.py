"""Paper Figs. 4/5 (HRM turning/balance points) and Fig. 10 (policy vs
hardware sweep).

Fig. 4/5: for Mixtral decode on L4/T4/v5e, report the attention and FFN
operational intensities, the P1/P2 critical intensities and the balance
point — the quantities the paper reads off its HRM plots.

Fig. 10: sweep CPU→GPU bandwidth × CPU scaling ratio on the 2×A100 setup
and report the chosen policy (attention device, r_w, r_c), reproducing
the paper's directional findings.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import hrm as H
from repro.core import policy as P


def turning_points(csv=True):
    cfg = get_config("mixtral-8x7b")
    rows = []
    for preset in ("l4", "t4", "v5e"):
        hw = H.preset(preset)
        lw = H.LayerWorkload.decode(cfg, batch=128, ctx=576)
        ia = lw.intensity_attn_vs_kv()
        p1a = H.turning_point_p1(hw, "gpu", "cpu", ia)
        at_cpu = ia < p1a
        rows.append((preset, "attention", ia, p1a, at_cpu))
        if csv:
            emit(f"fig4_{preset}_attention_I", ia,
                 f"P1={p1a:.1f},compute_at_data={at_cpu}")
        for n in (32, 128, 512, 2048):
            lwn = H.LayerWorkload.decode(cfg, batch=n, ctx=576)
            i_f = lwn.intensity_ffn_vs_weights()
            p2 = H.turning_point_p2(hw, "gpu", "cpu",
                                    i_exec_local=lwn.flops_ffn
                                    / max(lwn.bytes_w, 1))
            if csv:
                emit(f"fig5_{preset}_ffn_I_N{n}", i_f, f"P2crit={p2:.1f}")
    return rows


def fig10_sweep(csv=True):
    cfg = get_config("mixtral-8x7b")
    base = H.preset("a100x2")
    wl = P.Workload(prompt_len=512, gen_len=32)
    rows = []
    for bw_g in (100, 200, 300, 400, 500):
        for cpu_scale in (1, 2, 4):
            levels = (base.levels[0],
                      H.Level("cpu", 1.6e12 * cpu_scale,
                              100e9 * cpu_scale, 200e9 * cpu_scale))
            hw = H.Hardware(levels=levels,
                            links={("cpu", "gpu"): bw_g * 1e9}, name="sweep")
            try:
                best = P.search(cfg, hw, wl)["best"]
            except RuntimeError:
                continue
            pol = best["policy"]
            rows.append((bw_g, cpu_scale, pol))
            if csv:
                emit(f"fig10_bw{bw_g}_cpux{cpu_scale}",
                     1e6 / best["throughput"],
                     f"attn_cpu={not pol.attn_on_gpu},rw={pol.w_gpu_ratio},"
                     f"rc={pol.kv_gpu_ratio},N={pol.batch}")
    # directional check: offloaded weight fraction grows with link bw
    lo = [p for b, c, p in rows if b == 100 and c == 1][0]
    hi = [p for b, c, p in rows if b == 500 and c == 1][0]
    if csv:
        emit("fig10_direction", 0.0,
             f"rw_at_100GBps={lo.w_gpu_ratio},rw_at_500GBps={hi.w_gpu_ratio},"
             f"more_offload_with_faster_link={hi.w_gpu_ratio <= lo.w_gpu_ratio}")
    return rows


def run():
    turning_points()
    fig10_sweep()


if __name__ == "__main__":
    run()

"""§Roofline deliverable: aggregate the dry-run JSON records into the
per-(arch × shape × mesh) roofline table (terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, roofline fraction).

  PYTHONPATH=src python -m benchmarks.roofline_report [--md] [--mesh ...]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(dryrun_dir=DRYRUN_DIR):
    recs = []
    for p in sorted(dryrun_dir.glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:
            pass
    return recs


def fmt_ms(x):
    return f"{x * 1e3:.2f}"


def table(recs, mesh=None, md=False):
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            if mesh is None or r["mesh"] == mesh:
                rows.append((r["arch"], r["shape"], r["mesh"], "skip",
                             r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], "FAIL",
                         r.get("error", "")))
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rows.append((
            r["arch"], r["shape"], r["mesh"], r["dominant"],
            f"tc={fmt_ms(r['t_compute'])} tm={fmt_ms(r['t_memory'])} "
            f"tx={fmt_ms(r['t_collective'])} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"roofline={r['roofline_fraction'] * 100:.1f}%"))
    if md:
        print("| arch | shape | mesh | bottleneck | terms |")
        print("|---|---|---|---|---|")
        for row in rows:
            print("| " + " | ".join(str(c) for c in row) + " |")
    else:
        for row in rows:
            print(",".join(str(c) for c in row))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--dir", default=str(DRYRUN_DIR))
    args = ap.parse_args()
    recs = load_records(Path(args.dir))
    table(recs, mesh=args.mesh, md=args.md)


if __name__ == "__main__":
    main()

"""§Roofline deliverable: aggregate the dry-run JSON records into the
per-(arch × shape × mesh) roofline table (terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, roofline fraction).

  PYTHONPATH=src python -m benchmarks.roofline_report [--md] [--mesh ...]

``--paging BENCH_paging.json`` instead reports per-layer expert
miss-stall time from the paging bench's predict sweep: the stalled
miss bytes each layer streamed synchronously (hidden misses excluded —
their transfer overlapped the consuming dispatch's compute) divided by
the HRM's cpu→gpu link bandwidth (the measured H2D rate when a
BENCH_transfer.json artifact is present, else the preset).  This is
the ROADMAP's "miss-stall time per layer on the roofline report":
where expert I/O still bounds the pipeline after prediction +
replication.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def paging_stall_table(paging_path, hw_name="l4", md=False,
                       transfer_path="BENCH_transfer.json"):
    """Per-(variant × layer) expert miss-stall time for the predict
    sweep recorded in a BENCH_paging.json artifact."""
    from repro.core import hrm as H
    hw = H.with_measured_links(H.preset(hw_name), transfer_path)
    bw = hw.link_bw("cpu", "gpu")
    report = json.loads(Path(paging_path).read_text())
    sweep = report.get("predict")
    if not sweep:
        print(f"{paging_path}: no predict sweep section "
              "(rerun bench_paging with --predict/--replicate)")
        return []
    rows = []
    for name, row in sweep["variants"].items():
        per_layer = row.get("miss_stall_bytes_per_layer", {})
        toks = max(1, row.get("tokens", 1))
        for key, layers in per_layer.items():
            for li, b in enumerate(layers):
                rows.append((name, key, li, int(b),
                             b / bw * 1e3, b / toks / bw * 1e6))
        total = row.get("miss_stall_bytes", 0)
        rows.append((name, "total", "-", int(total),
                     total / bw * 1e3, total / toks / bw * 1e6))
    hdr = ("variant", "weights", "layer", "stall_bytes",
           "stall_ms", "stall_us_per_tok")
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for r in rows:
            print("| " + " | ".join(
                f"{c:.3f}" if isinstance(c, float) else str(c)
                for c in r) + " |")
    else:
        print(",".join(hdr))
        for r in rows:
            print(",".join(f"{c:.3f}" if isinstance(c, float) else str(c)
                           for c in r))
    print(f"# link_bw={bw / 1e9:.1f} GB/s ({hw.name})")
    return rows


def load_records(dryrun_dir=DRYRUN_DIR):
    recs = []
    for p in sorted(dryrun_dir.glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:
            pass
    return recs


def fmt_ms(x):
    return f"{x * 1e3:.2f}"


def table(recs, mesh=None, md=False):
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            if mesh is None or r["mesh"] == mesh:
                rows.append((r["arch"], r["shape"], r["mesh"], "skip",
                             r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], "FAIL",
                         r.get("error", "")))
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rows.append((
            r["arch"], r["shape"], r["mesh"], r["dominant"],
            f"tc={fmt_ms(r['t_compute'])} tm={fmt_ms(r['t_memory'])} "
            f"tx={fmt_ms(r['t_collective'])} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"roofline={r['roofline_fraction'] * 100:.1f}%"))
    if md:
        print("| arch | shape | mesh | bottleneck | terms |")
        print("|---|---|---|---|---|")
        for row in rows:
            print("| " + " | ".join(str(c) for c in row) + " |")
    else:
        for row in rows:
            print(",".join(str(c) for c in row))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--dir", default=str(DRYRUN_DIR))
    ap.add_argument("--paging", default=None, metavar="BENCH_paging.json",
                    help="report per-layer expert miss-stall time from a "
                         "paging bench artifact instead of the dry-run table")
    ap.add_argument("--hw", default="l4",
                    help="HRM hardware preset for the link bandwidth "
                         "(--paging mode)")
    args = ap.parse_args()
    if args.paging:
        paging_stall_table(args.paging, hw_name=args.hw, md=args.md)
        return
    recs = load_records(Path(args.dir))
    table(recs, mesh=args.mesh, md=args.md)


if __name__ == "__main__":
    main()

"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time (seconds) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")


def backend_info() -> dict:
    """Honesty fields every BENCH_*.json artifact records: which backend
    produced the numbers, and whether Pallas kernels ran under the
    interpreter (off-TPU) — interpret-mode wall times validate
    correctness and byte accounting, never device throughput."""
    backend = jax.default_backend()
    return {"backend": backend, "interpret": backend != "tpu"}

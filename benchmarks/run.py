"""Benchmark driver — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only e2e|policy|kernels|hrm|tp|engine]
"""
from __future__ import annotations

import argparse
import sys
import time


def _section(name):
    print(f"# ---- {name} " + "-" * max(1, 60 - len(name)), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    def want(k):
        return args.only is None or args.only == k

    t0 = time.time()
    if want("e2e"):
        _section("Fig.7 / Tab.4: end-to-end throughput by schedule")
        from benchmarks import bench_e2e
        bench_e2e.run()
    if want("policy"):
        _section("Tab.5: policy ablation")
        from benchmarks import bench_policy
        bench_policy.run()
    if want("kernels"):
        _section("Fig.9: KV-transfer vs attention vs MoE FFN")
        from benchmarks import bench_kernels
        bench_kernels.run()
    if want("hrm"):
        _section("Fig.4/5: HRM turning points; Fig.10: policy-vs-hardware")
        from benchmarks import bench_hrm
        bench_hrm.run()
    if want("tp"):
        _section("Fig.8: tensor-parallel scaling")
        from benchmarks import bench_tp_scaling
        bench_tp_scaling.run()
    if want("engine"):
        _section("engine micro-benchmark (real decode steps, CPU smoke)")
        from benchmarks import bench_engine
        bench_engine.run()
    print(f"# benchmarks done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()

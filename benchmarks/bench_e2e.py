"""Paper Fig. 7 / Tab. 4 — end-to-end generation throughput:
MoE-Lightning (CGOPipe) vs FlexGen (S4), FlexGen(c) (S3), FastDecode-style
(S2) and DeepSpeed-style streaming, each at its own best FEASIBLE policy
(the paper's comparison protocol), on the paper's three workloads and the
S1 (T4) / S2 (L4) hardware settings.

Latencies come from the HRM-parameterized event simulator
(core.cgopipe) — the same model validated against kernel-level wall time
in bench_kernels — so relative orderings reproduce the paper's findings.
"""
from __future__ import annotations

import itertools
from typing import Tuple

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import cgopipe as CG
from repro.core import hrm as H
from repro.core import policy as P

WORKLOADS = {
    "mtbench_g64": P.Workload(prompt_len=77, gen_len=64),
    "synth_reasoning": P.Workload(prompt_len=242, gen_len=50),
    "summarization": P.Workload(prompt_len=1693, gen_len=64),
}
SETTINGS = {"S1_T4": "t4", "S2_L4": "l4"}

# per-system constraints (the paper's Fig. 7 baselines; each system picks
# the policy that maximizes ITS OWN simulated throughput — the paper's
# comparison protocol).  FastDecode (S2) cannot stream weights at all and
# is therefore infeasible for models larger than GPU memory; it appears
# only in the Fig. 6 schedule ablation (tests/test_cgopipe.py).
SYSTEMS = {
    "moe_lightning": dict(schedule="cgopipe", attn=None),
    "flexgen_c_s3": dict(schedule="s3", attn=False),
    "flexgen_s4": dict(schedule="s4", attn=True),
    "deepspeed": dict(schedule="deepspeed", attn=True, kv_on_gpu=True),
}


def candidate_policies(cfg, hw, wl, spec):
    res = P.search(cfg, hw, wl)
    cands = [c["policy"] for c in
             (res["best"], res["best_gpu_attn"], res["best_cpu_attn"]) if c]
    # a few structured variants around the optimum
    extra = []
    for pol in list(cands):
        extra.append(P.Policy(pol.batch // 2 or pol.ubatch, pol.ubatch,
                              pol.attn_on_gpu, True, pol.w_gpu_ratio,
                              pol.kv_gpu_ratio))
        extra.append(P.Policy(pol.batch, min(pol.batch, pol.ubatch * 2),
                              pol.attn_on_gpu, True, pol.w_gpu_ratio,
                              pol.kv_gpu_ratio))
    cands += extra
    if spec.get("attn") is not None:
        cands = [p for p in cands if p.attn_on_gpu == spec["attn"]] or [
            P.Policy(c.batch, c.ubatch, spec["attn"], True, c.w_gpu_ratio,
                     c.kv_gpu_ratio) for c in cands]
    if spec.get("kv_on_gpu"):
        # deepspeed: KV resident on GPU caps N; single micro-batch
        kv_per_tok = P.kv_bytes_per_token_layer(cfg) * cfg.num_layers
        budget = 0.6 * hw.level("gpu").capacity
        n_max = max(8, int(budget / max(kv_per_tok, 1)
                           / (wl.prompt_len + wl.gen_len)))
        cands = [P.Policy(min(p.batch, n_max), min(p.batch, n_max), True,
                          True, p.w_gpu_ratio, 1.0) for p in cands]
    return cands


def system_throughput(cfg, hw, wl, spec) -> float:
    best = 0.0
    for pol in candidate_policies(cfg, hw, wl, spec):
        mem = P.memory_usage(cfg, wl, pol)
        if mem["gpu"] > hw.level("gpu").capacity or \
                mem["cpu"] > hw.level("cpu").capacity:
            continue
        t = CG.times_from_policy(cfg, hw, wl, pol)
        lat = CG.per_layer_latency(spec["schedule"], t, 16)
        est = P.estimate(cfg, hw, wl, pol)
        total = est["t_prefill"] + lat * cfg.num_layers * wl.gen_len
        best = max(best, pol.batch * wl.gen_len / total)
    return best


def decode_slot_utilization(gen_lens, ubatch: int) -> Tuple[float, float]:
    """Expected decode-slot utilization for whole-micro-batch retirement
    (static) vs slot recycling (continuous) on a generation-length mix.

    Static: a micro-batch of `ubatch` rows runs until its longest row
    finishes, so each group burns ubatch * max(gens) row-steps for
    sum(gens) useful tokens.  Continuous: drained slots are refilled
    immediately, so with a deep queue utilization approaches 1 (the last
    partially-empty groups are the only waste; ignored here)."""
    groups = [gen_lens[i:i + ubatch]
              for i in range(0, len(gen_lens), ubatch)]
    useful = sum(gen_lens)
    burned = sum(len(g) * max(g) for g in groups)
    return useful / burned, 1.0


def run(csv: bool = True):
    rows = []
    for (sname, preset), (wname, wl) in itertools.product(
            SETTINGS.items(), WORKLOADS.items()):
        cfg = get_config("mixtral-8x7b")
        hw = H.preset(preset)
        thr = {}
        for sysname, spec in SYSTEMS.items():
            try:
                thr[sysname] = system_throughput(cfg, hw, wl, spec)
            except RuntimeError:
                thr[sysname] = 0.0
        base = max(v for k, v in thr.items() if k != "moe_lightning")
        speedup = thr["moe_lightning"] / base if base else float("inf")
        for sysname, v in thr.items():
            rows.append((f"e2e_{sname}_{wname}_{sysname}", v))
            if csv:
                emit(f"e2e_{sname}_{wname}_{sysname}",
                     1e6 / max(v, 1e-9),
                     f"thr={v:.1f}tok/s")
        if csv:
            emit(f"e2e_{sname}_{wname}_SPEEDUP", 0.0,
                 f"moe_lightning_vs_best_baseline={speedup:.2f}x")
        # continuous-batching headroom on top of the CGOPipe schedule: the
        # Fig. 7 model assumes every decode slot stays useful for gen_len
        # steps; with a skewed mix (half the requests stop at gen_len/8),
        # static retirement wastes the difference while the slot-pool
        # engine recycles it (measured for real in bench_engine).
        skew = [wl.gen_len // 8 if i % 2 == 0 else wl.gen_len
                for i in range(32)]
        u_static, u_cont = decode_slot_utilization(skew, 8)
        if csv:
            emit(f"e2e_{wname}_continuous_gain", 0.0,
                 f"slot_util_static={u_static:.2f},"
                 f"slot_util_continuous={u_cont:.2f},"
                 f"modeled_gain={u_cont / u_static:.2f}x")
    return rows


if __name__ == "__main__":
    run()

"""Real-device transfer + crossover microbench → ``BENCH_transfer.json``.

Two measurements, both feeding measured constants back into the stack:

  * **pinned vs pageable H2D bandwidth** — ``device_put`` from a
    pinned-host-resident array vs from a pageable numpy array, over a
    size sweep.  The pinned figure is what ``core.hrm.measured_link_bw``
    substitutes for the spec-sheet cpu→gpu link term
    (``with_measured_links`` / ``policy.search(bench_path=...)``), so
    the roofline and the policy search optimize against *achieved* DMA
    rate.  On backends without a pinned_host memory space — or with a
    single memory space at all (this CPU container, where a "transfer"
    is a memcpy) — the bandwidth fields are recorded as null rather
    than poisoning the model with memcpy rates.

  * **dense-vs-paged kernel occupancy crossover** — wall time of the
    compiled paged flash-decode kernel vs the dense-view path over a
    ring-occupancy sweep.  The paged kernel gathers only mapped blocks;
    the dense view reads the whole ring but with simpler addressing —
    on real devices there is an occupancy above which dense wins.  The
    lowest swept occupancy where dense is faster is recorded as
    ``crossover_occupancy``; ``kernels.ops.load_paged_crossover`` feeds
    it to the engine's ``impl='auto'`` resolution.  Off-TPU the kernel
    only runs under the Pallas interpreter, whose wall time says
    nothing about device dispatch — the crossover is recorded null and
    ``auto`` stays always-paged on TPU / dense-ref on CPU.

``--smoke`` shrinks sizes/iters for the nightly CI job, which uploads
the artifact.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_kernels import _paged_case
from benchmarks.common import backend_info, emit, time_call
from repro.core import offload
from repro.kernels import ops

TRANSFER_MB = (4, 16, 64)
SMOKE_MB = (1, 4)
CROSSOVER_OCCUPANCY = (0.125, 0.25, 0.5, 0.75, 1.0)


def measure_h2d(sizes_mb, iters=5):
    """Per-size pinned/pageable H2D timings.  Returns (rows, pinned_bw,
    pageable_bw) — bandwidths in bytes/s from the largest size (startup
    latency amortized), or None when the backend can't express the
    measurement honestly."""
    info = backend_info()
    dev = jax.devices()[0]
    single_memory = info["backend"] == "cpu"
    pinned_shd = offload.pinned_host_sharding(warn=False)
    rows = []
    for mb in sizes_mb:
        n = mb * (1 << 20)
        host = np.random.default_rng(0).integers(
            0, 255, n, np.uint8)
        t_pageable = time_call(
            lambda: jax.device_put(host, dev), iters=iters)
        row = {"mbytes": mb, "pageable_s": t_pageable,
               "pageable_bytes_per_s": n / t_pageable}
        if pinned_shd is not None:
            pinned = jax.device_put(jnp.asarray(host), pinned_shd)
            jax.block_until_ready(pinned)
            t_pinned = time_call(
                lambda: jax.device_put(pinned, dev), iters=iters)
            row["pinned_s"] = t_pinned
            row["pinned_bytes_per_s"] = n / t_pinned
        rows.append(row)
        emit(f"h2d_{mb}mb", t_pageable * 1e6,
             f"pageable_gbps={n / t_pageable / 1e9:.2f}"
             + (f",pinned_gbps={n / row['pinned_s'] / 1e9:.2f}"
                if "pinned_s" in row else ",pinned=unavailable"))
    if single_memory:
        # one memory space: 'H2D' was a memcpy — do not report it as
        # link bandwidth (hrm.measured_link_bw would swallow it)
        return rows, None, None
    big = rows[-1]
    return (rows, big.get("pinned_bytes_per_s"),
            big["pageable_bytes_per_s"])


def measure_crossover(occupancies, smoke=False):
    """Dense-view vs paged-kernel wall time over a ring-occupancy sweep.
    Returns (rows, crossover) — crossover is the lowest occupancy where
    the dense path wins, None when dense never wins or when the sweep
    ran under the interpreter (off-TPU: not a device measurement)."""
    info = backend_info()
    B, bt, MB = (2, 8, 8) if smoke else (4, 16, 16)
    Hkv, Dh = 2, 16
    rng = np.random.default_rng(0)
    rows, crossover = [], None
    for occ in occupancies:
        q, cache, pos, mapped = _paged_case(rng, B, MB, bt, Hkv, Dh,
                                            occ, jnp.bfloat16)
        kern_impl = "interpret" if info["interpret"] else "pallas"
        t_kern = time_call(lambda: ops.paged_gqa_decode(
            q, cache, pos, scale=Dh ** -0.5, impl=kern_impl))
        t_dense = time_call(lambda: ops.paged_gqa_decode(
            q, cache, pos, scale=Dh ** -0.5, impl="ref"))
        dense_wins = t_dense < t_kern
        rows.append({"occupancy": occ, "mapped_blocks_per_row": mapped,
                     "paged_kernel_s": t_kern, "dense_view_s": t_dense,
                     "dense_wins": bool(dense_wins)})
        if dense_wins and crossover is None and not info["interpret"]:
            crossover = occ
        emit(f"crossover_occ{int(occ * 1000)}", t_kern * 1e6,
             f"dense_us={t_dense * 1e6:.1f},dense_wins={dense_wins},"
             f"backend={info['backend']}")
    return rows, crossover


def run(smoke: bool = False, out_path: str = "BENCH_transfer.json"):
    info = backend_info()
    sizes = SMOKE_MB if smoke else TRANSFER_MB
    h2d_rows, bw_pinned, bw_pageable = measure_h2d(
        sizes, iters=3 if smoke else 5)
    xo_rows, crossover = measure_crossover(CROSSOVER_OCCUPANCY, smoke)
    report = {
        **info,
        "supports_pinned_host": offload.supports_host_offload(),
        "h2d": h2d_rows,
        # null off-device: hrm.measured_link_bw / ops.load_paged_crossover
        # treat null as "no measurement" and keep their defaults
        "h2d_pinned_bytes_per_s": bw_pinned,
        "h2d_pageable_bytes_per_s": bw_pageable,
        "crossover_sweep": xo_rows,
        "crossover_occupancy": crossover,
    }
    if bw_pinned is not None and bw_pageable is not None:
        report["accept_pinned_ge_pageable"] = bw_pinned >= bw_pageable
    emit("transfer_summary", 0.0,
         f"backend={info['backend']},pinned_bw={bw_pinned},"
         f"pageable_bw={bw_pageable},crossover={crossover}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk sweep for the nightly CI job")
    ap.add_argument("--out", default="BENCH_transfer.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)

"""Device-KV footprint report: dense max_seq-wide slot pool vs the
block-granular paged pool with a host-RAM tier.

Serves the same seeded *skewed* workload (half short, half long
generations over varied prompt lengths — the shape whose actual
footprints a max_seq-wide pool over-allocates hardest) on the mixtral
smoke config through four KV layouts —

  * ``dense``    — the seed baseline: one max_seq-wide ring per slot,
    entirely on device;
  * ``paged_rc{25,50,100}`` — the shared block arena sized by
    r_c ∈ {0.25, 0.5, 1.0}: block page tables, cold blocks spilled to
    the host tier and streamed back through transfer_plan slices.

— and reports device KV bytes (absolute and per served token), arena
occupancy, block hit/miss/spill/prefetch counters, and wall-clock
tokens/s, asserting nothing (the acceptance test lives in
tests/test_kv_paging.py).  Traffic is the engine's own accounting
(DESIGN.md §2: on the CPU container the tiers are modeled, not
physically separate memories; the byte counts are exactly what the TPU
host-offload path would transfer).

``--smoke`` shrinks the workload for the nightly CI job, which uploads
the emitted ``BENCH_kv.json`` as a workflow artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.common import backend_info, emit
from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig

BLOCK_TOKENS = 16
RATIOS = (0.25, 0.5, 1.0)


def _serve(cfg, params, requests, **kw):
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4,
                                           block_tokens=BLOCK_TOKENS, **kw))
    for prompt, gen in requests:
        eng.submit(prompt, gen)
    t0 = time.perf_counter()
    out = eng.run_until_idle()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    return eng, out, toks, dt


def run(smoke: bool = False, out_path: str = "BENCH_kv.json"):
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    n_req, short_gen, long_gen = (8, 4, 12) if smoke else (16, 4, 24)
    requests = [(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 20))),
                 short_gen if i % 2 == 0 else long_gen)
                for i in range(n_req)]

    variants = {"dense": {}}
    for rc in RATIOS:
        variants[f"paged_rc{int(rc * 100)}"] = dict(kv_paged=True,
                                                    kv_gpu_ratio=rc)
    info = backend_info()
    report = {"config": cfg.name, "block_tokens": BLOCK_TOKENS,
              "ratios": list(RATIOS), **info, "variants": {}}
    # off-TPU wall rates are labeled as such — never device throughput
    tok_key = ("tokens_per_s" if not info["interpret"]
               else "wall_tokens_per_s_not_device_rate")
    outs = {}
    for name, kw in variants.items():
        eng, out, toks, dt = _serve(cfg, params, requests, **kw)
        outs[name] = out
        t = eng.kv_traffic()
        row = {
            "tokens": toks,
            tok_key: toks / dt,
            "device_kv_bytes": int(t["device_kv_bytes"]),
            "kv_bytes_per_token": t["device_kv_bytes"] / max(1, toks),
            "dense_equiv_bytes": int(t["dense_equiv_bytes"]),
            "device_bytes_reduction_vs_dense":
                t["dense_equiv_bytes"] / max(1, t["device_kv_bytes"]),
            "h2d_bytes": int(t["h2d_bytes"]),
            "d2h_bytes": int(t["d2h_bytes"]),
        }
        for k in ("device_blocks", "peak_blocks_in_use",
                  "arena_utilization", "hits", "misses", "spills",
                  "prefetches", "hit_rate", "gathered_bytes_per_step",
                  "paged_view_bytes_per_step", "gather_reduction_vs_view"):
            if k in t:
                row[k] = t[k]
        report["variants"][name] = row
        emit(f"kv_{name}", dt * 1e6,
             f"tok_per_s={toks / dt:.1f},"
             f"dev_kv_mb={t['device_kv_bytes'] / 1e6:.2f},"
             f"reduction={row['device_bytes_reduction_vs_dense']:.2f}x"
             + (f",hit_rate={t['hit_rate']:.2f}" if "hit_rate" in t else ""))

    report["greedy_identical"] = all(outs[n] == outs["dense"] for n in outs)
    tight = report["variants"][f"paged_rc{int(RATIOS[0] * 100)}"]
    emit("kv_device_bytes_reduction", 0.0,
         f"rc={RATIOS[0]},"
         f"reduction={tight['device_bytes_reduction_vs_dense']:.2f}x,"
         f"occupancy={tight['arena_utilization']:.2f},"
         f"greedy_identical={report['greedy_identical']}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk workload for the nightly CI job")
    ap.add_argument("--out", default="BENCH_kv.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)

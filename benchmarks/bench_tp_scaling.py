"""Paper Fig. 8 / §5.3 — tensor-parallel scaling on multiple T4s for
Mixtral-8x22B and DBRX: MoE-Lightning shows SUPER-linear throughput
scaling 2→4 GPUs because total GPU memory capacity bounds achievable
throughput (§4.3); pipeline-parallel FlexGen fails to scale.

TP here multiplies GPU memory capacity and HBM bandwidth in the HRM
hardware description (the paper's §4.3 construction) and re-runs the
policy search.
"""
from __future__ import annotations

from dataclasses import replace

from benchmarks.common import emit
from repro.configs.base import LayerSpec, ModelConfig
from repro.core import hrm as H
from repro.core import policy as P

# the paper's larger MoEs (benchmark-local configs; not assigned archs)
MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=16_384, vocab_size=32_768,
    period=(LayerSpec(moe=True),), num_experts=8, top_k=2,
    norm="rmsnorm", ffn_act="silu", tie_embeddings=False)
DBRX = ModelConfig(
    name="dbrx", family="moe", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=10_752, vocab_size=100_352,
    period=(LayerSpec(moe=True),), num_experts=16, top_k=4,
    norm="rmsnorm", ffn_act="silu", tie_embeddings=False)


def tp_hw(tp: int) -> H.Hardware:
    """§4.3: TP multiplies GPU capacity, HBM bandwidth AND the aggregate
    CPU→GPU bandwidth (each GPU has its own PCIe link; within one node the
    CPU memory/bandwidth are shared)."""
    t4 = H.preset("t4")
    g = t4.level("gpu")
    return H.Hardware(
        levels=(H.Level("gpu", g.p_peak * tp, g.b_peak * tp,
                        g.capacity * tp),
                H.Level("cpu", 1.6e12, 100e9, 416e9)),
        links={("cpu", "gpu"): 12e9 * tp}, name=f"{tp}xT4")


def run():
    wl = P.Workload(prompt_len=77, gen_len=64)
    for cfg in (MIXTRAL_8X22B, DBRX):
        thr = {}
        for tp in (1, 2, 4):
            try:
                best = P.search(cfg, tp_hw(tp), wl)["best"]
                thr[tp] = best["throughput"]
                pol = best["policy"]
                emit(f"fig8_{cfg.name}_tp{tp}", 1e6 / best["throughput"],
                     f"thr={best['throughput']:.1f}tok/s,N={pol.batch},"
                     f"rw={pol.w_gpu_ratio}")
            except RuntimeError:
                thr[tp] = 0.0
                emit(f"fig8_{cfg.name}_tp{tp}", 0.0, "infeasible")
        if thr.get(2) and thr.get(4):
            scale = thr[4] / thr[2]
            emit(f"fig8_{cfg.name}_scaling_2to4", 0.0,
                 f"x{scale:.2f}(superlinear={scale > 2.0},paper:2.1-3.38x)")


if __name__ == "__main__":
    run()

"""Real (wall-clock) engine micro-benchmark on the CPU smoke model.

Two experiments:

  * resident vs paged weights: decode-step latency and tokens/s with the
    continuous slot-pool engine (grounds the HRM/simulator numbers with
    an actually-executing system);
  * static vs continuous batching on a *skewed* generation-length
    workload (half the requests generate SHORT_GEN tokens, half
    LONG_GEN): static mode retires a micro-batch only when its slowest
    row finishes, so short rows burn decode slots doing masked no-ops;
    the slot-pool engine recycles drained slots mid-flight and must win
    decisively (the PR's acceptance bar is >= 1.5x tokens/s).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig

SHORT_GEN, LONG_GEN = 4, 64
N_REQUESTS = 16
PROMPT_LEN = 16


def _run_engine(cfg, params, ecfg, requests, warmup=False):
    eng = Engine(cfg, params, ecfg)
    if warmup:
        # trigger every jit compile (prefill buckets, decode chunk, slot
        # insert/reset) so the timed section measures steady-state serving
        for prompt, _ in requests[:2 * ecfg.ubatch]:
            eng.submit(prompt, 2)
        eng.run_until_idle()
        eng.steps = eng.tokens_out = 0
    base_rids = set(eng.scheduler.requests)
    for prompt, gen in requests:
        eng.submit(prompt, gen)
    t0 = time.perf_counter()
    out = eng.run_until_idle()
    dt = time.perf_counter() - t0
    out = {rid: toks for rid, toks in out.items() if rid not in base_rids}
    toks = sum(len(v) for v in out.values())
    return eng, out, toks, dt


def run():
    cfg = get_config("mixtral-8x7b").smoke()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)

    # 1. resident vs paged (uniform generation length)
    for paged in (False, True):
        reqs = [(rng.integers(2, cfg.vocab_size, 16), 16) for _ in range(8)]
        eng, out, toks, dt = _run_engine(
            cfg, params, EngineConfig(ubatch=4, num_ubs=2, max_seq=128,
                                      paged=paged), reqs, warmup=True)
        name = "paged" if paged else "resident"
        # per generated token (an engine tick is now a decode_chunk-token
        # chunk, so per-step latency would not be comparable to the seed)
        emit(f"engine_{name}_decode_per_tok", dt / max(toks, 1) * 1e6,
             f"tok_per_s={toks / dt:.1f},ticks={eng.steps}")

    # 2. static vs continuous on a skewed max_new_tokens mix
    reqs = [(rng.integers(2, cfg.vocab_size, PROMPT_LEN),
             SHORT_GEN if i % 2 == 0 else LONG_GEN)
            for i in range(N_REQUESTS)]
    results = {}
    # continuous_chunk1 isolates slot recycling from decode-chunk dispatch
    # amortization (static mode necessarily runs chunk=1 so it can retire
    # whole groups every token)
    variants = {"static": ("static", 1), "continuous": ("continuous", 4),
                "continuous_chunk1": ("continuous", 1)}
    for name, (mode, chunk) in variants.items():
        eng, out, toks, dt = _run_engine(
            cfg, params, EngineConfig(ubatch=4, num_ubs=2, max_seq=128,
                                      mode=mode, decode_chunk=chunk), reqs,
            warmup=True)
        results[name] = (out, toks / dt)
        emit(f"engine_{name}_skewed", dt * 1e6,
             f"tok_per_s={toks / dt:.1f},steps={eng.steps}")
    speedup = results["continuous"][1] / results["static"][1]
    recycle_only = results["continuous_chunk1"][1] / results["static"][1]
    identical = all(results[n][0] == results["static"][0]
                    for n in ("continuous", "continuous_chunk1"))
    emit("engine_continuous_speedup", 0.0,
         f"continuous_vs_static={speedup:.2f}x,"
         f"recycle_only={recycle_only:.2f}x,greedy_identical={identical}")
    return speedup, identical


if __name__ == "__main__":
    run()

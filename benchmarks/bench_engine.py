"""Real (wall-clock) engine micro-benchmark on the CPU smoke model:
decode-step latency and tokens/s for resident vs paged weights, and
schedule-order sanity (CGOPipe micro-batch rotation).  Grounds the
HRM/simulator numbers with an actually-executing system.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig


def run():
    cfg = get_config("mixtral-8x7b").smoke()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    for paged in (False, True):
        eng = Engine(cfg, params, EngineConfig(ubatch=4, num_ubs=2,
                                               max_seq=128, paged=paged))
        for _ in range(8):
            eng.submit(rng.integers(2, cfg.vocab_size, 16), 16)
        t0 = time.perf_counter()
        out = eng.run_until_idle()
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in out.values())
        name = "paged" if paged else "resident"
        emit(f"engine_{name}_decode", dt / max(eng.steps, 1) * 1e6,
             f"tok_per_s={toks / dt:.1f},steps={eng.steps}")


if __name__ == "__main__":
    run()

"""Real (wall-clock) engine micro-benchmark on the CPU smoke model.

Three experiments:

  * resident vs paged weights: decode-step latency and tokens/s with the
    continuous slot-pool engine (grounds the HRM/simulator numbers with
    an actually-executing system);
  * static vs continuous batching on a *skewed* generation-length
    workload (half the requests generate SHORT_GEN tokens, half
    LONG_GEN): static mode retires a micro-batch only when its slowest
    row finishes, so short rows burn decode slots doing masked no-ops;
    the slot-pool engine recycles drained slots mid-flight and must win
    decisively (PR 1's acceptance bar was >= 1.5x tokens/s);
  * overlapped chunked-prefill admission on a *long-prompt* skewed
    workload: long prompts of varied (previously unseen) lengths arrive
    at a server warmed on short typical traffic.  Non-overlapped
    admission stalls every decode group for a whole-prompt prefill AND
    pays a fresh XLA compile per novel 16-token prompt bucket on the
    serving path; staged chunked prefill drains the same prompts through
    a handful of fixed chunk shapes, one chunk per tick, round-robin
    with the decode chunks (this PR's acceptance bar is >= 1.2x tokens/s
    with bit-identical greedy transcripts).

Run directly with ``--overlap`` to run just the overlap experiment.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig

SHORT_GEN, LONG_GEN = 4, 64
N_REQUESTS = 16
PROMPT_LEN = 16
# overlap experiment: long prompts with varied lengths (cold buckets)
LONG_PROMPT_RANGE = (40, 120)
N_LONG_REQUESTS = 12
OVERLAP_SHORT_GEN, OVERLAP_LONG_GEN = 4, 24


def _run_engine(cfg, params, ecfg, requests, warmup=False):
    eng = Engine(cfg, params, ecfg)
    if warmup:
        # trigger every jit compile (prefill buckets, decode chunk, slot
        # insert/reset) so the timed section measures steady-state serving
        for prompt, _ in requests[:2 * ecfg.ubatch]:
            eng.submit(prompt, 2)
        eng.run_until_idle()
        eng.steps = eng.tokens_out = 0
    base_rids = set(eng.scheduler.requests)
    for prompt, gen in requests:
        eng.submit(prompt, gen)
    t0 = time.perf_counter()
    out = eng.run_until_idle()
    dt = time.perf_counter() - t0
    out = {rid: toks for rid, toks in out.items() if rid not in base_rids}
    toks = sum(len(v) for v in out.values())
    return eng, out, toks, dt


def _run_overlap_experiment(cfg, params, rng):
    """Long-prompt skewed workload, continuous mode, overlap off vs on.
    Warmup covers short typical traffic only — the long-tail prompt
    lengths hit the admission path cold, as they would in serving."""
    reqs = [(rng.integers(2, cfg.vocab_size, int(rng.integers(*LONG_PROMPT_RANGE))),
             OVERLAP_SHORT_GEN if i % 2 == 0 else OVERLAP_LONG_GEN)
            for i in range(N_LONG_REQUESTS)]
    results = {}
    for name, overlap in (("no_overlap", False), ("overlap", True)):
        ecfg = EngineConfig(ubatch=4, num_ubs=2, max_seq=128, decode_chunk=4,
                            overlap=overlap, prefill_chunk=32)
        eng = Engine(cfg, params, ecfg)
        for _ in range(2 * ecfg.ubatch):        # short-prompt warmup
            eng.submit(rng.integers(2, cfg.vocab_size, 12), 2)
        eng.run_until_idle()
        base = set(eng.scheduler.requests)
        for p, g in reqs:
            eng.submit(p, g)
        t0 = time.perf_counter()
        out = eng.run_until_idle()
        dt = time.perf_counter() - t0
        out = {rid: toks for rid, toks in out.items() if rid not in base}
        toks = sum(len(v) for v in out.values())
        results[name] = (out, toks / dt)
        emit(f"engine_{name}_longprompt", dt * 1e6,
             f"tok_per_s={toks / dt:.1f},steps={eng.steps}")
    speedup = results["overlap"][1] / results["no_overlap"][1]
    identical = results["overlap"][0] == results["no_overlap"][0]
    emit("engine_overlap_speedup", 0.0,
         f"overlap_vs_blocking={speedup:.2f}x,greedy_identical={identical}")
    return speedup, identical


def run(overlap_only: bool = False):
    cfg = get_config("mixtral-8x7b").smoke()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)

    if overlap_only:
        return _run_overlap_experiment(cfg, params, rng)

    # 1. resident vs paged (uniform generation length)
    for paged in (False, True):
        reqs = [(rng.integers(2, cfg.vocab_size, 16), 16) for _ in range(8)]
        eng, out, toks, dt = _run_engine(
            cfg, params, EngineConfig(ubatch=4, num_ubs=2, max_seq=128,
                                      paged=paged), reqs, warmup=True)
        name = "paged" if paged else "resident"
        # per generated token (an engine tick is now a decode_chunk-token
        # chunk, so per-step latency would not be comparable to the seed)
        emit(f"engine_{name}_decode_per_tok", dt / max(toks, 1) * 1e6,
             f"tok_per_s={toks / dt:.1f},ticks={eng.steps}")

    # 2. static vs continuous on a skewed max_new_tokens mix
    reqs = [(rng.integers(2, cfg.vocab_size, PROMPT_LEN),
             SHORT_GEN if i % 2 == 0 else LONG_GEN)
            for i in range(N_REQUESTS)]
    results = {}
    # continuous_chunk1 isolates slot recycling from decode-chunk dispatch
    # amortization (static mode necessarily runs chunk=1 so it can retire
    # whole groups every token)
    variants = {"static": ("static", 1), "continuous": ("continuous", 4),
                "continuous_chunk1": ("continuous", 1)}
    for name, (mode, chunk) in variants.items():
        eng, out, toks, dt = _run_engine(
            cfg, params, EngineConfig(ubatch=4, num_ubs=2, max_seq=128,
                                      mode=mode, decode_chunk=chunk), reqs,
            warmup=True)
        results[name] = (out, toks / dt)
        emit(f"engine_{name}_skewed", dt * 1e6,
             f"tok_per_s={toks / dt:.1f},steps={eng.steps}")
    speedup = results["continuous"][1] / results["static"][1]
    recycle_only = results["continuous_chunk1"][1] / results["static"][1]
    identical = all(results[n][0] == results["static"][0]
                    for n in ("continuous", "continuous_chunk1"))
    emit("engine_continuous_speedup", 0.0,
         f"continuous_vs_static={speedup:.2f}x,"
         f"recycle_only={recycle_only:.2f}x,greedy_identical={identical}")

    # 3. blocking vs overlapped chunked-prefill admission on long prompts
    _run_overlap_experiment(cfg, params, rng)
    return speedup, identical


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--overlap", action="store_true",
                    help="run only the overlapped-admission experiment "
                         "(long-prompt skewed workload)")
    args = ap.parse_args()
    run(overlap_only=args.overlap)

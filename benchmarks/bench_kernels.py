"""Paper Fig. 9 — latency triangle: single-layer KV-cache transfer vs
attention kernel vs MoE FFN kernel, swept over micro-batch size μ and
context length.

Two modes in one table:
  * measured — REAL wall time of our kernels at CPU-tractable scale
    (attention partials path and the grouped-FFN path the Pallas kernels
    implement; interpret-mode Pallas is also timed for the record);
  * modeled  — HRM-projected latencies at the paper's full Mixtral scale
    on the L4 instance, which is what Fig. 9 plots.

``--paged`` runs the paged-decode gather report instead (nightly CI →
``BENCH_kernels.json`` artifact): KV bytes gathered per decode step and
tokens/s for the page-table-native kernel vs the dense
``kvcache.paged_view`` materialization vs a dense max_seq ring, at ring
occupancy ∈ {0.25, 0.5, 1.0} on the mixtral smoke attention geometry.
Gathered bytes are exact from the block geometry (the quantity
``Engine.kv_traffic()`` accounts); wall times are the CPU container's
(the kernel is timed under the Pallas interpreter, labeled as such —
the jnp ref path is what serves on CPU)."""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import backend_info, emit, time_call
from repro.configs import get_config
from repro.core import hrm as H
from repro.kernels import ops, ref
from repro.models import kvcache


def measured(csv=True):
    rng = np.random.default_rng(0)
    cfg = get_config("mixtral-8x7b").smoke()
    D, Hq, Hkv, Dh = cfg.d_model, 4, 2, 16
    rows = []
    for mu in (8, 32):
        for ctx in (128, 512):
            q = jnp.asarray(rng.normal(0, 1, (mu, Hq, Dh)), jnp.bfloat16)
            k = jnp.asarray(rng.normal(0, 1, (mu, ctx, Hkv, Dh)), jnp.bfloat16)
            v = jnp.asarray(rng.normal(0, 1, (mu, ctx, Hkv, Dh)), jnp.bfloat16)
            valid = jnp.ones((mu, ctx), bool)
            t_attn = time_call(
                lambda: ops.gqa_decode(q, k, v, valid, scale=Dh ** -0.5))
            # "KV transfer": host->device copy of the same KV bytes
            kv_host = np.asarray(k), np.asarray(v)
            t_kv = time_call(lambda: (jax.device_put(kv_host[0]),
                                      jax.device_put(kv_host[1])))
            E, C, F = cfg.num_experts, max(mu // 2, 1), cfg.d_ff
            x = jnp.asarray(rng.normal(0, 1, (E, C, D)), jnp.bfloat16)
            wi = jnp.asarray(rng.normal(0, .1, (E, D, 2, F)), jnp.bfloat16)
            wo = jnp.asarray(rng.normal(0, .1, (E, F, D)), jnp.bfloat16)
            t_ffn = time_call(lambda: ops.moe_ffn(x, wi, wo))
            rows.append((mu, ctx, t_kv, t_attn, t_ffn))
            if csv:
                emit(f"fig9_measured_mu{mu}_ctx{ctx}_kv_transfer",
                     t_kv * 1e6, "")
                emit(f"fig9_measured_mu{mu}_ctx{ctx}_attention",
                     t_attn * 1e6,
                     f"attn_vs_kv={t_kv / t_attn:.2f}x")
                emit(f"fig9_measured_mu{mu}_ctx{ctx}_moe_ffn",
                     t_ffn * 1e6, "")
    return rows


def modeled(csv=True):
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    cpu, gpu = hw.level("cpu"), hw.level("gpu")
    b_cg = hw.link_bw("cpu", "gpu")
    rows = []
    for mu in (32, 64, 128, 256):
        for ctx in (128, 512, 2048):
            lw = H.LayerWorkload.decode(cfg, mu, ctx)
            t_kv = lw.bytes_kv / b_cg
            t_attn = max(lw.flops_attn / cpu.p_peak, lw.bytes_kv / cpu.b_peak)
            t_ffn = max(lw.flops_ffn / gpu.p_peak, lw.bytes_w / gpu.b_peak)
            rows.append((mu, ctx, t_kv, t_attn, t_ffn))
            if csv:
                emit(f"fig9_modeled_mu{mu}_ctx{ctx}", t_attn * 1e6,
                     f"kv={t_kv * 1e3:.2f}ms,attn={t_attn * 1e3:.2f}ms,"
                     f"ffn={t_ffn * 1e3:.2f}ms,kv/attn={t_kv / t_attn:.1f}x")
    return rows


def run():
    m = measured()
    md = modeled()
    # paper's §6.2 claim: CPU attention 3-4x faster than KV transfer
    ratios = [t_kv / t_attn for (_, _, t_kv, t_attn, _) in md]
    emit("fig9_claim_cpu_attn_vs_kv_transfer", 0.0,
         f"modeled_ratio_range={min(ratios):.1f}-{max(ratios):.1f}x"
         f"(paper:3-4x)")
    return m, md


# ---------------------------------------------------------------------------
# Paged-decode gather report (BENCH_kernels.json)
# ---------------------------------------------------------------------------

PAGED_OCCUPANCY = (0.25, 0.5, 0.75, 1.0)


def _paged_case(rng, B, MB, bt, Hkv, Dh, occupancy, dtype):
    """One arena + page table at the given ring occupancy: every row maps
    a ceil(occupancy·MB)-block prefix (the steady-decode shape), arena
    sized to exactly the mapped blocks + the trash block."""
    mapped = max(1, int(np.ceil(occupancy * MB)))
    dev = B * mapped
    NB = dev + 1
    pt = np.full((B, MB), -1, np.int32)
    phys = rng.permutation(dev)
    for b in range(B):
        pt[b, :mapped] = phys[b * mapped:(b + 1) * mapped]
    # ring holds positions 0..mapped*bt-1; decode sits at the prefix end
    sp = np.full((NB, bt), -1, np.int32)
    for b in range(B):
        for j in range(mapped):
            sp[pt[b, j]] = np.arange(j * bt, (j + 1) * bt)
    pos = np.full((B,), mapped * bt - 1, np.int32)
    cache = {
        "k": kvcache.retile_arena_leaf(
            "k", jnp.asarray(rng.normal(0, 1, (NB, bt, Hkv, Dh)), dtype)),
        "v": kvcache.retile_arena_leaf(
            "v", jnp.asarray(rng.normal(0, 1, (NB, bt, Hkv, Dh)), dtype)),
        "slot_pos": jnp.asarray(sp),
        "page_table": jnp.asarray(pt),
    }
    q = jnp.asarray(rng.normal(0, 1, (B, 4 * Hkv, Dh)), dtype)
    return q, cache, jnp.asarray(pos), mapped


def paged_report(csv=True, out_path="BENCH_kernels.json"):
    cfg = get_config("mixtral-8x7b").smoke()
    Hkv, Dh = 2, cfg.head_dim or 16
    B, bt, MB = 4, 16, 16
    W = MB * bt
    rng = np.random.default_rng(0)
    itemsize = jnp.dtype(jnp.bfloat16).itemsize
    blk_bytes = 2 * bt * Hkv * Dh * itemsize          # k + v, one block
    info = backend_info()
    report = {"config": cfg.name, "ubatch": B, "block_tokens": bt,
              "max_seq": W, "kv_heads": Hkv, "head_dim": Dh,
              **info, "occupancy": {}}
    for occ in PAGED_OCCUPANCY:
        q, cache, pos, mapped = _paged_case(rng, B, MB, bt, Hkv, Dh,
                                            occ, jnp.bfloat16)
        scale = Dh ** -0.5
        kern_impl = "pallas" if not info["interpret"] else "interpret"
        t_kern = time_call(lambda: ops.paged_gqa_decode(
            q, cache, pos, scale=scale, impl=kern_impl))
        t_view = time_call(lambda: ops.paged_gqa_decode(
            q, cache, pos, scale=scale, impl="ref"))
        ring_k = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, Dh)), jnp.bfloat16)
        ring_v = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, Dh)), jnp.bfloat16)
        valid = jnp.asarray(np.arange(W)[None] < (mapped * bt))
        valid = jnp.broadcast_to(valid, (B, W))
        t_dense = time_call(lambda: ops.gqa_decode(
            q, ring_k, ring_v, valid, scale=scale,
            impl=kern_impl if not info["interpret"] else "ref"))
        kern_bytes = B * mapped * blk_bytes            # mapped blocks only
        view_bytes = B * MB * blk_bytes                # full dense view
        row = {
            "mapped_blocks_per_row": mapped,
            "kernel_gathered_bytes_per_step": kern_bytes,
            "paged_view_gathered_bytes_per_step": view_bytes,
            "dense_ring_gathered_bytes_per_step": view_bytes,
            "gather_reduction_vs_view": view_bytes / kern_bytes,
        }
        if info["interpret"]:
            # interpret-mode wall times are Python-interpreter rates —
            # recorded for the trend only, NEVER device throughput
            row["interpret_wall_tok_s_not_device_rate"] = {
                "paged_kernel": B / t_kern,
                "paged_view_ref": B / t_view,
                "dense_ref": B / t_dense,
            }
        else:
            # real-device throughput columns (TPU): compiled kernels
            row["tok_s_paged_kernel"] = B / t_kern
            row["tok_s_paged_view"] = B / t_view
            row["tok_s_dense_ring"] = B / t_dense
        report["occupancy"][str(occ)] = row
        if csv:
            emit(f"paged_decode_occ{int(occ * 100)}", t_view * 1e6,
                 f"gathered_kb={kern_bytes / 1e3:.1f},"
                 f"view_kb={view_bytes / 1e3:.1f},"
                 f"reduction={row['gather_reduction_vs_view']:.2f}x,"
                 f"backend={info['backend']}")
    tight = report["occupancy"][str(PAGED_OCCUPANCY[0])]
    report["accept_3x_reduction_at_low_occupancy"] = \
        tight["gather_reduction_vs_view"] >= 3.0
    # CI regression guard (nightly): the paged kernel must gather fewer
    # bytes than the dense view at every partial occupancy and never
    # more at full occupancy — the retile must not regress byte counts
    report["accept_beats_view_all_occupancies"] = all(
        r["gather_reduction_vs_view"] >= (1.0 if float(o) >= 1.0 else
                                          1.0 + 1e-9)
        for o, r in report["occupancy"].items())
    if csv:
        emit("paged_decode_gather_reduction", 0.0,
             f"occ={PAGED_OCCUPANCY[0]},"
             f"reduction={tight['gather_reduction_vs_view']:.2f}x,"
             f"accept={report['accept_3x_reduction_at_low_occupancy']}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="paged-decode gather report -> BENCH_kernels.json")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    if args.paged:
        paged_report(out_path=args.out)
    else:
        run()

"""Paper Fig. 9 — latency triangle: single-layer KV-cache transfer vs
attention kernel vs MoE FFN kernel, swept over micro-batch size μ and
context length.

Two modes in one table:
  * measured — REAL wall time of our kernels at CPU-tractable scale
    (attention partials path and the grouped-FFN path the Pallas kernels
    implement; interpret-mode Pallas is also timed for the record);
  * modeled  — HRM-projected latencies at the paper's full Mixtral scale
    on the L4 instance, which is what Fig. 9 plots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.core import hrm as H
from repro.kernels import ops, ref


def measured(csv=True):
    rng = np.random.default_rng(0)
    cfg = get_config("mixtral-8x7b").smoke()
    D, Hq, Hkv, Dh = cfg.d_model, 4, 2, 16
    rows = []
    for mu in (8, 32):
        for ctx in (128, 512):
            q = jnp.asarray(rng.normal(0, 1, (mu, Hq, Dh)), jnp.bfloat16)
            k = jnp.asarray(rng.normal(0, 1, (mu, ctx, Hkv, Dh)), jnp.bfloat16)
            v = jnp.asarray(rng.normal(0, 1, (mu, ctx, Hkv, Dh)), jnp.bfloat16)
            valid = jnp.ones((mu, ctx), bool)
            t_attn = time_call(
                lambda: ops.gqa_decode(q, k, v, valid, scale=Dh ** -0.5))
            # "KV transfer": host->device copy of the same KV bytes
            kv_host = np.asarray(k), np.asarray(v)
            t_kv = time_call(lambda: (jax.device_put(kv_host[0]),
                                      jax.device_put(kv_host[1])))
            E, C, F = cfg.num_experts, max(mu // 2, 1), cfg.d_ff
            x = jnp.asarray(rng.normal(0, 1, (E, C, D)), jnp.bfloat16)
            wi = jnp.asarray(rng.normal(0, .1, (E, D, 2, F)), jnp.bfloat16)
            wo = jnp.asarray(rng.normal(0, .1, (E, F, D)), jnp.bfloat16)
            t_ffn = time_call(lambda: ops.moe_ffn(x, wi, wo))
            rows.append((mu, ctx, t_kv, t_attn, t_ffn))
            if csv:
                emit(f"fig9_measured_mu{mu}_ctx{ctx}_kv_transfer",
                     t_kv * 1e6, "")
                emit(f"fig9_measured_mu{mu}_ctx{ctx}_attention",
                     t_attn * 1e6,
                     f"attn_vs_kv={t_kv / t_attn:.2f}x")
                emit(f"fig9_measured_mu{mu}_ctx{ctx}_moe_ffn",
                     t_ffn * 1e6, "")
    return rows


def modeled(csv=True):
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    cpu, gpu = hw.level("cpu"), hw.level("gpu")
    b_cg = hw.link_bw("cpu", "gpu")
    rows = []
    for mu in (32, 64, 128, 256):
        for ctx in (128, 512, 2048):
            lw = H.LayerWorkload.decode(cfg, mu, ctx)
            t_kv = lw.bytes_kv / b_cg
            t_attn = max(lw.flops_attn / cpu.p_peak, lw.bytes_kv / cpu.b_peak)
            t_ffn = max(lw.flops_ffn / gpu.p_peak, lw.bytes_w / gpu.b_peak)
            rows.append((mu, ctx, t_kv, t_attn, t_ffn))
            if csv:
                emit(f"fig9_modeled_mu{mu}_ctx{ctx}", t_attn * 1e6,
                     f"kv={t_kv * 1e3:.2f}ms,attn={t_attn * 1e3:.2f}ms,"
                     f"ffn={t_ffn * 1e3:.2f}ms,kv/attn={t_kv / t_attn:.1f}x")
    return rows


def run():
    m = measured()
    md = modeled()
    # paper's §6.2 claim: CPU attention 3-4x faster than KV transfer
    ratios = [t_kv / t_attn for (_, _, t_kv, t_attn, _) in md]
    emit("fig9_claim_cpu_attn_vs_kv_transfer", 0.0,
         f"modeled_ratio_range={min(ratios):.1f}-{max(ratios):.1f}x"
         f"(paper:3-4x)")
    return m, md


if __name__ == "__main__":
    run()

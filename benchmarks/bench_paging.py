"""H2D weight-traffic report: whole-layer streaming vs expert-granular
paged weights with a policy-sized residency cache.

Serves the same seeded workload on the mixtral smoke config (top-2 of 8
experts) through four weight layouts —

  * ``whole_layer``   — the seed baseline: every layer's full page span
    (all E experts) streams every forward pass;
  * ``expert_stream`` — expert-granular spans, no residency pool
    (w_gpu_ratio=0): only the *activated* experts stream;
  * ``expert_tight``  — a tight policy budget (w_gpu_ratio=0.25) with the
    popularity-EWMA residency cache and router-ahead prefetch;
  * ``expert_hit``    — every span fits resident (w_gpu_ratio=1.0): only
    cold-start fills stream.

— and reports measured H2D weight bytes/token, residency hit/miss/
prefetch counters, and wall-clock tokens/s, asserting nothing (the
acceptance test lives in tests/test_residency.py).  Traffic is the
engine's own accounting (DESIGN.md §2: on the CPU container traffic is
modeled, not physically moved; the byte counts are exactly what the TPU
host-offload path would transfer).

``--module-batch`` additionally sweeps module-based batching (decoupled
attention/expert phases): the same tight-budget expert-paged serve at
module_groups ∈ {1, 2, 4, 8} over an 8-group rotation, reporting the
measured bytes/token amortization curve (one expert-span stream serves
G groups' staged tokens per accumulation window).

``--predict`` / ``--replicate`` sweep the intra-pass prediction +
replication layer on a skewed workload (two prompt templates, 95% of
requests on the first — the production-realistic regime the ROADMAP
names): the PR 3 router-ahead lockstep baseline (frozen-snapshot
accounting, no predictor) vs intra-pass accounting vs gate-predictor
prefetch vs hot-expert replication, all at the tight budget.  Reports
hit rate, expert-phase H2D bytes/token, the demand/router/predicted/
replicated hit split, prefetch accuracy, and per-layer miss-stall
bytes; ``accept_hit_and_bytes`` guards the acceptance bar (hit ≥ 0.7
and ≥ 1.5× fewer expert-phase bytes/token than the PR 3 baseline —
smoke runs get a small slack on the byte ratio).

``--smoke`` shrinks the workload for the nightly CI job, which uploads
the emitted ``BENCH_paging.json`` as a workflow artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import backend_info, emit
from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig

PAGE_ELEMS = 4096          # fine pages so smoke-scale expert spans pack tight
TIGHT_RW = 0.25            # the "tight w_gpu_ratio" of the acceptance bar


def _serve(cfg, params, requests, **kw):
    base = dict(ubatch=2, num_ubs=2, max_seq=64, decode_chunk=4,
                page_elems=PAGE_ELEMS)
    base.update(kw)
    eng = Engine(cfg, params, EngineConfig(**base))
    for prompt, gen in requests:
        eng.submit(prompt, gen)
    t0 = time.perf_counter()
    out = eng.run_until_idle()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    return eng, out, toks, dt


MODULE_GROUPS_SWEEP = (1, 2, 4, 8)


def run_module_sweep(cfg, params, smoke: bool) -> dict:
    """Module-based batching amortization curve: tight-budget
    expert-paged serving over an 8-group rotation at module_groups ∈
    MODULE_GROUPS_SWEEP (G=1 is the lockstep baseline).  Decode-heavy
    workload so the expert-phase weight stream dominates."""
    rng = np.random.default_rng(1)
    n_req, gen = (16, 12) if smoke else (32, 24)
    requests = [(rng.integers(2, cfg.vocab_size, int(rng.integers(2, 8))),
                 gen) for _ in range(n_req)]
    sweep = {}
    base_row = None
    # off-TPU wall rates are labeled as such — never device throughput
    tok_key = ("tokens_per_s" if not backend_info()["interpret"]
               else "wall_tokens_per_s_not_device_rate")
    for mg in MODULE_GROUPS_SWEEP:
        eng, out, toks, dt = _serve(
            cfg, params, requests, num_ubs=8,
            expert_paged=True, w_gpu_ratio=TIGHT_RW,
            module_batch=mg > 1, module_groups=mg)
        t = eng.weight_traffic()
        row = {
            "tokens": toks,
            tok_key: toks / dt,
            "h2d_weight_bytes": int(t["h2d_bytes"]),
            "expert_phase_bytes": int(t["expert_phase_bytes"]),
            "bytes_per_token_amortized": t["bytes_per_token_amortized"],
            "module_groups_effective": t["module_groups_effective"],
            "transcripts": out,
        }
        if base_row is None:
            base_row = row
        row["amortization_vs_lockstep"] = (
            base_row["bytes_per_token_amortized"]
            / max(1.0, row["bytes_per_token_amortized"]))
        sweep[mg] = row
        emit(f"paging_module_g{mg}", dt * 1e6,
             f"tok_per_s={toks / dt:.1f},"
             f"bytes_per_tok={row['bytes_per_token_amortized']:.0f},"
             f"g_eff={row['module_groups_effective']:.2f},"
             f"amortization={row['amortization_vs_lockstep']:.2f}x")
    identical = all(r["transcripts"] == base_row["transcripts"]
                    for r in sweep.values())
    return {
        "tight_w_gpu_ratio": TIGHT_RW,
        "num_ubs": 8,
        "greedy_identical": identical,
        "groups": {str(mg): {k: v for k, v in row.items()
                             if k != "transcripts"}
                   for mg, row in sweep.items()},
    }


GUARD_MIN_HIT = 0.70        # acceptance: skewed hit rate at TIGHT_RW
GUARD_MIN_RATIO = 1.5       # acceptance: expert-phase bytes/token vs PR 3
GUARD_MIN_RATIO_SMOKE = 1.35  # slack for the shrunk nightly workload


def run_predict_sweep(cfg, params, smoke: bool,
                      predict: bool = True, replicate: bool = True) -> dict:
    """Intra-pass prediction + replication sweep on a skewed workload:
    16 requests drawn from two prompt templates with 95% of the mass on
    the first — decode-heavy (gen ≫ prompt) so the expert weight stream
    dominates and the popularity EWMA has a head worth pinning.

    ``pr3_baseline`` reproduces PR 3's router-ahead lockstep exactly
    (frozen-snapshot accounting, predictor off); ``intra`` turns on
    intra-pass accounting (a demand-missed span streams once per chunk
    and stays staged for the remaining passes); ``predict`` adds the
    cross-layer gate predictor's prioritized prefetch; ``replicate``
    pins popularity-top spans persistently; ``predict_replicate`` runs
    both.  Transcripts must be bit-identical across all variants — the
    mechanisms change *when* spans move, never *what* is computed."""
    rng = np.random.default_rng(7)
    n_req, gen = (8, 32) if smoke else (16, 48)
    temps = [rng.integers(2, cfg.vocab_size, 6) for _ in range(2)]
    requests = []
    for _ in range(n_req):
        t = temps[0] if rng.random() < 0.95 else temps[int(rng.integers(0, 2))]
        requests.append((t, gen))

    variants = {
        "pr3_baseline": dict(predict=False, intra_pass=False),
        "intra": dict(predict=False),
    }
    if predict:
        variants["predict"] = dict()
    if replicate:
        variants["replicate"] = dict(predict=False, replicate_frac=0.5)
    if predict and replicate:
        variants["predict_replicate"] = dict(replicate_frac=0.5)

    tok_key = ("tokens_per_s" if not backend_info()["interpret"]
               else "wall_tokens_per_s_not_device_rate")
    rows = {}
    outs = {}
    base = None
    for name, kw in variants.items():
        eng, out, toks, dt = _serve(
            cfg, params, requests, decode_chunk=8,
            expert_paged=True, w_gpu_ratio=TIGHT_RW, **kw)
        outs[name] = out
        t = eng.weight_traffic()
        row = {
            "tokens": toks,
            tok_key: toks / dt,
            "hit_rate": t["hit_rate"],
            "h2d_bytes_per_token": t["h2d_bytes"] / max(1, toks),
            "expert_phase_bytes_per_token":
                t["expert_phase_bytes"] / max(1, toks),
            "demand_hits": t["demand_hits"],
            "router_hits": t["router_hits"],
            "predicted_hits": t["predicted_hits"],
            "replicated_hits": t["replicated_hits"],
            "predicted_prefetches": t["predicted_prefetches"],
            "predicted_used": t["predicted_used"],
            "prefetch_accuracy": t["prefetch_accuracy"],
            "predictor_accuracy": t["predictor_accuracy"],
            "replications": t["replications"],
            "replica_spans": t["replica_spans"],
            "hidden_misses": t["hidden_misses"],
            "stall_misses": t["stall_misses"],
            "miss_stall_bytes": t["miss_stall_bytes"],
            "miss_stall_bytes_per_layer": t["miss_stall_bytes_per_layer"],
        }
        if base is None:
            base = row
        row["expert_bytes_ratio_vs_pr3"] = (
            base["expert_phase_bytes_per_token"]
            / max(1.0, row["expert_phase_bytes_per_token"]))
        rows[name] = row
        emit(f"paging_predict_{name}", dt * 1e6,
             f"hit_rate={row['hit_rate']:.3f},"
             f"expert_bytes_per_tok={row['expert_phase_bytes_per_token']:.0f},"
             f"ratio_vs_pr3={row['expert_bytes_ratio_vs_pr3']:.2f}x,"
             f"pf_acc={row['prefetch_accuracy']:.2f}")

    identical = all(outs[n] == outs["pr3_baseline"] for n in outs)
    full = ("predict_replicate" if "predict_replicate" in rows
            else "predict" if "predict" in rows
            else "replicate" if "replicate" in rows else "intra")
    min_ratio = GUARD_MIN_RATIO_SMOKE if smoke else GUARD_MIN_RATIO
    accept = (rows[full]["hit_rate"] >= GUARD_MIN_HIT
              and rows[full]["expert_bytes_ratio_vs_pr3"] >= min_ratio
              and identical)
    emit("paging_predict_accept", 0.0,
         f"variant={full},hit={rows[full]['hit_rate']:.3f}"
         f">={GUARD_MIN_HIT},"
         f"ratio={rows[full]['expert_bytes_ratio_vs_pr3']:.2f}x"
         f">={min_ratio},identical={identical},accept={accept}")
    return {
        "tight_w_gpu_ratio": TIGHT_RW,
        "decode_chunk": 8,
        "workload": {"n_req": n_req, "gen": gen, "templates": 2,
                     "dominant_frac": 0.95, "seed": 7},
        "greedy_identical": identical,
        "guard": {"min_hit_rate": GUARD_MIN_HIT, "min_bytes_ratio": min_ratio,
                  "variant": full},
        "accept_hit_and_bytes": accept,
        "variants": rows,
    }


def run(smoke: bool = False, out_path: str = "BENCH_paging.json",
        module_batch: bool = False, predict: bool = False,
        replicate: bool = False):
    cfg = get_config("mixtral-8x7b").smoke()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    n_req, gen = (8, 12) if smoke else (16, 24)
    requests = [(rng.integers(2, cfg.vocab_size, int(rng.integers(6, 20))),
                 gen) for _ in range(n_req)]

    variants = {
        "whole_layer": dict(paged=True),
        "expert_stream": dict(expert_paged=True, w_gpu_ratio=0.0),
        "expert_tight": dict(expert_paged=True, w_gpu_ratio=TIGHT_RW),
        "expert_hit": dict(expert_paged=True, w_gpu_ratio=1.0),
    }
    info = backend_info()
    report = {"config": cfg.name, "top_k": cfg.top_k,
              "num_experts": cfg.num_experts, "tight_w_gpu_ratio": TIGHT_RW,
              "page_elems": PAGE_ELEMS, **info, "variants": {}}
    tok_key = ("tokens_per_s" if not info["interpret"]
               else "wall_tokens_per_s_not_device_rate")
    outs = {}
    for name, kw in variants.items():
        eng, out, toks, dt = _serve(cfg, params, requests, **kw)
        outs[name] = out
        t = eng.weight_traffic()
        row = {
            "tokens": toks,
            tok_key: toks / dt,
            "h2d_weight_bytes": int(t["h2d_bytes"]),
            "h2d_bytes_per_token": t["h2d_bytes"] / max(1, toks),
            "fwd_passes": t["fwd_passes"],
        }
        for k in ("hits", "misses", "prefetches", "evictions", "hit_rate"):
            if k in t:
                row[k] = t[k]
        report["variants"][name] = row
        emit(f"paging_{name}", dt * 1e6,
             f"tok_per_s={toks / dt:.1f},"
             f"bytes_per_tok={row['h2d_bytes_per_token']:.0f}"
             + (f",hit_rate={t['hit_rate']:.2f}" if "hit_rate" in t else ""))

    base = report["variants"]["whole_layer"]["h2d_bytes_per_token"]
    for name in ("expert_stream", "expert_tight", "expert_hit"):
        row = report["variants"][name]
        row["traffic_reduction_vs_whole_layer"] = \
            base / max(1.0, row["h2d_bytes_per_token"])
    report["greedy_identical"] = all(outs[n] == outs["whole_layer"]
                                     for n in outs)
    tight = report["variants"]["expert_tight"]
    emit("paging_traffic_reduction", 0.0,
         f"tight_rw={TIGHT_RW},"
         f"reduction={tight['traffic_reduction_vs_whole_layer']:.2f}x,"
         f"hit_rate={tight['hit_rate']:.2f},"
         f"greedy_identical={report['greedy_identical']}")
    if module_batch:
        report["module_batch"] = run_module_sweep(cfg, params, smoke)
    if predict or replicate:
        report["predict"] = run_predict_sweep(
            cfg, params, smoke, predict=predict, replicate=replicate)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunk workload for the nightly CI job")
    ap.add_argument("--module-batch", action="store_true",
                    help="also sweep module_groups in "
                         f"{MODULE_GROUPS_SWEEP} (8-group rotation) and "
                         "report the bytes/token amortization curve")
    ap.add_argument("--predict", action="store_true",
                    help="sweep the intra-pass gate-predictor prefetch on "
                         "the skewed workload (predict section + "
                         "hit-rate/bytes acceptance guard)")
    ap.add_argument("--replicate", action="store_true",
                    help="sweep hot-expert replication on the skewed "
                         "workload (combines with --predict)")
    ap.add_argument("--out", default="BENCH_paging.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out,
        module_batch=args.module_batch, predict=args.predict,
        replicate=args.replicate)

"""Recompute census-based roofline terms for existing dry-run JSON records
(the compiled HLO facts — memory_analysis, collective cross-checks — are
unchanged; only the analytic terms are re-derived)."""
import ast
import json
import sys
from pathlib import Path
from types import SimpleNamespace

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, get_shape
from repro.core.census import census
from repro.core.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_for

MESHES = {"single_pod_16x16": {"data": 16, "model": 16},
          "multi_pod_2x16x16": {"pod": 2, "data": 16, "model": 16}}


def parse_rule(v):
    if v == "None":
        return None
    if v.startswith("("):
        return ast.literal_eval(v)
    return v


def main(dirname="experiments/dryrun"):
    n = 0
    for p in sorted(Path(dirname).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok" or r["mesh"] not in MESHES:
            continue
        cfg = get_config(r["arch"])
        shape = get_shape(r["shape"])
        mesh_shape = MESHES[r["mesh"]]
        plan = SimpleNamespace(
            dp_axes=tuple(r["plan"]["dp_axes"]),
            kv_axes=tuple(r["plan"]["kv_axes"]),
            expert_axes=tuple(r["plan"]["expert_axes"]),
            moe_variant=r["plan"]["moe_variant"],
            rules={k: parse_rule(v) for k, v in r["plan"]["rules"].items()})
        c = census(cfg, shape, mesh_shape, plan)
        chips = r["chips"]
        mf = model_flops_for(cfg, shape)
        r["flops_per_chip"] = c.flops / chips
        r["bytes_per_chip"] = c.hbm_bytes
        r["collective_bytes"] = c.coll_total
        r["collectives"] = dict(c.coll_bytes)
        r["t_compute"] = c.flops / chips / PEAK_FLOPS
        r["t_memory"] = c.hbm_bytes / HBM_BW
        r["t_collective"] = c.coll_total / ICI_BW
        terms = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}
        r["dominant"] = max(terms, key=terms.get)
        r["model_flops"] = mf
        r["useful_flops_ratio"] = mf / max(c.flops, 1.0)
        tb = max(terms.values())
        r["roofline_fraction"] = (mf / chips / tb) / PEAK_FLOPS if tb else 0.0
        p.write_text(json.dumps(r, indent=1))
        n += 1
    print(f"recomputed {n} records")


if __name__ == "__main__":
    main(*sys.argv[1:])

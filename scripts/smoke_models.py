"""Dev script: run every smoke config through forward/prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_shape
from repro.models import forward, unembed
from repro.models.inputs import concrete_inputs
from repro.models.params import count_params, init_params

ok = True
for arch in ALL_ARCHS:
    cfg = get_config(arch)
    n = count_params(cfg)
    na = count_params(cfg, active_only=True)
    smoke = cfg.smoke()
    try:
        params = init_params(smoke, jax.random.key(0))
        # train forward
        tr = concrete_inputs(smoke, get_shape("train_4k").smoke())
        kw = {k: v for k, v in tr.items() if k not in ("tokens", "targets")}
        out = forward(smoke, params, tr["tokens"], mode="train", **kw)
        logits = unembed(smoke, params, out["hidden"])
        assert logits.shape == (*tr["tokens"].shape, smoke.vocab_size)
        assert not bool(jnp.isnan(logits).any()), "NaN in train logits"
        # prefill + decode
        from repro.models import kvcache
        B, S = 4, 32
        cache = kvcache.init_cache(smoke, B, 64)
        toks = jnp.ones((B, S), jnp.int32)
        kw2 = {k: (v[:B] if hasattr(v, 'shape') else v) for k, v in kw.items()}
        out = forward(smoke, params, toks, cache=cache, mode="prefill", **kw2)
        cache = out["cache"]
        assert int(cache["pos"][0]) == S
        out = forward(smoke, params, toks[:, :1], cache=cache, mode="decode", **kw2)
        lg = unembed(smoke, params, out["hidden"][:, -1])
        assert lg.shape == (B, smoke.vocab_size)
        assert not bool(jnp.isnan(lg).any()), "NaN in decode logits"
        print(f"OK   {arch:24s} params={n/1e9:8.3f}B active={na/1e9:8.3f}B")
    except Exception as e:  # noqa
        ok = False
        import traceback
        print(f"FAIL {arch}: {type(e).__name__}: {e}")
        traceback.print_exc()
sys.exit(0 if ok else 1)

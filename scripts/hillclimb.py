"""Perf-iteration driver: run one dry-run cell with explicit overrides and
log (hypothesis, change, before/after terms) to experiments/perf_log.jsonl.

  PYTHONPATH=src python scripts/hillclimb.py --arch gemma2-2b \
      --shape train_4k --tag mb16 --hypothesis "..." \
      --override num_micro=16 --override remat=True
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

import argparse
import ast
import json
import time
from pathlib import Path


def parse_override(s):
    k, v = s.split("=", 1)
    try:
        v = ast.literal_eval(v)
    except Exception:
        pass
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell, OUT_DIR
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    mesh_name = ("multi_pod_2x16x16" if args.mesh == "multi"
                 else "single_pod_16x16")
    overrides = dict(parse_override(s) for s in args.override)
    rec = run_cell(args.arch, args.shape, mesh, mesh_name,
                   out_dir=OUT_DIR.parent / "hillclimb",
                   plan_overrides=overrides, tag=args.tag)
    entry = {"t": time.strftime("%H:%M:%S"), "arch": args.arch,
             "shape": args.shape, "mesh": mesh_name, "tag": args.tag,
             "hypothesis": args.hypothesis, "overrides": overrides,
             "status": rec.get("status")}
    if rec.get("status") == "ok":
        entry.update({k: rec[k] for k in
                      ("t_compute", "t_memory", "t_collective", "dominant",
                       "useful_flops_ratio", "roofline_fraction")})
        entry["mem_gb"] = round((rec["memory_per_chip"]["argument"]
                                 + rec["memory_per_chip"]["temp"]) / 1e9, 2)
    log = Path("experiments/perf_log.jsonl")
    log.parent.mkdir(exist_ok=True)
    with log.open("a") as f:
        f.write(json.dumps(entry) + "\n")
    print(json.dumps(entry, indent=1))


if __name__ == "__main__":
    main()

"""Generate the data-driven sections of EXPERIMENTS.md (§Dry-run table,
§Roofline table) from experiments/dryrun/*.json, splicing them between
hand-written sections kept in this file's TEMPLATE."""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def load():
    recs = {}
    for p in sorted((ROOT / "experiments/dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


ARCH_ORDER = ["gemma2-2b", "olmo-1b", "glm4-9b", "qwen2.5-3b",
              "paligemma-3b", "moonshot-v1-16b-a3b", "deepseek-v3-671b",
              "mamba2-1.3b", "jamba-1.5-large-398b", "whisper-small",
              "mixtral-8x7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
MESHES = ["single_pod_16x16", "multi_pod_2x16x16"]


def dryrun_table(recs):
    out = ["| arch | shape | mesh | status | bytes/chip (arg+temp) | "
           "HLO collectives (trip-scaled) | plan |",
           "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPES:
            for m in MESHES:
                r = recs.get((a, s, m))
                if r is None:
                    continue
                mm = "2pod" if "multi" in m else "1pod"
                if r["status"] == "skipped":
                    out.append(f"| {a} | {s} | {mm} | skip | — | — | "
                               f"{r['reason'][:40]} |")
                    continue
                if r["status"] != "ok":
                    out.append(f"| {a} | {s} | {mm} | **FAIL** | — | — | "
                               f"{r.get('error', '')[:60]} |")
                    continue
                mem = r["memory_per_chip"]
                gb = (mem["argument"] + mem["temp"]) / 1e9
                hx = r.get("hlo_collectives_scaled", {})
                hxs = ", ".join(f"{k}:{v / 1e9:.2f}GB"
                                for k, v in sorted(hx.items())
                                if isinstance(v, (int, float)) and v > 1e7)
                p = r["plan"]
                plan = (f"dp={','.join(p['dp_axes']) or '-'} "
                        f"kv={','.join(p['kv_axes']) or '-'} "
                        f"ep={','.join(p['expert_axes']) or '-'} "
                        f"{p['moe_variant']}")
                out.append(f"| {a} | {s} | {mm} | ok ({r['compile_s']}s) | "
                           f"{gb:.1f} GB | {hxs or '—'} | {plan} |")
    return "\n".join(out)


def roofline_table(recs):
    out = ["| arch | shape | t_compute | t_memory | t_collective | bound | "
           "MODEL/HLO flops | roofline frac | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        "compute": "more useful FLOPs/byte needs larger per-chip batch or "
                   "lower capacity factor",
        "memory": "decode is weight/KV-read bound: quantize weights (int8 "
                  "experts) or grow batch",
        "collective": "resharding/a2a bound: move activations not weights; "
                      "see §Perf",
    }
    for a in ARCH_ORDER:
        for s in SHAPES:
            r = recs.get((a, s, "single_pod_16x16"))
            if r is None or r["status"] != "ok":
                if r is not None and r["status"] == "skipped":
                    out.append(f"| {a} | {s} | — | — | — | — | — | — | "
                               f"skip(full-attn) |")
                continue
            out.append(
                f"| {a} | {s} | {r['t_compute'] * 1e3:.2f} ms | "
                f"{r['t_memory'] * 1e3:.2f} ms | "
                f"{r['t_collective'] * 1e3:.2f} ms | **{r['dominant']}** | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction'] * 100:.1f}% | "
                f"{notes[r['dominant']][:52]} |")
    return "\n".join(out)


def main():
    recs = load()
    md = (ROOT / "EXPERIMENTS.md").read_text()
    start = md.index("<!-- DRYRUN_TABLE -->")
    end = md.index("<!-- END_DRYRUN_TABLE -->")
    md = (md[:start] + "<!-- DRYRUN_TABLE -->\n" + dryrun_table(recs) + "\n"
          + md[end:])
    start = md.index("<!-- ROOFLINE_TABLE -->")
    end = md.index("<!-- END_ROOFLINE_TABLE -->")
    md = (md[:start] + "<!-- ROOFLINE_TABLE -->\n" + roofline_table(recs)
          + "\n" + md[end:])
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

"""End-to-end driver (the paper's workload): serve a ~110M-parameter LM
with batched requests through the full MoE-Lightning pipeline —

  1. HRM policy search for the target hardware (paper §4.2),
  2. Algorithm-2 request placement (paper Appendix A.2) — incremental
     per-slot admission in continuous mode, whole micro-batches in static,
  3. paged weights consumed layer-by-layer in-scan (paper Appendix A.1),
  4. continuous batching over a persistent KV slot pool with CGOPipe
     micro-batch rotation (paper §4.1): drained slots are recycled
     mid-flight, so skewed generation lengths don't strand decode rows.

  PYTHONPATH=src python examples/offloaded_serving.py \
      [--requests 32] [--mode continuous|static] [--skew] \
      [--overlap] [--long-prompts]

``--overlap`` stages admission as chunked prefill interleaved with the
decode chunks (request-level CGOPipe); pair with ``--long-prompts`` to
see it matter — long varied-length prompts otherwise stall every decode
group for a whole-prompt (freshly compiled) prefill.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import hrm, policy as pol
from repro.models.params import count_params, init_params
from repro.serving.engine import Engine, EngineConfig

# a real ~110M dense LM (full config, not a smoke reduction)
LM_110M = ModelConfig(
    name="lm-110m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32_000,
    period=(LayerSpec(),), norm="rmsnorm", ffn_act="silu",
    tie_embeddings=True, rope_theta=10_000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=12)
    # --paged / --no-paged; default (neither) runs BOTH and shows the
    # comparison (the old `store_true, default=True` made --paged a no-op
    # and left the unpaged baseline unreachable)
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="paged-weight streaming; omit to run both "
                         "paged and resident and compare")
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--skew", action="store_true",
                    help="mix short (gen-len/4) and long (gen-len) "
                         "generations to show slot recycling")
    ap.add_argument("--overlap", action="store_true",
                    help="staged chunked-prefill admission interleaved "
                         "with decode (continuous mode only)")
    ap.add_argument("--long-prompts", action="store_true",
                    help="draw prompts from 16..48 tokens instead of "
                         "4..24 (shows what --overlap buys)")
    args = ap.parse_args()

    print(f"params: {count_params(LM_110M) / 1e6:.1f}M")

    # 1. HRM policy advice (what μ/N/placement the paper's optimizer picks
    #    for this model on an L4-class box)
    advice = pol.search(LM_110M, hrm.preset("l4"),
                        pol.Workload(prompt_len=24, gen_len=args.gen_len))
    p = advice["best"]["policy"]
    print(f"HRM policy: N={p.batch} mu={p.ubatch} attn_on_gpu={p.attn_on_gpu}"
          f" r_w={p.w_gpu_ratio} (est {advice['best']['throughput']:.0f}"
          f" tok/s on L4)")

    # 2-4. run the engine (CPU-scaled micro-batches; same code path);
    # default shows BOTH weight layouts back to back
    params = init_params(LM_110M, jax.random.key(0))
    rng = np.random.default_rng(0)
    lo, hi = (16, 49) if args.long_prompts else (4, 25)
    requests = []
    for i in range(args.requests):
        n = int(rng.integers(lo, hi))
        gen = (max(1, args.gen_len // 4) if args.skew and i % 2 == 0
               else args.gen_len)
        requests.append((rng.integers(2, LM_110M.vocab_size, n), gen))

    variants = [(True,), (False,)] if args.paged is None else [(args.paged,)]
    outs = {}
    for (paged,) in variants:
        eng = Engine(LM_110M, params,
                     EngineConfig(ubatch=4, num_ubs=2, max_seq=64,
                                  paged=paged, page_elems=1 << 18,
                                  mode=args.mode, overlap=args.overlap,
                                  prefill_chunk=16))
        for prompt, gen in requests:
            eng.submit(prompt, gen)
        t0 = time.time()
        out = eng.run_until_idle()
        dt = time.time() - t0
        outs[paged] = out
        toks = sum(len(v) for v in out.values())
        traffic = eng.weight_traffic()
        print(f"served {len(out)} requests, {toks} tokens in {dt:.1f}s "
              f"({toks / dt:.1f} tok/s, paged={paged}, mode={args.mode}, "
              f"overlap={args.overlap}, engine ticks={eng.steps}, "
              f"H2D weight bytes={traffic['h2d_bytes'] / 1e6:.0f}MB)")
        if args.mode == "continuous":
            fills = [len(s.history)
                     for grp in eng.scheduler.slots for s in grp]
            print(f"slot pool: {len(fills)} slots, "
                  f"{sum(fills)} admissions (max reuse {max(fills)}x)")
    if len(outs) == 2:
        print(f"greedy transcripts identical across paged/resident: "
              f"{outs[True] == outs[False]}")


if __name__ == "__main__":
    main()

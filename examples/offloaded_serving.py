"""End-to-end driver (the paper's workload): serve a ~110M-parameter LM
with batched requests through the full MoE-Lightning pipeline —

  1. HRM policy search for the target hardware (paper §4.2),
  2. Algorithm-2 request placement (paper Appendix A.2) — incremental
     per-slot admission in continuous mode, whole micro-batches in static,
  3. paged weights consumed layer-by-layer in-scan (paper Appendix A.1),
  4. continuous batching over a persistent KV slot pool with CGOPipe
     micro-batch rotation (paper §4.1): drained slots are recycled
     mid-flight, so skewed generation lengths don't strand decode rows.

  PYTHONPATH=src python examples/offloaded_serving.py \
      [--requests 32] [--mode continuous|static] [--skew] \
      [--overlap] [--long-prompts] \
      [--kv-paged | --no-kv-paged] [--kv-gpu-ratio 0.25] [--block-tokens 16] \
      [--module-batch | --no-module-batch] [--module-groups N]

``--overlap`` stages admission as chunked prefill interleaved with the
decode chunks (request-level CGOPipe); pair with ``--long-prompts`` to
see it matter — long varied-length prompts otherwise stall every decode
group for a whole-prompt (freshly compiled) prefill.

``--kv-paged`` swaps the dense max_seq-wide KV rings for the
block-granular paged pool (shared arena + page tables) with the host
tier sized from ``--kv-gpu-ratio`` (the policy's r_c); omitting the
flag runs BOTH layouts and prints paged-vs-dense device KV bytes/token
alongside the weight-paging comparison.

``--module-batch`` turns on module-based batching: attention + router
run for ``--module-groups`` rotation groups back-to-back and each
paged weight span streams once per accumulation window instead of once
per group — omitting the flag runs BOTH schedules and prints lockstep
vs module-batched H2D weight bytes/token.

``--predict`` / ``--no-predict`` and ``--replicate-frac`` drive the
MoE expert-paging epilogue (the 110M LM is dense, so this serves the
mixtral smoke config with expert-granular paged weights at r_w=0.25 on
a skewed two-template workload): intra-pass gate-predictor prefetch
and hot-expert replication.  Omitting ``--predict`` runs BOTH the
PR 3-style router-ahead baseline and the predict+replicate engine and
prints the hit-rate and expert H2D bytes/token deltas.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import hrm, policy as pol
from repro.models.params import count_params, init_params
from repro.serving.engine import Engine, EngineConfig

# a real ~110M dense LM (full config, not a smoke reduction)
LM_110M = ModelConfig(
    name="lm-110m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32_000,
    period=(LayerSpec(),), norm="rmsnorm", ffn_act="silu",
    tie_embeddings=True, rope_theta=10_000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=12)
    # --paged / --no-paged; default (neither) runs BOTH and shows the
    # comparison (the old `store_true, default=True` made --paged a no-op
    # and left the unpaged baseline unreachable)
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="paged-weight streaming; omit to run both "
                         "paged and resident and compare")
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--skew", action="store_true",
                    help="mix short (gen-len/4) and long (gen-len) "
                         "generations to show slot recycling")
    ap.add_argument("--overlap", action="store_true",
                    help="staged chunked-prefill admission interleaved "
                         "with decode (continuous mode only)")
    ap.add_argument("--long-prompts", action="store_true",
                    help="draw prompts from 16..48 tokens instead of "
                         "4..24 (shows what --overlap buys)")
    # --kv-paged / --no-kv-paged; omit to run both layouts and compare
    ap.add_argument("--kv-paged", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="block-granular paged KV pool (shared arena + "
                         "page tables + host tier); omit to run both "
                         "paged and dense and compare bytes/token")
    ap.add_argument("--kv-gpu-ratio", type=float, default=0.25,
                    help="r_c — fraction of KV blocks resident in the "
                         "device arena (rest spills to the host tier)")
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="ring positions per KV block (must divide "
                         "max_seq)")
    # --module-batch / --no-module-batch; omit to run both and compare
    ap.add_argument("--module-batch",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="module-based batching (decoupled attention/"
                         "expert phases, one weight stream per "
                         "accumulation window); omit to run both "
                         "schedules and compare H2D bytes/token")
    ap.add_argument("--module-groups", type=int, default=None,
                    help="rotation groups per accumulation window "
                         "(default: num_ubs)")
    # --predict / --no-predict; omit to run both and print the deltas
    ap.add_argument("--predict", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="intra-pass gate-predictor prefetch in the MoE "
                         "expert-paging epilogue; omit to run both the "
                         "router-ahead baseline and predict+replicate "
                         "and compare hit rate + bytes/token")
    ap.add_argument("--replicate-frac", type=float, default=0.5,
                    help="fraction of the residency pool pinned to the "
                         "popularity-top experts in the MoE epilogue "
                         "(0 disables replication)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="chaos epilogue: replay the seeded fault "
                         "schedule against the offload plane and print "
                         "degradation events, retry counts, and the "
                         "transcript-identity verdict")
    args = ap.parse_args()

    print(f"params: {count_params(LM_110M) / 1e6:.1f}M")

    # 1. HRM policy advice (what μ/N/placement the paper's optimizer picks
    #    for this model on an L4-class box)
    advice = pol.search(LM_110M, hrm.preset("l4"),
                        pol.Workload(prompt_len=24, gen_len=args.gen_len))
    p = advice["best"]["policy"]
    print(f"HRM policy: N={p.batch} mu={p.ubatch} attn_on_gpu={p.attn_on_gpu}"
          f" r_w={p.w_gpu_ratio} (est {advice['best']['throughput']:.0f}"
          f" tok/s on L4)")

    # 2-4. run the engine (CPU-scaled micro-batches; same code path);
    # default shows BOTH weight layouts back to back
    params = init_params(LM_110M, jax.random.key(0))
    rng = np.random.default_rng(0)
    lo, hi = (16, 49) if args.long_prompts else (4, 25)
    requests = []
    for i in range(args.requests):
        n = int(rng.integers(lo, hi))
        gen = (max(1, args.gen_len // 4) if args.skew and i % 2 == 0
               else args.gen_len)
        requests.append((rng.integers(2, LM_110M.vocab_size, n), gen))

    w_variants = [True, False] if args.paged is None else [args.paged]
    kv_variants = [True, False] if args.kv_paged is None else [args.kv_paged]
    mb_variants = ([False, True] if args.module_batch is None
                   else [args.module_batch])
    outs = {}
    kv_rows = {}
    mb_rows = {}
    for paged in w_variants:
        for kv_paged, module_batch in [(kv, mb) for kv in kv_variants
                                       for mb in mb_variants]:
            eng = Engine(LM_110M, params,
                         EngineConfig(ubatch=4, num_ubs=2, max_seq=64,
                                      paged=paged, page_elems=1 << 18,
                                      mode=args.mode, overlap=args.overlap,
                                      prefill_chunk=16, kv_paged=kv_paged,
                                      kv_gpu_ratio=args.kv_gpu_ratio,
                                      block_tokens=args.block_tokens,
                                      module_batch=module_batch,
                                      module_groups=args.module_groups))
            for prompt, gen in requests:
                eng.submit(prompt, gen)
            t0 = time.time()
            out = eng.run_until_idle()
            dt = time.time() - t0
            outs[(paged, kv_paged, module_batch)] = out
            toks = sum(len(v) for v in out.values())
            traffic = eng.weight_traffic()
            kvt = eng.kv_traffic()
            kv_rows[kv_paged] = kvt
            if paged:
                mb_rows[module_batch] = traffic["h2d_bytes"] / max(1, toks)
            kv_note = (f", KV dev bytes/tok="
                       f"{kvt['device_kv_bytes'] / max(1, toks):.0f}"
                       + (f" (arena occ {kvt['arena_utilization']:.2f}, "
                          f"KV H2D {kvt['h2d_bytes'] / 1e6:.1f}MB, "
                          f"decode gather "
                          f"{kvt['gather_reduction_vs_view']:.1f}x below "
                          f"the dense view)"
                          if kv_paged else ""))
            print(f"served {len(out)} requests, {toks} tokens in {dt:.1f}s "
                  f"({toks / dt:.1f} tok/s, paged={paged}, "
                  f"kv_paged={kv_paged}, module_batch={module_batch}, "
                  f"mode={args.mode}, "
                  f"overlap={args.overlap}, engine ticks={eng.steps}, "
                  f"H2D weight bytes={traffic['h2d_bytes'] / 1e6:.0f}MB"
                  f"{kv_note})")
            if args.mode == "continuous":
                fills = [len(s.history)
                         for grp in eng.scheduler.slots for s in grp]
                print(f"slot pool: {len(fills)} slots, "
                      f"{sum(fills)} admissions (max reuse {max(fills)}x)")
    if len(kv_rows) == 2:
        toks = sum(len(v) for v in next(iter(outs.values())).values())
        dense_bt = kv_rows[False]["device_kv_bytes"] / max(1, toks)
        paged_bt = kv_rows[True]["device_kv_bytes"] / max(1, toks)
        print(f"device KV bytes/token: dense={dense_bt:.0f} "
              f"paged={paged_bt:.0f} "
              f"({dense_bt / max(1.0, paged_bt):.2f}x smaller at "
              f"r_c={args.kv_gpu_ratio})")
    if len(mb_rows) == 2:
        print(f"H2D weight bytes/token (paged): "
              f"lockstep={mb_rows[False]:.0f} "
              f"module-batched={mb_rows[True]:.0f} "
              f"({mb_rows[False] / max(1.0, mb_rows[True]):.2f}x fewer "
              f"per accumulation window)")
    if len(outs) > 1:
        base = next(iter(outs.values()))
        print(f"greedy transcripts identical across all "
              f"{len(outs)} weight/KV layouts: "
              f"{all(o == base for o in outs.values())}")

    # 5. MoE expert-paging epilogue: intra-pass prediction + replication
    #    (needs routed experts — LM_110M is dense, so this serves the
    #    mixtral smoke config on a skewed two-template workload)
    import dataclasses
    from repro.configs import get_config
    mcfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                               dtype="float32")
    mparams = init_params(mcfg, jax.random.key(1))
    mrng = np.random.default_rng(7)
    temps = [mrng.integers(2, mcfg.vocab_size, 6) for _ in range(2)]
    mreqs = []
    for _ in range(16):
        t = (temps[0] if mrng.random() < 0.95
             else temps[int(mrng.integers(0, 2))])
        mreqs.append((t, max(8, 2 * args.gen_len)))
    if args.predict is None:
        moe_variants = [("router-ahead baseline",
                         dict(predict=False, intra_pass=False)),
                        ("predict+replicate",
                         dict(predict=True,
                              replicate_frac=args.replicate_frac))]
    else:
        moe_variants = [("predict" if args.predict else "no-predict",
                         dict(predict=args.predict,
                              replicate_frac=args.replicate_frac))]
    moe_rows = {}
    moe_outs = {}
    for name, kw in moe_variants:
        eng = Engine(mcfg, mparams,
                     EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                  decode_chunk=8, page_elems=4096,
                                  expert_paged=True, w_gpu_ratio=0.25,
                                  **kw))
        for prompt, gen in mreqs:
            eng.submit(prompt, gen)
        out = eng.run_until_idle()
        toks = sum(len(v) for v in out.values())
        t = eng.weight_traffic()
        moe_rows[name] = (t["hit_rate"],
                          t["expert_phase_bytes"] / max(1, toks))
        moe_outs[name] = out
        print(f"MoE expert paging [{name}]: hit_rate={t['hit_rate']:.3f}, "
              f"expert H2D bytes/tok="
              f"{t['expert_phase_bytes'] / max(1, toks):.0f}, "
              f"prefetch_accuracy={t['prefetch_accuracy']:.2f}, "
              f"replica_spans={t['replica_spans']}")
    if len(moe_rows) == 2:
        (bh, bb), (ph, pb) = (moe_rows[n] for n, _ in moe_variants)
        ident = (moe_outs[moe_variants[0][0]]
                 == moe_outs[moe_variants[1][0]])
        print(f"predict+replicate vs baseline: hit rate {bh:.3f} -> "
              f"{ph:.3f} (+{ph - bh:.3f}), expert bytes/token "
              f"{bb:.0f} -> {pb:.0f} ({bb / max(1.0, pb):.2f}x fewer), "
              f"transcripts identical: {ident}")

    # 6. chaos epilogue (--chaos SEED): the same skewed MoE smoke served
    #    twice — fault-free, then under the seeded fault schedule — with
    #    the degradation ladder walking rungs live (DESIGN.md §10)
    if args.chaos is not None:
        from repro.runtime.faults import FaultEvent, FaultPlan
        crng = np.random.default_rng(args.chaos)
        sites = ("kv_spill", "kv_fetch", "kv_pool", "expert_copy",
                 "plan_drain", "host_alloc", "dispatch")
        plan = FaultPlan(
            seed=args.chaos,
            probs={"*": {"fail": 0.06, "stall": 0.04, "partial": 0.04,
                         "exhaust": 0.03, "hostmem": 0.01}},
            trace=[FaultEvent(sites[int(crng.integers(0, len(sites)))],
                              ("fail", "stall", "partial",
                               "exhaust")[int(crng.integers(0, 4))],
                              after=int(crng.integers(0, 10)),
                              count=int(crng.integers(1, 6)))],
            stall_ms=float(crng.integers(50, 5000)),
            max_faults=int(crng.integers(40, 200)))
        ckw = dict(ubatch=2, num_ubs=2, max_seq=64, decode_chunk=4,
                   expert_paged=True, w_gpu_ratio=0.5, prefetch=True,
                   predict=True, module_batch=True, kv_paged=True,
                   kv_gpu_ratio=0.25, kv_prefetch=True)
        cwork = [(mrng.integers(2, mcfg.vocab_size,
                                int(mrng.integers(4, 20))),
                  4 if i % 2 == 0 else 12) for i in range(8)]
        runs = {}
        for label, extra in (("fault-free", {}),
                             ("chaos", dict(fault_plan=plan,
                                            degrade_down_after=2,
                                            degrade_up_after=5))):
            eng = Engine(mcfg, mparams, EngineConfig(**ckw, **extra))
            for prompt, gen in cwork:
                eng.submit(prompt, gen)
            runs[label] = (eng, eng.run_until_idle())
        eng, out = runs["chaos"]
        ft = eng.fault_traffic()
        print(f"\nchaos epilogue (seed {args.chaos}):")
        print(f"  injected {ft['injected_total']} faults: "
              + (", ".join(f"{k}x{v}"
                           for k, v in sorted(ft["injected"].items()))
                 or "none"))
        print(f"  retries={ft['retries']} aborts={ft['aborts']} "
              f"stalls={ft['stalls']} hostmem={ft['hostmem_faults']} "
              f"shed={ft['shed_requests']}")
        for ev in ft["degradation_events"]:
            arrow = "↓" if ev["direction"] == "down" else "↑"
            print(f"  ladder {arrow} {ev['from']} -> {ev['to']} "
                  f"(reason: {ev['reason']})")
        if not ft["degradation_events"]:
            print("  ladder: no transitions (faults absorbed by retries)")
        print(f"  final rung: {ft['level_name']} "
              f"(demotions={ft['demotions']}, "
              f"promotions={ft['promotions']})")
        ident = out == runs["fault-free"][1]
        print(f"  transcripts identical to fault-free run: {ident}")


if __name__ == "__main__":
    main()

"""Quickstart: train a tiny MoE LM for 40 steps, checkpoint it, reload it,
and generate a few tokens with the batching engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np

from repro.configs import get_config
from repro.runtime.checkpoint import CheckpointManager
from repro.serving.engine import Engine, EngineConfig
from repro.training.trainer import Trainer, TrainConfig


def main():
    cfg = get_config("mixtral-8x7b").smoke()
    print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
          f"experts={cfg.num_experts} top{cfg.top_k}")

    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(cfg, TrainConfig(
            steps=40, batch_size=4, seq_len=64, ckpt_dir=d, ckpt_every=20,
            log_every=10))
        final = trainer.run()
        print("training done:", {k: round(v, 3) for k, v in final.items()})
        for m in trainer.metrics_log:
            print(f"  step {m['step']:>3} loss {m['loss']:.3f}")

        # resume from checkpoint (fault-tolerance path) and serve
        ckpt = CheckpointManager(d)
        step, tree, _ = ckpt.restore()
        print(f"restored checkpoint @ step {step}")

        eng = Engine(cfg, tree["params"],
                     EngineConfig(ubatch=4, num_ubs=2, max_seq=96))
        rng = np.random.default_rng(0)
        for i in range(6):
            eng.submit(rng.integers(2, cfg.vocab_size, 8 + i), 8)
        out = eng.run_until_idle()
        print("generated:", {rid: toks for rid, toks in sorted(out.items())})


if __name__ == "__main__":
    main()

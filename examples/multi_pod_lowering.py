"""Programmatic multi-pod lowering + roofline readout for one cell —
the public API the dry-run harness is built on.

NOTE: must run in a fresh process (device count is fixed at jax init).

  PYTHONPATH=src python examples/multi_pod_lowering.py \
      [--arch deepseek-v3-671b] [--shape decode_32k] [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v3-671b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    name = "multi_pod_2x16x16" if args.multi_pod else "single_pod_16x16"
    print(f"mesh: {name} ({mesh.size} chips), cell: "
          f"{args.arch} x {args.shape}")
    compiled, rep, plan = lower_cell(args.arch, args.shape, mesh, name)
    print(f"plan: dp={plan.dp_axes} kv={plan.kv_axes} "
          f"experts={plan.expert_axes} moe={plan.moe_variant}")
    m = compiled.memory_analysis()
    print(f"memory/chip: args={m.argument_size_in_bytes / 1e9:.2f}GB "
          f"temp={m.temp_size_in_bytes / 1e9:.2f}GB")
    print(f"roofline terms: compute={rep.t_compute * 1e3:.2f}ms "
          f"memory={rep.t_memory * 1e3:.2f}ms "
          f"collective={rep.t_collective * 1e3:.2f}ms "
          f"-> bound: {rep.dominant}")
    print(f"useful-FLOPs ratio {rep.useful_flops_ratio:.2f}, "
          f"roofline fraction {rep.roofline_fraction * 100:.1f}%")


if __name__ == "__main__":
    main()

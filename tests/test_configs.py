"""Config-layer tests: every assigned arch loads with its published
numbers; param counts match public figures; shape applicability matrix."""
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config, \
    get_shape, shape_applicable
from repro.models.params import count_params

EXPECTED = {
    # arch: (total params ±tol, active ±tol) in billions; None = sanity only
    "gemma2-2b": (2.61, None),
    "olmo-1b": (1.18, None),
    "glm4-9b": (9.40, None),
    "qwen2.5-3b": (3.09, None),
    "paligemma-3b": (2.51, None),        # LM backbone only (vision stubbed)
    "deepseek-v3-671b": (671.0, 37.55),
    "mamba2-1.3b": (1.34, None),
    "jamba-1.5-large-398b": (397.7, None),
    "whisper-small": (0.24, None),
    "mixtral-8x7b": (46.7, 12.9),
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    assert cfg.num_periods * len(cfg.period) + len(cfg.prologue) \
        == cfg.num_layers


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_param_counts(arch):
    total, active = EXPECTED[arch]
    n = count_params(get_config(arch)) / 1e9
    assert abs(n - total) / total < 0.02, f"{arch}: {n:.2f}B vs {total}B"
    if active:
        na = count_params(get_config(arch), active_only=True) / 1e9
        assert abs(na - active) / active < 0.03


def test_assigned_archs_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(SHAPES) == 4


def test_shape_applicability():
    # long_500k only for the sub-quadratic stacks
    runs = {a for a in ALL_ARCHS
            if shape_applicable(get_config(a), get_shape("long_500k"))[0]}
    assert runs == {"mamba2-1.3b", "jamba-1.5-large-398b"}
    for a in ALL_ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), get_shape(s))[0]


def test_smoke_configs_reduced():
    for a in ALL_ARCHS:
        cfg, sm = get_config(a), get_config(a).smoke()
        assert sm.num_layers <= cfg.num_layers
        assert sm.d_model < cfg.d_model
        assert count_params(sm) < count_params(cfg)
        assert len(sm.period) == len(cfg.period)   # same family structure

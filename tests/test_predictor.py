"""Intra-pass prediction + replication properties: GatePredictor
online-fit convergence on a synthetic permutation-structured gate
(hypothesis + seeded fallback), replica pinning/hysteresis under
admission pressure, EDF ordering of predicted transfers, and the
engine-level guarantee that predicted prefetch dedupes against the
router-ahead queue — a span wanted by both paths is fetched once."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import paging, residency


# ---------------------------------------------------------------------------
# GatePredictor: online-fit convergence on a permutation gate
# ---------------------------------------------------------------------------

def _run_permutation_trajectory(seed, n_steps=80):
    """Synthetic skewed gate with exact cross-layer structure: expert e
    active at layer i ⇒ expert perm[e] active at layer i+1, cyclically
    across passes (layer L-1 of pass t seeds layer 0 of pass t+1) — the
    deterministic analogue of an aligned decode trajectory.  The
    predictor must learn every head, including the wrap head, to score
    well."""
    rng = np.random.default_rng(seed)
    L, E = 3, 8
    perm = rng.permutation(E)
    gp = residency.GatePredictor(L, E)

    def step_fwd(vec):
        nxt = np.zeros_like(vec)
        nxt[perm[vec > 0]] = 1.0
        return nxt

    cur0 = np.zeros(E)
    cur0[rng.choice(E, 2, replace=False)] = 1.0
    counts = None
    for _ in range(n_steps):
        counts = np.zeros((L, E))
        counts[0] = cur0
        for i in range(1, L):
            counts[i] = step_fwd(counts[i - 1])
        gp.fit_step(counts)
        cur0 = step_fwd(counts[L - 1])      # next pass re-enters layer 0
    return gp, perm, counts


def _check_convergence(seed):
    gp, perm, counts = _run_permutation_trajectory(seed)
    assert gp.acc >= 0.9, f"predictor failed to converge: acc={gp.acc:.3f}"
    # shift-1 predictions reproduce the permutation for every layer,
    # wrap included: active experts at layer i predict perm[e] at
    # (i+1) % L
    preds = gp.predict(counts, lookahead=1)
    by_layer = {}
    for l, e, _ in preds:
        by_layer.setdefault(l, set()).add(e)
    L = counts.shape[0]
    for i in range(L):
        expected = {int(perm[e]) for e in np.flatnonzero(counts[i] > 0)}
        assert by_layer.get((i + 1) % L, set()) == expected


if HAS_HYPOTHESIS:
    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_predictor_converges_on_permutation_gate(seed):
        _check_convergence(seed)


def test_predictor_converges_on_permutation_gate_seeded():
    for seed in range(8):
        _check_convergence(seed)


def test_predictor_lookahead_chains_permutation():
    """Shift-2 scores must cover the two-steps-ahead experts — the
    "stream layer i+2 while layer i computes" claim."""
    gp, perm, counts = _run_permutation_trajectory(11)
    L = counts.shape[0]
    preds = gp.predict(counts, lookahead=2)
    by_layer = {}
    for l, e, _ in preds:
        by_layer.setdefault(l, set()).add(e)
    for i in range(L):
        two_ahead = {int(perm[perm[e]])
                     for e in np.flatnonzero(counts[i] > 0)}
        assert two_ahead <= by_layer.get((i + 2) % L, set())


def test_predictor_accuracy_is_pre_update():
    """The first fit_step scores an untrained head — accuracy must
    reflect chance, not the post-update weights."""
    gp = residency.GatePredictor(2, 8)
    counts = np.zeros((2, 8))
    counts[0, 0] = counts[1, 3] = 1.0
    gp.fit_step(counts)
    assert gp.acc <= 0.5


# ---------------------------------------------------------------------------
# EDF ordering of predicted transfers
# ---------------------------------------------------------------------------

def test_predicted_drain_order_is_edf():
    """Earliest consuming layer first (the deadline), higher score first
    within a layer, expert index as the deterministic tiebreak."""
    pairs = [(2, 1), (0, 5), (1, 2), (0, 3), (1, 7)]
    scores = [0.9, 0.2, 0.8, 0.7, 0.8]
    order = paging.predicted_drain_order(pairs, scores)
    assert [pairs[i] for i in order] == [
        (0, 3), (0, 5), (1, 2), (1, 7), (2, 1)]


# ---------------------------------------------------------------------------
# Replication: pinning, budget, hysteresis
# ---------------------------------------------------------------------------

def _hot(E, *idx):
    m = np.zeros((1, E), bool)
    for i in idx:
        m[0, i] = True
    return m


def test_replicas_pin_top_experts_and_survive_pressure():
    r = residency.ExpertResidency(1, 8, capacity=4, span_bytes=8,
                                  replicate_frac=0.5, replica_warmup=0)
    assert r.replica_budget == 2
    for _ in range(10):
        r.begin_chunk()
        r.observe(_hot(8, 0, 1))
        r.update_replicas()
    assert {int(p) for p in r.replicas} == {0, 1}
    assert r.is_resident(0, 0) and r.is_resident(0, 1)
    # admission pressure fills the rest of the pool and then tries to
    # evict — replicas must never be the victim
    for e in (2, 3, 4, 5):
        r.admit(0, e, demand=True)
    assert r.is_resident(0, 0) and r.is_resident(0, 1)
    assert {int(p) for p in r.replicas} == {0, 1}


def test_replica_hysteresis_demotes_cooled_expert():
    """A replica whose popularity falls below replica_exit × the entry
    threshold loses its pin (stays resident, demand-evictable) and the
    newly-hot expert takes the slot."""
    r = residency.ExpertResidency(1, 8, capacity=4, span_bytes=8,
                                  replicate_frac=0.5, replica_warmup=0,
                                  replica_exit=0.5)
    for _ in range(10):
        r.begin_chunk()
        r.observe(_hot(8, 0, 1))
        r.update_replicas()
    assert {int(p) for p in r.replicas} == {0, 1}
    # expert 1 cools, expert 2 heats: hysteresis swaps the pin
    for _ in range(40):
        r.begin_chunk()
        r.observe(_hot(8, 0, 2))
        r.update_replicas()
    assert {int(p) for p in r.replicas} == {0, 2}


def test_replica_warmup_defers_pinning():
    r = residency.ExpertResidency(1, 8, capacity=4, span_bytes=8,
                                  replicate_frac=0.5, replica_warmup=5)
    for _ in range(3):
        r.begin_chunk()
        r.observe(_hot(8, 0, 1))
        assert r.update_replicas() == [] and not r.replicas
    for _ in range(4):
        r.begin_chunk()
        r.observe(_hot(8, 0, 1))
        r.update_replicas()
    assert {int(p) for p in r.replicas} == {0, 1}


# ---------------------------------------------------------------------------
# Engine-level: predicted prefetch dedupes against router-ahead
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixtral_setup():
    import jax
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    return cfg, init_params(cfg, jax.random.key(1))


def test_predicted_dedupe_never_double_fetches(mixtral_setup, monkeypatch):
    """The predicted queue shares _pending with router-ahead: at every
    drain the pending queue must hold each (weights, layer, expert) span
    at most once, and the cause-split counters must partition the
    hits."""
    from repro.serving import engine as E
    cfg, params = mixtral_setup
    orig = E.Engine._drain_prefetch

    def spy(self, gid, *, retry_refused):
        pend = [(key, l, e) for key, l, e, _, _ in self._pending]
        assert len(pend) == len(set(pend)), "span double-queued"
        return orig(self, gid, retry_refused=retry_refused)

    monkeypatch.setattr(E.Engine, "_drain_prefetch", spy)
    # skewed two-template workload: aligned enough that the predictor
    # scores well, divergent enough that predicted spans are sometimes
    # non-resident (a fully aligned stream leaves nothing to prefetch)
    rng = np.random.default_rng(7)
    temps = [rng.integers(2, cfg.vocab_size, 6) for _ in range(2)]
    eng = E.Engine(cfg, params,
                   E.EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                  decode_chunk=8, page_elems=4096,
                                  expert_paged=True, w_gpu_ratio=0.25,
                                  replicate_frac=0.5))
    for _ in range(16):
        t = (temps[0] if rng.random() < 0.95
             else temps[int(rng.integers(0, 2))])
        eng.submit(t, 16)
    eng.run_until_idle()
    t = eng.weight_traffic()
    # the predicted path actually ran and the split partitions the hits
    assert t["predicted_prefetches"] > 0
    assert (t["demand_hits"] + t["router_hits"] + t["predicted_hits"]
            + t["replicated_hits"] == t["hits"])
    assert 0.0 <= t["prefetch_accuracy"] <= 1.0
    assert t["predictor_accuracy"] > 0.0

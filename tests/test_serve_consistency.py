"""Decode-with-cache must reproduce teacher-forced full-forward logits —
the core serving-correctness invariant, checked across families (GQA,
windowed+softcap, MLA+MoE, SSM, hybrid, enc-dec)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, unembed
from repro.models import kvcache
from repro.models.params import init_params

pytestmark = pytest.mark.slow      # all-family sweep, multi-minute

FAMS = ["qwen2.5-3b", "gemma2-2b", "deepseek-v3-671b", "mamba2-1.3b",
        "jamba-1.5-large-398b", "whisper-small"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_teacher_forcing(arch, rng):
    cfg = dataclasses.replace(get_config(arch).smoke(), dtype="float32")
    params = init_params(cfg, jax.random.key(1))
    B, S, n_dec = 2, 12, 4
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, S + n_dec)),
                       jnp.int32)
    extras = {}
    if cfg.encoder_layers:
        extras["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.vision_tokens:
        extras["patches"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)

    # teacher-forced full forward over S+n_dec tokens
    full = forward(cfg, params, toks, mode="train", **extras)
    full_logits = unembed(cfg, params, full["hidden"])

    # prefill S, then decode the remaining n_dec one by one
    cache = kvcache.init_cache(cfg, B, S + n_dec + 2, dtype=jnp.float32)
    out = forward(cfg, params, toks[:, :S], cache=cache, mode="prefill",
                  **extras)
    cache = out["cache"]
    pre_logits = unembed(cfg, params, out["hidden"][:, -1])
    np.testing.assert_allclose(pre_logits, full_logits[:, S - 1],
                               rtol=2e-3, atol=2e-3)
    for t in range(n_dec):
        out = forward(cfg, params, toks[:, S + t:S + t + 1], cache=cache,
                      mode="decode")
        cache = out["cache"]
        logits = unembed(cfg, params, out["hidden"][:, -1])
        np.testing.assert_allclose(
            logits, full_logits[:, S + t], rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode step {t}")


def test_int8_kv_decode_within_quant_tolerance(rng):
    """int8 KV cache (per-token-per-head scales): decode logits must track
    teacher forcing within the quantization error budget."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32", kv_dtype="int8")
    params = init_params(cfg, jax.random.key(1))
    B, S, nd = 2, 12, 3
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, S + nd)),
                       jnp.int32)
    full = unembed(cfg, params,
                   forward(cfg, params, toks, mode="train")["hidden"])
    cache = kvcache.init_cache(cfg, B, S + nd + 1, dtype=jnp.float32)
    out = forward(cfg, params, toks[:, :S], cache=cache, mode="prefill")
    cache = out["cache"]
    for t in range(nd):
        out = forward(cfg, params, toks[:, S + t:S + t + 1], cache=cache,
                      mode="decode")
        cache = out["cache"]
        lg = unembed(cfg, params, out["hidden"][:, -1])
        rel = (float(jnp.max(jnp.abs(lg - full[:, S + t])))
               / float(jnp.max(jnp.abs(full[:, S + t]))))
        assert rel < 0.05, (t, rel)


def test_window_ring_overflow_consistency(rng):
    """gemma2 window layers: a cache narrower than the sequence must still
    reproduce teacher forcing (ring overwrite correctness)."""
    cfg = dataclasses.replace(get_config("gemma2-2b").smoke(),
                              dtype="float32", window_size=8)
    params = init_params(cfg, jax.random.key(2))
    B, S, n_dec = 1, 20, 3
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, S + n_dec)),
                       jnp.int32)
    full_logits = unembed(cfg, params,
                          forward(cfg, params, toks, mode="train")["hidden"])
    cache = kvcache.init_cache(cfg, B, S + n_dec + 1, dtype=jnp.float32)
    out = forward(cfg, params, toks[:, :S], cache=cache, mode="prefill")
    cache = out["cache"]
    for t in range(n_dec):
        out = forward(cfg, params, toks[:, S + t:S + t + 1], cache=cache,
                      mode="decode")
        cache = out["cache"]
        logits = unembed(cfg, params, out["hidden"][:, -1])
        np.testing.assert_allclose(logits, full_logits[:, S + t],
                                   rtol=3e-3, atol=3e-3)

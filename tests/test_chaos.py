"""Chaos verification for the fault-injected offload plane (DESIGN.md
§10's acceptance gate).  North-star invariant: an injected fault
schedule may cost throughput — retries, stalls, degradation rungs —
but must NEVER change tokens.  Every schedule here sheds no request
(priority-0 workload), so greedy transcripts must stay bit-identical
to the fault-free run across kv-paged × expert-paged × module-batch ×
overlap serving modes.

The fuzzer is hypothesis-driven when hypothesis is installed (CI);
the bare container runs the same property over seeded schedules, so
tier-1 always exercises it.  benchmarks/bench_faults.py reports the
same sweep as BENCH_faults.json."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.runtime.faults import FaultEvent, FaultPlan, LADDER_LEVELS

# fault sites wired into the engine (tested below to stay in sync)
SITES = ("kv_spill", "kv_fetch", "kv_pool", "expert_copy", "plan_drain",
         "host_alloc", "dispatch")

MODES = {
    "plain": {},
    "kv_paged": dict(kv_paged=True, kv_gpu_ratio=0.25, kv_prefetch=True),
    "expert_paged": dict(expert_paged=True, w_gpu_ratio=0.5, prefetch=True,
                         predict=True),
    "expert_module_kv": dict(expert_paged=True, w_gpu_ratio=0.5,
                             prefetch=True, predict=True, module_batch=True,
                             kv_paged=True, kv_gpu_ratio=0.25,
                             kv_prefetch=True),
    "overlap_kv": dict(overlap=True, prefill_chunk=16, kv_paged=True,
                       kv_gpu_ratio=0.25),
}


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    return cfg, init_params(cfg, jax.random.key(1))


def _work(cfg, seed=0, n=8):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 20))),
             4 if i % 2 == 0 else 12) for i in range(n)]


def _serve(cfg, params, work, **kw):
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4, **kw))
    for p, q in work:
        eng.submit(p, q)                       # priority 0: nothing shed
    return eng, eng.run_until_idle()


def _schedule(seed):
    """One seeded chaos schedule: probabilistic faults over every site
    plus a scripted burst drawn from the seed (so every run sees at
    least one concentrated fault window, not just scattered draws)."""
    rng = np.random.default_rng(seed)
    site = SITES[int(rng.integers(0, len(SITES)))]
    kind = ("fail", "stall", "partial", "exhaust")[int(rng.integers(0, 4))]
    return FaultPlan(
        seed=seed,
        probs={"*": {"fail": 0.06, "stall": 0.04, "partial": 0.04,
                     "exhaust": 0.03, "hostmem": 0.01}},
        trace=[FaultEvent(site, kind, after=int(rng.integers(0, 10)),
                          count=int(rng.integers(1, 6)))],
        stall_ms=float(rng.integers(50, 5000)),
        max_faults=int(rng.integers(40, 200)))


def _check_chaos(cfg, params, mode_kw, seed, baseline, work):
    eng, out = _serve(cfg, params, work, fault_plan=_schedule(seed),
                      degrade_down_after=2, degrade_up_after=5, **mode_kw)
    assert out == baseline, f"tokens changed under fault seed {seed}"
    ft = eng.fault_traffic()
    assert ft["injected_total"] > 0, "schedule injected nothing"
    assert ft["retries"] + ft["stalls"] + ft["injected_total"] > 0
    if eng._kv is not None:
        eng._kv.check_invariants()
    for r in eng.residency.values():
        # shrink/replica bookkeeping stayed coherent under faults
        assert r.occupancy() <= r.capacity
    return ft


# ---------------------------------------------------------------------------
# Fast tier-1 subset: every mode, a couple of seeds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", list(MODES))
def test_chaos_transcripts_stable_fast(setup, mode):
    cfg, params = setup
    work = _work(cfg)
    _, baseline = _serve(cfg, params, work, **MODES[mode])
    for seed in (0, 1):
        _check_chaos(cfg, params, MODES[mode], seed, baseline, work)


# ---------------------------------------------------------------------------
# Ladder descent + full recovery, end to end
# ---------------------------------------------------------------------------

def test_ladder_full_round_trip_under_burst(setup):
    """A sustained failure burst walks the ladder to its bottom rung;
    a second fault-free wave of work walks it all the way back to
    healthy.  Every step-down has a tested re-promotion, the engine's
    degraded-mode flags all revert, and tokens never change — the
    degraded second wave matches a fresh healthy engine bit-for-bit
    (priority-0 work is never shed even at admission_shed)."""
    cfg, params = setup
    kw = dict(MODES["expert_module_kv"], watchdog=False)
    work = _work(cfg, n=10)
    _, baseline = _serve(cfg, params, work, **kw)
    # p=0.9 expert-copy failures until the budget runs dry: the fault
    # streak outlives many safe points, so the descent is enacted
    plan = FaultPlan(seed=0, probs={"expert_copy": 0.9}, max_faults=150)
    eng, out = _serve(cfg, params, work, fault_plan=plan,
                      degrade_down_after=1, degrade_up_after=8, **kw)
    assert out == baseline
    ft = eng.fault_traffic()
    downs = [e for e in ft["degradation_events"] if e["direction"] == "down"]
    assert {e["to"] for e in downs} == set(LADDER_LEVELS[1:]), \
        "burst never reached the bottom rung"
    assert ft["retries"] > 0 and ft["injected_total"] > 0
    assert ft["shed_requests"] == 0          # priority-0: nothing shed
    # second wave, fault budget exhausted: abundant healthy ops walk
    # the ladder back while serving — and still match a fresh engine
    work2 = _work(cfg, seed=5, n=8)
    _, base2 = _serve(cfg, params, work2, **kw)
    rids = [eng.submit(p, q) for p, q in work2]
    out2 = eng.run_until_idle()
    assert [out2[r] for r in rids] == [base2[r] for r in sorted(base2)]
    ft = eng.fault_traffic()
    ups = [e for e in ft["degradation_events"] if e["direction"] == "up"]
    downs = [e for e in ft["degradation_events"] if e["direction"] == "down"]
    assert len(downs) == len(ups), "a step-down never re-promoted"
    assert ft["level_name"] == "healthy"
    # degraded-mode side effects all reverted
    assert eng._mg == eng._mg_base
    assert not eng._degraded_no_predict
    assert eng.scheduler.shed_priority is None
    for r in eng.residency.values():
        assert r.limit is None


def test_admission_shed_drops_only_sheddable_work(setup):
    """With the ladder pinned at admission_shed, priority-1 submissions
    are rejected at admission while priority-0 transcripts match the
    healthy run of the same priority-0 subset."""
    cfg, params = setup
    kw = MODES["kv_paged"]
    work = _work(cfg, n=6)
    _, baseline = _serve(cfg, params, work, **kw)
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4, **kw))
    eng._ladder.force_at_least("admission_shed", site="test")
    rids0 = [eng.submit(p, q) for p, q in work]
    rids1 = [eng.submit(p, q, priority=1) for p, q in work[:3]]
    out = eng.run_until_idle()
    assert {rid: out[rid] for rid in rids0} == baseline
    for rid in rids1:
        r = eng.scheduler.requests[rid]
        assert r.shed and r.generated == []
    assert eng.fault_traffic()["shed_requests"] == len(rids1)


# ---------------------------------------------------------------------------
# The fuzzer: hypothesis-driven when available, seeded sweep otherwise
# ---------------------------------------------------------------------------

_FUZZ_MODES = ("kv_paged", "expert_module_kv")


def _fuzz_one(setup, mode, seed):
    cfg, params = setup
    work = _work(cfg, seed=1 + seed % 3)
    _, baseline = _serve(cfg, params, work, **MODES[mode])
    _check_chaos(cfg, params, MODES[mode], seed, baseline, work)


if HAS_HYPOTHESIS:
    @pytest.mark.slow
    @given(mode=st.sampled_from(_FUZZ_MODES), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_chaos_fuzz(setup, mode, seed):
        _fuzz_one(setup, mode, seed)
else:
    @pytest.mark.slow
    @pytest.mark.parametrize("mode", _FUZZ_MODES)
    def test_chaos_fuzz(setup, mode):
        for seed in range(2, 8):
            _fuzz_one(setup, mode, seed)

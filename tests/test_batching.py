"""Algorithm 2 (request batching) — property-based invariants."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.batching import Request, batch_requests

reqs = st.lists(
    st.tuples(st.integers(1, 500), st.integers(1, 64)), min_size=0,
    max_size=60).map(
        lambda xs: [Request(i, l, g) for i, (l, g) in enumerate(xs)])


@given(reqs, st.integers(1, 8), st.integers(1, 16), st.integers(1, 64),
       st.integers(64, 4096))
@settings(max_examples=100, deadline=None)
def test_algorithm2_invariants(requests, n_ub, ubs, gen_len, cache_size):
    mbs, aborted = batch_requests(requests, n_ub, ubs, gen_len, cache_size)
    placed = [r for mb in mbs for r in mb.requests]
    placed_ids = [r.rid for r in placed]
    aborted_ids = [r.rid for r in aborted]
    # conservation: every request placed exactly once or aborted
    assert sorted(placed_ids + aborted_ids) == sorted(r.rid for r in requests)
    assert len(set(placed_ids)) == len(placed_ids)
    for mb in mbs:
        # micro-batch size cap
        assert len(mb) <= ubs
        # cache budget: tokens + reserved generation per request
        assert mb.tokens + len(mb) * gen_len <= cache_size \
            or len(mb.requests) == 1  # single oversized requests abort instead
    # a request only aborts if it genuinely couldn't fit an empty partition
    for r in aborted:
        assert r.input_len + gen_len > cache_size or len(mbs) >= 1


@given(reqs)
@settings(max_examples=50, deadline=None)
def test_algorithm2_balance(requests):
    """Longest-first into least-loaded: unsealed partitions' token counts
    differ by at most the largest single request."""
    if not requests:
        return
    mbs, _ = batch_requests(requests, 4, 1000, 1, 10 ** 9)
    sums = sorted(mb.tokens for mb in mbs)
    if len(sums) >= 2 and sums[0] > 0:
        longest = max(r.input_len for r in requests)
        assert sums[-1] - sums[0] <= longest

"""Serving step functions: the ragged-prompt prefill regression
(make_prefill_fill_step must take logits at each row's true final
position, not the padded bucket tail) and the chunked-prefill step
(incremental KV fill at a row offset must reproduce the monolithic
prefill — logits, cache contents, and greedy continuations)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import kvcache
from repro.models.params import init_params
from repro.serving import steps as serve_steps


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(5))
    return cfg, params


# ------------------------------------------------- ragged prefill fix

def test_prefill_fill_step_uses_true_lengths(setup):
    """Regression: a batch of ragged prompts padded to one bucket width
    must yield, per row, the same logits as that prompt prefilled alone
    at its exact length (hidden[:, -1] read the zero-pad tail instead)."""
    cfg, params = setup
    step = jax.jit(serve_steps.make_prefill_fill_step(cfg))
    rng = np.random.default_rng(0)
    lens = [3, 9, 6]
    S = 16
    toks = np.zeros((len(lens), S), np.int32)
    prompts = []
    for i, n in enumerate(lens):
        p = rng.integers(2, cfg.vocab_size, n)
        prompts.append(p)
        toks[i, :n] = p
    cache = kvcache.init_cache(cfg, len(lens), 32)
    logits, cache = step(params, jnp.asarray(toks), cache,
                         jnp.asarray(lens, np.int32))
    np.testing.assert_array_equal(np.asarray(cache["pos"]), lens)
    for i, (p, n) in enumerate(zip(prompts, lens)):
        solo_cache = kvcache.init_cache(cfg, 1, 32)
        solo_logits, _ = step(params, jnp.asarray(p[None, :]), solo_cache,
                              jnp.asarray([n], np.int32))
        np.testing.assert_allclose(np.asarray(logits[i]),
                                   np.asarray(solo_logits[0]),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------- chunked prefill

def _chunked_prefill(cfg, params, prompt, max_seq, widths):
    """Drain `prompt` through chunks of the given widths (padded to each
    width); returns (final-position logits, cache)."""
    chunk_fns = {w: jax.jit(serve_steps.make_prefill_chunk(cfg))
                 for w in set(widths)}
    cache = kvcache.init_cache(cfg, 1, max_seq)
    t = 0
    logits = None
    for w in widths:
        if t == len(prompt):
            break
        n = min(w, len(prompt) - t)
        toks = np.zeros((1, w), np.int32)
        toks[0, :n] = prompt[t:t + n]
        logits, cache = chunk_fns[w](params, jnp.asarray(toks), cache,
                                     jnp.asarray([n], np.int32))
        t += n
    assert t == len(prompt)
    return logits, cache


@pytest.mark.parametrize("widths", [(4, 4, 4, 4), (8, 8), (8, 4, 4),
                                    (16,), (8, 8, 2)])
def test_chunked_prefill_matches_monolithic(setup, widths):
    """Any chunking of the prompt — including a padded final chunk —
    must agree with the monolithic prefill on final-position logits (the
    next sampled token) and leave an equivalent ring cache behind."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    n = min(sum(widths), 14)                      # ragged vs last width
    prompt = rng.integers(2, cfg.vocab_size, n)
    full = jax.jit(serve_steps.make_prefill_fill_step(cfg))
    ref_logits, ref_cache = full(params, jnp.asarray(prompt[None, :]),
                                 kvcache.init_cache(cfg, 1, 32),
                                 jnp.asarray([n], np.int32))
    logits, cache = _chunked_prefill(cfg, params, prompt, 32, widths)
    assert int(jnp.argmax(logits[0])) == int(jnp.argmax(ref_logits[0]))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
    assert int(cache["pos"][0]) == n
    # ring contents agree on every slot holding a true prompt position
    sp = np.asarray(cache["p0"]["slot_pos"][0, 0])
    ref_sp = np.asarray(ref_cache["p0"]["slot_pos"][0, 0])
    real = (ref_sp >= 0) & (ref_sp < n)
    np.testing.assert_array_equal(sp[real], ref_sp[real])
    np.testing.assert_allclose(
        np.asarray(cache["p0"]["k"][0, 0][real]),
        np.asarray(ref_cache["p0"]["k"][0, 0][real]), rtol=2e-4, atol=2e-4)


def test_chunked_prefill_pad_tail_stays_masked(setup):
    """Padded chunk-tail positions are clamped to one-past-the-end: they
    must never overwrite a true prompt slot nor mark a slot as holding a
    causally-visible position."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(2, cfg.vocab_size, 5)
    _, cache = _chunked_prefill(cfg, params, prompt, 32, (8,))
    sp = np.asarray(cache["p0"]["slot_pos"][0, 0])
    # slots 0..4 hold the prompt; slot 5 holds the clamped pad writes
    np.testing.assert_array_equal(sp[:5], np.arange(5))
    assert sp[5] == 5                 # > final pos 4: causally masked
    assert (sp[6:] == -1).all()


# ------------------------------------------------- partial slot insert

def test_insert_slot_span_writes_only_offset_range(qwen_f32):
    cfg = qwen_f32
    pool = kvcache.init_cache(cfg, 3, 16)
    single = kvcache.init_cache(cfg, 1, 16)
    single["pos"] = jnp.asarray([12], jnp.int32)
    single["p0"] = jax.tree.map(lambda a: a + 2, single["p0"])
    out = kvcache.insert_slot_span(pool, single, 1, 4, length=8)
    for name in ("k", "v", "slot_pos"):
        # target row: ring slots [4, 12) copied, the rest untouched
        np.testing.assert_array_equal(
            np.asarray(out["p0"][name][:, 1, 4:12]),
            np.asarray(single["p0"][name][:, 0, 4:12]))
        np.testing.assert_array_equal(
            np.asarray(out["p0"][name][:, 1, :4]),
            np.asarray(pool["p0"][name][:, 1, :4]))
        np.testing.assert_array_equal(
            np.asarray(out["p0"][name][:, 1, 12:]),
            np.asarray(pool["p0"][name][:, 1, 12:]))
        # neighbors untouched
        for row in (0, 2):
            np.testing.assert_array_equal(
                np.asarray(out["p0"][name][:, row]),
                np.asarray(pool["p0"][name][:, row]))
    assert int(out["pos"][1]) == 12


def test_insert_slot_span_wraps_ring(qwen_f32):
    """Span indices are taken modulo the ring width (sliding-window
    layers wrap mid-span)."""
    cfg = qwen_f32
    pool = kvcache.init_cache(cfg, 2, 8)
    single = kvcache.init_cache(cfg, 1, 8)
    single["p0"] = jax.tree.map(lambda a: a + 3, single["p0"])
    out = kvcache.insert_slot_span(pool, single, 0, 6, length=4)
    # positions 6,7,8,9 -> slots 6,7,0,1
    touched = [6, 7, 0, 1]
    untouched = [2, 3, 4, 5]
    np.testing.assert_array_equal(
        np.asarray(out["p0"]["k"][:, 0, touched]),
        np.asarray(single["p0"]["k"][:, 0, touched]))
    np.testing.assert_array_equal(
        np.asarray(out["p0"]["k"][:, 0, untouched]),
        np.asarray(pool["p0"]["k"][:, 0, untouched]))

"""Unit suite for the fault-injected offload plane (DESIGN.md §10):
FaultPlan determinism and scripted traces, TransferEngine retry /
backoff / abort / stall accounting, the Watchdog EWMA fix (deadline
updates on every step, including before an abort-policy raise), the
DegradationLadder state machine with hysteresis, and scheduler
SLO-shedding semantics.  The end-to-end chaos fuzz lives in
tests/test_chaos.py."""
import numpy as np
import pytest

from repro.runtime.faults import (FAULT_KINDS, LADDER_LEVELS,
                                  DegradationLadder, FaultEvent,
                                  FaultInjector, FaultPlan,
                                  HostMemoryError, OffloadFaultError,
                                  StallTimeout, TransientTransferError)
from repro.runtime.transfer import TransferEngine
from repro.runtime.watchdog import StragglerError, Watchdog


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def _draw_seq(plan, site, n=200):
    return [(ev.kind if ev else None) for ev in
            (plan.draw(site) for _ in range(n))]


def test_plan_deterministic_per_seed():
    """Same seed → identical draw sequence (the chaos fuzzer's premise);
    different seed → different sequence."""
    probs = {"*": {"fail": 0.1, "stall": 0.1, "exhaust": 0.05}}
    a = _draw_seq(FaultPlan(seed=3, probs=probs), "kv_fetch")
    b = _draw_seq(FaultPlan(seed=3, probs=probs), "kv_fetch")
    c = _draw_seq(FaultPlan(seed=4, probs=probs), "kv_fetch")
    assert a == b
    assert a != c
    assert any(k is not None for k in a)


def test_scripted_trace_window():
    """A scripted event fires exactly on ops [after, after+count) of its
    own site and nowhere else."""
    plan = FaultPlan(trace=[FaultEvent("kv_fetch", "fail", after=2,
                                       count=3)])
    kinds = _draw_seq(plan, "kv_fetch", n=8)
    assert kinds == [None, None, "fail", "fail", "fail", None, None, None]
    assert _draw_seq(plan, "kv_spill", n=8) == [None] * 8


def test_scripted_wins_over_probabilistic():
    plan = FaultPlan(seed=0, probs={"x": 1.0},
                     trace=[FaultEvent("x", "stall", after=0, count=1,
                                       stall_ms=99.0)])
    ev = plan.draw("x")
    assert ev.kind == "stall" and ev.stall_ms == 99.0


def test_max_faults_bounds_injections():
    plan = FaultPlan(seed=0, probs={"*": 1.0}, max_faults=5)
    kinds = _draw_seq(plan, "s", n=50)
    assert sum(k is not None for k in kinds) == 5
    assert plan.injected == 5


def test_per_site_probability_isolation():
    """A site-specific prob only fires at that site; '*' covers the
    rest."""
    plan = FaultPlan(seed=0, probs={"only_here": 1.0})
    assert all(k == "fail" for k in _draw_seq(plan, "only_here", 10))
    assert all(k is None for k in _draw_seq(plan, "elsewhere", 10))


def test_injector_counts_and_raise_for():
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("host_alloc", "hostmem", after=0, count=1),
               FaultEvent("host_alloc", "fail", after=1, count=1)]))
    with pytest.raises(HostMemoryError) as ei:
        inj.raise_for("host_alloc")
    assert ei.value.site == "host_alloc"
    with pytest.raises(HostMemoryError):       # probe site: every hard
        inj.raise_for("host_alloc")            # kind is an alloc failure
    inj.raise_for("host_alloc")                    # past the window: no-op
    assert inj.counts == {"host_alloc/hostmem": 1, "host_alloc/fail": 1}
    assert inj.total() == 2
    assert isinstance(ei.value, OffloadFaultError)


def test_unarmed_injector_is_noop():
    inj = FaultInjector()
    assert not inj.armed
    assert inj.fire("x") is None
    assert inj.stall_s("x") == 0.0
    inj.raise_for("x")
    assert inj.total() == 0


def test_fault_kinds_closed():
    with pytest.raises(AssertionError):
        FaultEvent("s", "meteor_strike")
    assert set(FAULT_KINDS) == {"fail", "stall", "partial", "hostmem",
                                "exhaust"}


# ---------------------------------------------------------------------------
# TransferEngine
# ---------------------------------------------------------------------------

def test_transfer_retries_then_succeeds():
    """N injected fails within budget cost N retries, zero aborts, and
    the op's side effect runs exactly once (injection fires before the
    closure, so a retried donated-buffer write never re-executes)."""
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("t", "fail", after=0, count=3)]))
    eng = TransferEngine(inj, max_retries=4)
    ran = []
    out = eng.run("t", lambda: ran.append(1) or "ok", nbytes=128)
    assert out == "ok" and ran == [1]
    assert eng.retries == 3 and eng.aborts == 0 and eng.ok_ops == 1
    assert eng.bytes_moved == 128


def test_transfer_abort_after_budget():
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("t", "fail", after=0, count=10)]))
    eng = TransferEngine(inj, max_retries=2)
    with pytest.raises(TransientTransferError):
        eng.run("t", lambda: "never")
    assert eng.retries == 2 and eng.aborts == 1 and eng.ok_ops == 0


def test_run_mandatory_survives_exhausted_cycles():
    """A mandatory op outlives its retry budget: exhausted cycles book
    aborts but the op still lands once the burst passes."""
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("t", "fail", after=0, count=7)]))
    eng = TransferEngine(inj, max_retries=2)
    assert eng.run_mandatory("t", lambda: "landed") == "landed"
    assert eng.retries + eng.aborts * 0 >= 1
    assert eng.aborts >= 1 and eng.ok_ops == 1


def test_run_mandatory_hostmem_hook_then_reissue():
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("t", "hostmem", after=0, count=1)]))
    eng = TransferEngine(inj)
    demoted = []
    out = eng.run_mandatory("t", lambda: "ok",
                            on_hostmem=lambda: demoted.append(1))
    assert out == "ok" and demoted == [1]
    assert eng.hostmem_faults == 1


def test_hostmem_without_hook_propagates():
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("t", "hostmem", after=0, count=1)]))
    eng = TransferEngine(inj)
    with pytest.raises(HostMemoryError):
        eng.run_mandatory("t", lambda: "ok")


def test_injected_stall_books_and_aborts_by_policy():
    """A virtual stall far beyond the EWMA deadline books a stall (log
    policy) or raises StallTimeout (abort policy) — deterministically,
    with no real sleeping."""
    def mk(policy):
        inj = FaultInjector(FaultPlan(
            trace=[FaultEvent("t", "stall", after=3, count=1,
                              stall_ms=60_000.0)]))
        return TransferEngine(inj, min_deadline_s=1e-4,
                              deadline_factor=2.0, stall_policy=policy)
    eng = mk("log")
    for _ in range(4):
        eng.run("t", lambda: None)
    assert eng.stalls == 1 and eng.ok_ops == 4
    eng = mk("abort")
    for _ in range(3):
        eng.run("t", lambda: None)
    with pytest.raises(StallTimeout):
        eng.run("t", lambda: None)


def test_transfer_feeds_ladder():
    ladder = DegradationLadder(down_after=2, up_after=3)
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("t", "fail", after=0, count=2)]))
    eng = TransferEngine(inj, max_retries=4, ladder=ladder)
    eng.run("t", lambda: None)
    assert ladder.pending() and ladder.target == 1


def test_stats_shape():
    eng = TransferEngine()
    eng.run("a", lambda: None, nbytes=10)
    s = eng.stats()
    assert s["ok_ops"] == 1 and s["bytes_moved"] == 10
    assert "a" in s["deadline_s"]


# ---------------------------------------------------------------------------
# Watchdog (satellite: EWMA must update on EVERY step)
# ---------------------------------------------------------------------------

def test_watchdog_ewma_updates_every_step():
    """Regression: the EWMA used to seed only on the first step and then
    never move; observe() must fold every in-deadline sample in."""
    wd = Watchdog(deadline_factor=10.0, min_deadline_s=0.0)
    wd.observe(1.0)
    assert wd.ewma == pytest.approx(1.0)
    wd.observe(2.0)
    assert wd.ewma > 1.0                     # moved — not frozen at the seed
    assert wd.steps_seen == 2


def test_watchdog_zero_first_step_does_not_reseed():
    """Seeding is by step count, not by value: a 0.0-duration first step
    must not leave the EWMA permanently re-seedable."""
    wd = Watchdog(deadline_factor=10.0, min_deadline_s=1.0)
    wd.observe(0.0)
    wd.observe(5.0)
    e1 = wd.ewma
    assert e1 > 0.0
    wd.observe(5.0)
    assert wd.ewma > e1


def test_watchdog_updates_before_abort_raise():
    """The violating sample (deadline-clipped) must reach the EWMA even
    when the abort policy raises — one straggler neither poisons nor
    freezes the estimate."""
    wd = Watchdog(deadline_factor=2.0, min_deadline_s=0.0, policy="abort")
    wd.observe(1.0)
    before = wd.ewma
    with pytest.raises(StragglerError):
        wd.observe(100.0)
    assert wd.steps_seen == 2 and wd.slow_steps == 1
    assert before < wd.ewma <= before + wd.alpha * 2.0 * before + 1e-9


def test_watchdog_step_end_virtual_seconds():
    wd = Watchdog(deadline_factor=1.5, min_deadline_s=1e-4)
    wd.step_start()
    assert wd.step_end()                           # real dt ~ 0: fine
    wd.step_start()
    assert not wd.step_end(extra_s=10.0)           # injected stall violates
    assert wd.slow_steps == 1


# ---------------------------------------------------------------------------
# DegradationLadder
# ---------------------------------------------------------------------------

def test_ladder_down_after_threshold_and_one_rung_per_apply_loop():
    lad = DegradationLadder(down_after=3, up_after=5)
    for _ in range(2):
        lad.note_fault("kv_fetch")
    assert not lad.pending()
    lad.note_fault("kv_fetch")
    assert lad.pending() and lad.target == 1
    steps = []
    evs = lad.apply(lambda o, n, d: steps.append((o, n, d)), tick=7)
    assert steps == [(0, 1, "down")]
    assert lad.level == 1 and lad.level_name == "pageable_host"
    assert evs[0]["reason"] == "kv_fetch" and evs[0]["tick"] == 7


def test_ladder_hysteresis_up_slower_than_down():
    lad = DegradationLadder(down_after=2, up_after=6)
    for _ in range(2):
        lad.note_fault("x")
    lad.apply()
    for _ in range(5):
        lad.note_ok()
    assert not lad.pending()                 # 5 < up_after: stays degraded
    lad.note_ok()
    assert lad.pending() and lad.target == 0
    lad.apply()
    assert lad.level == 0
    assert lad.demotions == 1 and lad.promotions == 1
    with pytest.raises(AssertionError):
        DegradationLadder(down_after=3, up_after=3)   # no hysteresis band


def test_ladder_ok_resets_fault_streak():
    lad = DegradationLadder(down_after=3, up_after=4)
    lad.note_fault("x")
    lad.note_fault("x")
    lad.note_ok()
    lad.note_fault("x")
    lad.note_fault("x")
    assert not lad.pending()                 # streak broken by the ok


def test_ladder_force_at_least_and_multi_rung_apply():
    lad = DegradationLadder(down_after=2, up_after=3)
    lad.force_at_least("lockstep", site="host_alloc")
    assert lad.target == LADDER_LEVELS.index("lockstep")
    crossings = []
    lad.apply(lambda o, n, d: crossings.append((LADDER_LEVELS[n], d)))
    assert crossings == [("pageable_host", "down"), ("no_predict", "down"),
                         ("lockstep", "down")]
    # force never promotes
    lad.force_at_least("pageable_host")
    assert not lad.pending()


def test_ladder_full_descent_and_recovery_events_pair_up():
    """Every rung stepped down has a matching re-promotion, and the
    event log records the whole round trip in order."""
    lad = DegradationLadder(down_after=1, up_after=2)
    for _ in range(len(LADDER_LEVELS) + 3):     # clamped at the bottom
        lad.note_fault("s")
    lad.apply(tick=1)
    assert lad.level == len(LADDER_LEVELS) - 1
    assert lad.level_name == "admission_shed"
    for _ in range(2 * len(LADDER_LEVELS)):
        lad.note_ok()
        lad.apply(tick=2)
    assert lad.level == 0 and lad.level_name == "healthy"
    downs = [e for e in lad.events if e["direction"] == "down"]
    ups = [e for e in lad.events if e["direction"] == "up"]
    assert len(downs) == len(ups) == len(LADDER_LEVELS) - 1
    assert [e["to"] for e in downs] == list(LADDER_LEVELS[1:])
    assert [e["to"] for e in ups] == list(reversed(LADDER_LEVELS[:-1]))
    assert [e["seq"] for e in lad.events] == list(range(len(lad.events)))


def test_ladder_max_level_clamp():
    lad = DegradationLadder(down_after=1, up_after=2, max_level=2)
    for _ in range(50):
        lad.note_fault("s")
    lad.apply()
    assert lad.level == 2
    lad.force_at_least("admission_shed")
    lad.apply()
    assert lad.level == 2


# ---------------------------------------------------------------------------
# Scheduler SLO-shedding
# ---------------------------------------------------------------------------

def _sched(**kw):
    from repro.serving.scheduler import Scheduler
    return Scheduler(ubatch=2, num_ubs=2, cache_tokens=512, gen_len=8,
                     max_input_len=64, **kw)


def test_shed_disabled_by_default():
    s = _sched()
    rid = s.submit(np.arange(4), 4, priority=5)
    assert not s.requests[rid].shed and s.queue


def test_shed_priority_threshold_at_submit():
    s = _sched()
    s.shed_priority = 1
    keep = s.submit(np.arange(4), 4, priority=0)
    drop = s.submit(np.arange(4), 4, priority=1)
    assert not s.requests[keep].shed
    r = s.requests[drop]
    assert r.shed and r.aborted and r.done and not r.generated
    assert s.shed_count == 1
    assert [q.rid for q in s.queue] == [keep]


def test_shed_queued_but_never_preempted_requests():
    """Turning shedding on shed-ls queued NEW work at admission, but a
    preempted request (partial transcript) is never shed — its tokens
    must survive."""
    s = _sched()
    a = s.submit(np.arange(4), 6, priority=1)
    b = s.submit(np.arange(4), 6, priority=1)
    slots = s.admit_to_slots()
    assert [sl.req.rid for sl in slots] == [a, b]
    for sl in slots:
        s.start_decode(sl)
    s.requests[a].generated.extend([7, 8])         # a has output
    s.preempt(next(sl for sl in slots if sl.req.rid == a))
    c = s.submit(np.arange(4), 6, priority=1)      # queued, no output
    s.shed_priority = 1
    admitted = s.admit_to_slots()
    assert [sl.req.rid for sl in admitted] == [a]  # re-admitted, not shed
    assert s.requests[a].generated == [7, 8]
    assert s.requests[c].shed and not s.requests[a].shed
    assert s.shed_count == 1


def test_shed_static_admit_path():
    s = _sched()
    s.shed_priority = 2
    s.submit(np.arange(4), 4, priority=0)
    s.submit(np.arange(4), 4, priority=3)
    mbs = s.admit()
    admitted = {r.rid for mb in mbs for r in mb}
    assert admitted == {0}
    assert s.requests[1].shed and s.shed_count == 1

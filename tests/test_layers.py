"""Layer-level numerics: chunked attention vs O(S^2) oracle, RoPE
properties, window masking, softcap, MLA absorbed-vs-naive equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ATTN_MLA, LayerSpec
from repro.models.attention import (attention_partials, combine_partials,
                                    decode_valid_mask, mla_forward)
from repro.models.common import (apply_rope, attention_reference,
                                 chunked_attention, rmsnorm, softcap)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("cap", [0.0, 20.0])
def test_chunked_attention_matches_reference(rng, window, cap):
    B, S, Hq, Hkv, D = 2, 50, 8, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, window=window,
                          attn_softcap=cap, chunk=16)
    b = attention_reference(q, k, v, causal=True, window=window,
                            attn_softcap=cap)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_chunked_attention_kv_len_mask(rng):
    B, S, H, D = 2, 32, 4, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    lens = jnp.asarray([10, 32])
    a = chunked_attention(q, k, v, causal=True, kv_len=lens, chunk=8)
    # row 0 must equal attention over only the first 10 kv positions
    b = attention_reference(q[:1, :10], k[:1, :10], v[:1, :10], causal=True)
    np.testing.assert_allclose(a[0, :10], b[0], rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relativity(rng):
    B, S, H, D = 1, 8, 2, 16
    x = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, D)), jnp.float32)

    def dot_at(p, d):
        qr = apply_rope(q, jnp.full((1, 1), p), 1e4)
        kr = apply_rope(k, jnp.full((1, 1), p + d), 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(3, 5) - dot_at(11, 5)) < 1e-3


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(softcap(x, 0.0), x)
    # near-linear for small inputs
    np.testing.assert_allclose(softcap(jnp.asarray([0.1]), 30.0),
                               jnp.asarray([0.1]), rtol=1e-3)


def test_decode_partials_match_full_softmax(rng):
    B, H, Hkv, D, W = 3, 8, 4, 16, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    valid = jnp.asarray(rng.random((B, W)) > 0.5)
    o = combine_partials(*attention_partials(q, k, v, valid, scale=D ** -0.5))
    ref = attention_reference(q[:, None], k, v, causal=False,
                              kv_len=None, scale=D ** -0.5)
    # manually mask via big matmul
    g = H // Hkv
    s = jnp.einsum("bhgd,bwhd->bhgw",
                   (q.reshape(B, Hkv, g, D) * D ** -0.5), k)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o2 = jnp.einsum("bhgw,bwhd->bhgd", p, v).reshape(B, H, D)
    np.testing.assert_allclose(o, o2, rtol=2e-5, atol=2e-5)


def test_decode_valid_mask_window():
    slot_pos = jnp.asarray([[5, 6, 7, -1]])
    pos = jnp.asarray([7])
    np.testing.assert_array_equal(
        decode_valid_mask(slot_pos, pos, 0)[0], [True, True, True, False])
    np.testing.assert_array_equal(
        decode_valid_mask(slot_pos, pos, 2)[0], [False, True, True, False])


@pytest.mark.slow
def test_mla_absorbed_decode_equals_naive_prefill(rng):
    """The absorbed decode path must produce the same output as the naive
    (decompressed) attention at the same position."""
    cfg = dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                              dtype="float32")
    from repro.models import kvcache
    from repro.models.params import init_params
    spec = LayerSpec(attn=ATTN_MLA)
    params = init_params(cfg, jax.random.key(0))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["p0"]["attn"])
    B, S = 2, 9
    x = jnp.asarray(rng.normal(0, 0.3, (B, S, cfg.d_model)), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    # naive full forward
    out_full, _ = mla_forward(cfg, spec, p, x, positions, cache=None,
                              mode="full")
    # prefill first S-1 then decode token S-1
    cache = kvcache._spec_cache(cfg, spec, 1, B, 16, jnp.float32)
    cache = jax.tree.map(lambda a: a[0], cache)
    _, cache = mla_forward(cfg, spec, p, x[:, :S - 1],
                           positions[:, :S - 1], cache=cache, mode="full")
    out_dec, _ = mla_forward(cfg, spec, p, x[:, S - 1:],
                             positions[:, S - 1:], cache=cache, mode="decode",
                             pos=jnp.full((B,), S - 1))
    np.testing.assert_allclose(out_dec[:, 0], out_full[:, -1],
                               rtol=2e-4, atol=2e-4)


def test_rmsnorm_gemma_offset(rng):
    x = jnp.asarray(rng.normal(0, 1, (2, 8)), jnp.float32)
    w = jnp.zeros((8,))
    # gemma convention: weight 0 with offset 1 == plain rms normalize
    y = rmsnorm(x, w, 1e-6, offset=1.0)
    np.testing.assert_allclose(
        jnp.mean(jnp.square(y), -1), jnp.ones(2), rtol=1e-4)

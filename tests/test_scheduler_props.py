"""Property-based scheduler tests: random submit/admit/drain/release
traces through the real Scheduler must uphold the slot-pool lifecycle
invariants — per-group KV budget (worst-case AND EOS-aware reservations),
no double-occupancy, FCFS admission, and abort-or-admit (no head-of-queue
livelock).  The trace driver and invariant checks live in
tests/scheduler_trace.py (shared with the deterministic seeded suite so
the machinery runs even where hypothesis is unavailable)."""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from scheduler_trace import run_trace  # noqa: E402


def _eos_draw_from(eos_salt: int, eos_mod: int):
    """Deterministic pure function of (rid, k): required by the driver,
    which consults it more than once per token."""
    def eos_draw(rid, k):
        if eos_mod == 0:
            return False
        return (rid * 2654435761 + k * 40503 + eos_salt) % eos_mod == 0
    return eos_draw


trace_params = dict(
    ubatch=st.integers(1, 3),
    num_ubs=st.integers(1, 3),
    cache_tokens=st.integers(8, 64),
    chunk=st.integers(1, 8),
    prefill_chunk=st.integers(1, 8),
    requests=st.lists(
        st.tuples(st.integers(1, 24), st.integers(1, 12)),
        min_size=1, max_size=24),
    arrival_gaps=st.lists(st.integers(0, 3), min_size=24, max_size=24),
    eos_salt=st.integers(0, 2**16),
    eos_mod=st.integers(0, 6),
)


def _run(reserve_mode, ubatch, num_ubs, cache_tokens, chunk, prefill_chunk,
         requests, arrival_gaps, eos_salt, eos_mod, **shed_kw):
    arrivals, t = [], 0
    for i in range(len(requests)):
        t += arrival_gaps[i]
        arrivals.append(t)
    return run_trace(
        ubatch=ubatch, num_ubs=num_ubs, cache_tokens=cache_tokens,
        reserve_mode=reserve_mode, requests=requests, arrivals=arrivals,
        chunk=chunk, prefill_chunk=prefill_chunk,
        eos_draw=_eos_draw_from(eos_salt, eos_mod), **shed_kw)


@settings(max_examples=150, deadline=None)
@given(**trace_params)
def test_worst_case_reservations_hold_invariants(**kw):
    """Worst-case mode: the budget bound, slot exclusivity, FCFS and
    drain-to-completion must hold on any trace — and no preemption may
    ever be needed (the driver asserts all of these per tick)."""
    _run("worst", **kw)


@settings(max_examples=150, deadline=None)
@given(**trace_params)
def test_ewma_reservations_hold_invariants(**kw):
    """EOS-aware mode: admission is optimistic, but enforce_budget +
    recompute preemption must keep the same invariants intact."""
    _run("ewma", **kw)


@settings(max_examples=75, deadline=None)
@given(**trace_params)
def test_ewma_never_serves_fewer_requests(**kw):
    """Preemption must only re-order work, never lose or duplicate it:
    both reservation modes serve exactly the same set of requests."""
    a = _run("worst", **kw)
    b = _run("ewma", **kw)
    assert sorted(a.served) == sorted(b.served)
    assert sorted(a.aborted) == sorted(b.aborted)


@settings(max_examples=100, deadline=None)
@given(priorities=st.lists(st.integers(0, 2), min_size=24, max_size=24),
       shed_a=st.integers(0, 12), shed_len=st.integers(0, 20),
       reserve_mode=st.sampled_from(["worst", "ewma"]),
       **trace_params)
def test_admission_shed_drops_only_sheddable_work(priorities, shed_a,
                                                  shed_len, reserve_mode,
                                                  **kw):
    """Degraded-mode shedding (the ladder's admission_shed rung) on any
    trace and any shed window: only NEW priority>=1 work is dropped,
    requests with transcripts (admitted, possibly preempted) and
    priority-0 work always survive, and the trace still drains with
    every rid accounted for — the per-tick driver asserts the rest."""
    res = _run(reserve_mode, priorities=priorities[:len(kw["requests"])],
               shed_window=(shed_a, shed_a + shed_len), shed_priority=1,
               **kw)
    assert not set(res.shed) & set(res.served)

"""Pinned-host offload probe + measured impl='auto' dispatch.

The CPU validation backend exposes only ``unpinned_host`` memory, so the
probe's fallback branch is the live path here (one structured
``HostOffloadFallbackWarning`` per process, then silence); the pinned
branch — engine host tier as jax arrays written through the
out_shardings-pinned ``_kv_host_write`` jit — is driven by
monkeypatching ``offload._make_pinned_sharding`` with a plain CPU
sharding, and must leave greedy transcripts bit-identical to the
pageable-numpy tier and the dense ring.  The measured crossover
(``benchmarks/bench_transfer.py`` → ``BENCH_transfer.json``) resolves
``paged_attn_impl='auto'`` at engine init: dense-ref off-TPU, paged
kernel on TPU when unmeasured, dense at/above the measured occupancy —
both sides of the threshold pinned here.  Finally the fused decode-write
acceptance: ``kvcache.write_decode_paged`` must not be a separate
dispatch on the paged decode hot path (trace-time spy)."""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hrm as H
from repro.core import offload
from repro.kernels import ops
from repro.models import kvcache
from repro.models.model import ExecPolicy
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig


def _plain_sharding():
    return jax.sharding.SingleDeviceSharding(jax.devices()[0])


@pytest.fixture
def fake_pinned(monkeypatch):
    """Backends-with-pinned-host world: the probe succeeds and the
    'pinned' sharding is a plain CPU sharding (placement is exercised,
    the memory space is simulated)."""
    monkeypatch.setattr(offload, "supports_host_offload", lambda: True)
    monkeypatch.setattr(offload, "_make_pinned_sharding", _plain_sharding)
    yield


@pytest.fixture
def no_pinned(monkeypatch):
    """Fallback world with the warn-once latch reset."""
    monkeypatch.setattr(offload, "supports_host_offload", lambda: False)
    monkeypatch.setattr(offload, "_warned_no_pinned", False)
    yield


# ---------------------------------------------------------------------------
# Probe: both branches, warn-once
# ---------------------------------------------------------------------------

def test_probe_fallback_warns_exactly_once(no_pinned):
    with pytest.warns(offload.HostOffloadFallbackWarning,
                      match="no pinned_host memory space"):
        assert offload.pinned_host_sharding() is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second probe must be silent
        assert offload.pinned_host_sharding() is None
        assert offload.pinned_host_sharding(warn=False) is None


def test_probe_warn_false_never_warns(no_pinned):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert offload.pinned_host_sharding(warn=False) is None
    assert not offload._warned_no_pinned     # latch untouched


def test_probe_pinned_branch(fake_pinned):
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # support => no warning
        s = offload.pinned_host_sharding()
    assert s is not None
    x = jnp.arange(8.0)
    y = offload.pinned_put(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(offload.to_device(y)),
                                  np.asarray(x))


def test_pinned_put_identity_without_support(no_pinned):
    x = jnp.arange(4.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert offload.pinned_put(x) is x


# ---------------------------------------------------------------------------
# Engine: jax pinned-host tier ≡ pageable-numpy tier ≡ dense ring
# ---------------------------------------------------------------------------

def _work(cfg, seed=0, n=4):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(1, 24))),
             int(rng.integers(1, 8))) for _ in range(n)]


def _run(cfg, params, work, policy=None, **kw):
    ecfg = dict(ubatch=2, num_ubs=2, max_seq=64, decode_chunk=4)
    ecfg.update(kw)
    eng = Engine(cfg, params, EngineConfig(**ecfg), policy=policy)
    for p, q in work:
        eng.submit(p, q)
    return eng, eng.run_until_idle()


def _smoke(arch="qwen2.5-3b"):
    cfg = dataclasses.replace(get_config(arch).smoke(), dtype="float32")
    return cfg, init_params(cfg, jax.random.key(3))


def test_engine_pinned_tier_transcripts_identical(fake_pinned):
    cfg, params = _smoke()
    work = _work(cfg)
    _, dense = _run(cfg, params, work)
    eng, paged = _run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25)
    assert eng._kv_pinned                    # the jax host-tier branch ran
    assert all(isinstance(a, jax.Array) for g in eng._kv_host.values()
               for a in g.values())
    t = eng.kv_traffic()
    assert t["d2h_bytes"] > 0 and t["h2d_bytes"] > 0   # spills + fetches
    assert paged == dense


def test_engine_fallback_tier_is_numpy():
    cfg, params = _smoke()
    eng, _ = _run(cfg, params, _work(cfg), kv_paged=True, kv_gpu_ratio=0.25)
    assert not eng._kv_pinned
    assert all(isinstance(a, np.ndarray) for g in eng._kv_host.values()
               for a in g.values())


# ---------------------------------------------------------------------------
# Measured crossover: impl='auto' resolution
# ---------------------------------------------------------------------------

@pytest.fixture
def crossover_state():
    yield
    ops.set_paged_crossover(None)            # never leak into other tests


def test_auto_impl_off_tpu_is_ref(crossover_state):
    ops.set_paged_crossover(0.5)
    if not ops.on_tpu():
        assert ops.paged_auto_impl(0.1) == "ref"
        assert ops.paged_auto_impl(0.9) == "ref"


def test_auto_impl_unmeasured_stays_paged(crossover_state, monkeypatch):
    monkeypatch.setattr(ops, "on_tpu", lambda: True)
    ops.set_paged_crossover(None)
    assert ops.paged_auto_impl(0.05) == "pallas"
    assert ops.paged_auto_impl(1.0) == "pallas"


def test_auto_impl_both_sides_of_threshold(crossover_state, monkeypatch):
    monkeypatch.setattr(ops, "on_tpu", lambda: True)
    ops.set_paged_crossover(0.5)
    assert ops.paged_auto_impl(0.49) == "pallas"   # below: paged kernel
    assert ops.paged_auto_impl(0.5) == "ref"       # at/above: dense view
    assert ops.paged_auto_impl(0.51) == "ref"


def test_load_crossover_artifact(crossover_state, tmp_path):
    p = tmp_path / "BENCH_transfer.json"
    p.write_text(json.dumps({"crossover_occupancy": 0.75}))
    assert ops.load_paged_crossover(str(p)) == 0.75
    # a null measurement (interpret-mode bench run) must clear nothing
    ops.set_paged_crossover(None)
    p.write_text(json.dumps({"crossover_occupancy": None}))
    assert ops.load_paged_crossover(str(p)) is None
    # missing / malformed files are "no measurement", not errors
    assert ops.load_paged_crossover(str(tmp_path / "absent.json")) is None
    p.write_text("not json{")
    assert ops.load_paged_crossover(str(p)) is None


def test_engine_resolves_auto_policy(crossover_state):
    """policy.paged_attn_impl='auto' is resolved host-side at init from
    the measured table (off-TPU: dense-ref), and the serve matches the
    dense ring bit-exactly."""
    cfg, params = _smoke()
    work = _work(cfg, seed=1)
    _, dense = _run(cfg, params, work)
    eng, paged = _run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                      policy=ExecPolicy(paged_attn_impl="auto"))
    assert eng.policy.paged_attn_impl in ("ref", "pallas")   # resolved
    if not ops.on_tpu():
        assert eng.policy.paged_attn_impl == "ref"
    assert paged == dense


def test_hrm_measured_links(tmp_path):
    hw = H.preset("l4")
    spec_bw = hw.link_bw("cpu", "gpu")
    p = tmp_path / "BENCH_transfer.json"
    p.write_text(json.dumps({"h2d_pinned_bytes_per_s": 2.0e10,
                             "h2d_pageable_bytes_per_s": 1.0e10}))
    m = H.with_measured_links(hw, str(p))
    assert m.link_bw("cpu", "gpu") == 2.0e10
    assert m.name.endswith("+measured")
    assert hw.link_bw("cpu", "gpu") == spec_bw      # original untouched
    # pageable figure used when pinned is unavailable
    p.write_text(json.dumps({"h2d_pinned_bytes_per_s": None,
                             "h2d_pageable_bytes_per_s": 1.5e10}))
    assert H.with_measured_links(hw, str(p)).link_bw("cpu", "gpu") == 1.5e10
    # no artifact → hardware unchanged
    assert H.with_measured_links(
        hw, str(tmp_path / "none.json")).link_bw("cpu", "gpu") == spec_bw


# ---------------------------------------------------------------------------
# Fused epilogue acceptance: no separate write dispatch on the hot path
# ---------------------------------------------------------------------------

def test_write_decode_paged_not_on_hot_path(monkeypatch):
    """The paged decode step folds the one-token scatter into the fused
    attention dispatchers (which call the private ``_decode_scatter``):
    the public ``write_decode_paged`` wrapper must never be traced on
    the serving hot path."""
    calls = {"n": 0}
    real = kvcache.write_decode_paged

    def spy(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(kvcache, "write_decode_paged", spy)
    # spy sanity: a direct call is counted
    B, NB, bt, Hkv, D = 2, 5, 4, 2, 8
    cache = {"k": kvcache.retile_arena_leaf(
                 "k", jnp.zeros((NB, bt, Hkv, D))),
             "v": kvcache.retile_arena_leaf(
                 "v", jnp.zeros((NB, bt, Hkv, D))),
             "slot_pos": jnp.full((NB, bt), -1, jnp.int32),
             "page_table": jnp.arange(B * 2, dtype=jnp.int32
                                      ).reshape(B, 2)}
    new = {"k": jnp.ones((B, 1, Hkv, D)), "v": jnp.ones((B, 1, Hkv, D))}
    kvcache.write_decode_paged(cache, new, jnp.zeros((B,), jnp.int32))
    assert calls["n"] == 1
    calls["n"] = 0

    jax.clear_caches()                       # force hot-path retraces
    cfg, params = _smoke()
    work = _work(cfg, seed=2, n=3)
    for policy in (None, ExecPolicy(paged_attn_impl="interpret")):
        _, out = _run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                      policy=policy)
        assert out                           # the serve actually decoded
    assert calls["n"] == 0


# ---------------------------------------------------------------------------
# Recoverable fall-back: pinned → pageable → (re-probe) → pinned
# ---------------------------------------------------------------------------

def test_engine_demotes_to_pageable_on_injected_hostmem(fake_pinned):
    """An injected pinned-allocation failure at init falls the engine
    back to the pageable-numpy tier (recoverable: no process-wide
    latch), forces the ladder's pageable_host rung, and transcripts
    still match the dense ring."""
    from repro.runtime.faults import FaultEvent, FaultPlan
    cfg, params = _smoke()
    work = _work(cfg)
    _, dense = _run(cfg, params, work)
    plan = FaultPlan(trace=[FaultEvent("host_alloc", "hostmem",
                                       after=0, count=1)])
    eng, paged = _run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                      fault_plan=plan)
    assert not eng._kv_pinned                  # fell back at the probe
    assert all(isinstance(a, np.ndarray) for g in eng._kv_host.values()
               for a in g.values())
    assert paged == dense
    ft = eng.fault_traffic()
    assert ft["injected"].get("host_alloc/hostmem") == 1


def test_host_tier_demote_then_repromote_round_trip(fake_pinned):
    """The satellite acceptance: mid-run demotion to pageable AND the
    ladder's re-promotion back to pinned, with block bytes preserved
    across both transitions (transcripts identical to dense)."""
    cfg, params = _smoke()
    work = _work(cfg, seed=7, n=6)
    _, dense = _run(cfg, params, work)
    eng = Engine(cfg, params, EngineConfig(
        ubatch=2, num_ubs=2, max_seq=64, decode_chunk=4,
        kv_paged=True, kv_gpu_ratio=0.25))
    assert eng._kv_pinned
    for p, q in work:
        eng.submit(p, q)
    # run a few steps so the host tier holds real spilled blocks
    for _ in range(3):
        eng.step()
    eng._demote_host_tier()
    assert not eng._kv_pinned
    assert all(isinstance(a, np.ndarray) for g in eng._kv_host.values()
               for a in g.values())
    assert eng._ladder.pending()               # rung recorded for next tick
    for _ in range(2):
        eng.step()                             # serves on the pageable tier
    eng._repromote_host_tier()
    assert eng._kv_pinned                      # probe succeeded: pinned again
    assert all(isinstance(a, jax.Array) for g in eng._kv_host.values()
               for a in g.values())
    out = eng.run_until_idle()
    assert out == dense


def test_repromote_stays_pageable_when_probe_still_fails(no_pinned):
    """Re-promotion is honest: when the re-probe still finds no pinned
    space the tier stays pageable (and serving continues unharmed)."""
    cfg, params = _smoke()
    eng = Engine(cfg, params, EngineConfig(
        ubatch=2, num_ubs=2, max_seq=64, decode_chunk=4,
        kv_paged=True, kv_gpu_ratio=0.25))
    assert not eng._kv_pinned
    eng._repromote_host_tier()
    assert not eng._kv_pinned
    for p, q in _work(cfg, seed=9, n=2):
        eng.submit(p, q)
    assert eng.run_until_idle()


def test_reset_host_probe_rearms_warning(no_pinned):
    """reset_host_probe clears the warn-once latch, so a recurring
    fall-back is observable per occurrence, not once per process."""
    with pytest.warns(offload.HostOffloadFallbackWarning):
        offload.pinned_host_sharding()
    offload.reset_host_probe()
    with pytest.warns(offload.HostOffloadFallbackWarning):
        offload.pinned_host_sharding()

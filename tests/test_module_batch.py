"""Module-based batching: the routed-token staging buffer and the
decoupled attention/expert two-phase schedule.

Property suite (hypothesis when available, seeded stand-in otherwise)
over the staging index map ``models.moe.stage_bucket``:

  * token conservation — per (group, bucket) the kept count is exactly
    min(routed, cap); capacity overflow *drops to the lockstep path's
    drops*, never silently loses extra tokens;
  * no cross-group mixing — every kept entry's staged slot lies inside
    its own group's span of the buffer;
  * groups=1 degenerates bit-exactly to the lockstep ``_bucket``.

Then the end-to-end guarantees: a staged grouped MoE call equals G
independent per-group calls; a window whose staging buffer would
overflow ``module_stage_tokens`` falls back to lockstep (same
transcripts, tokens never dropped); the ≥2× expert-weight
traffic-amortization acceptance bar on a decode-dominated workload;
and the policy-search grid extension."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                          # CI installs it; the bare
    HAS_HYPOTHESIS = False                   # container runs the seeded
                                             # cases below instead

from repro.models import moe
from repro.models.moe import _bucket, stage_bucket, stage_conservation_ok


# ---------------------------------------------------------------------------
# Staging index-map properties
# ---------------------------------------------------------------------------

def _check_staging(dest, n_buckets, cap, groups):
    dest = jnp.asarray(dest, jnp.int32)
    slot, keep = stage_bucket(dest, n_buckets, cap, groups)
    assert stage_conservation_ok(np.asarray(dest), np.asarray(slot),
                                 np.asarray(keep), n_buckets, cap, groups)
    # per-group decisions are the lockstep path's: each group's slice run
    # through _bucket alone keeps exactly the same entries at the same
    # within-group ranks (staged slot minus the group's span offset)
    per_g = dest.shape[0] // groups
    slot_np, keep_np = np.asarray(slot), np.asarray(keep)
    for g in range(groups):
        sl = slice(g * per_g, (g + 1) * per_g)
        s1, k1 = _bucket(dest[sl], n_buckets, cap)
        assert np.array_equal(keep_np[sl], np.asarray(k1))
        kept = keep_np[sl]
        assert np.array_equal(slot_np[sl][kept] - g * cap,
                              np.asarray(s1)[kept])


def _random_case(rng):
    groups = int(rng.integers(1, 5))
    per_g = int(rng.integers(1, 13))
    n_buckets = int(rng.integers(1, 9))
    cap = int(rng.integers(1, per_g + 2))
    dest = rng.integers(-1, n_buckets, groups * per_g)
    return dest, n_buckets, cap, groups


if HAS_HYPOTHESIS:
    @st.composite
    def _case(draw):
        groups = draw(st.integers(1, 4))
        per_g = draw(st.integers(1, 12))
        n_buckets = draw(st.integers(1, 8))
        cap = draw(st.integers(1, per_g + 1))
        dest = draw(st.lists(st.integers(-1, n_buckets - 1),
                             min_size=groups * per_g,
                             max_size=groups * per_g))
        return np.array(dest, np.int32), n_buckets, cap, groups

    @settings(max_examples=40, deadline=None)
    @given(_case())
    def test_staging_properties_hypothesis(case):
        _check_staging(*case)


@pytest.mark.parametrize("seed", range(8))
def test_staging_properties_seeded(seed):
    rng = np.random.default_rng(seed)
    for _ in range(6):
        _check_staging(*_random_case(rng))


def test_staging_degenerates_to_bucket():
    """groups=1 is bit-identical to the lockstep _bucket map."""
    rng = np.random.default_rng(0)
    dest = jnp.asarray(rng.integers(-1, 4, 24), jnp.int32)
    s0, k0 = _bucket(dest, 4, 3)
    s1, k1 = stage_bucket(dest, 4, 3, groups=1)
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
    assert np.array_equal(np.asarray(k0), np.asarray(k1))


def test_staging_overflow_drops_match_lockstep():
    """Capacity overflow inside one group drops exactly the entries the
    lockstep path would drop (rank ≥ cap) — first-come ranks, tokens of
    the *other* group unaffected."""
    # group 0 routes 4 tokens to bucket 0 with cap 2; group 1 routes 1
    dest = jnp.asarray([0, 0, 0, 0, 0, -1, -1, -1], jnp.int32)
    slot, keep = stage_bucket(dest, 2, 2, groups=2)
    keep = np.asarray(keep)
    assert keep.tolist() == [True, True, False, False, True,
                             False, False, False]
    assert np.asarray(slot)[4] == 1 * 2 + 0   # group 1's span starts at g*cap


# ---------------------------------------------------------------------------
# Staged grouped MoE == per-group lockstep calls
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def moe_cfg():
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.key(7))
    return cfg, params


@pytest.mark.parametrize("groups", [2, 4])
def test_staged_grouped_matches_pergroup(moe_cfg, groups):
    cfg, params = moe_cfg
    layer = params["blocks"]["p0"]["moe"]
    p = jax.tree.map(lambda a: a[0], layer)   # layer 0 of the stack
    per_g = 4
    x = jax.random.normal(jax.random.key(1), (groups * per_g, cfg.d_model),
                          jnp.float32)
    out_staged, _ = moe.moe_grouped(cfg, p, x, token_groups=groups)
    for g in range(groups):
        sl = slice(g * per_g, (g + 1) * per_g)
        out_g, _ = moe.moe_grouped(cfg, p, x[sl])
        assert np.array_equal(np.asarray(out_staged[sl]), np.asarray(out_g))


# ---------------------------------------------------------------------------
# Engine: fallback + the ≥2× amortization acceptance bar
# ---------------------------------------------------------------------------

def _serve(cfg, params, work, **kw):
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, EngineConfig(**kw))
    for prompt, quota in work:
        eng.submit(prompt, quota)
    out = eng.run_until_idle()
    assert all(r.done for r in eng.scheduler.requests.values())
    return out, eng


def _decode_heavy_workload(cfg, seed, n):
    """Short prompts, long generations: expert-weight streaming dominates
    and every decode window runs with all groups live."""
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(2, 6))),
             int(rng.integers(16, 25)))
            for _ in range(n)]


def test_stage_capacity_falls_back_to_lockstep(moe_cfg):
    """module_stage_tokens below one full window shrinks the window
    toward lockstep — transcripts stay identical and no request loses
    tokens (fallback, not drop)."""
    cfg, params = moe_cfg
    work = _decode_heavy_workload(cfg, seed=2, n=5)
    kw = dict(ubatch=3, num_ubs=2, max_seq=64, decode_chunk=4)
    base, _ = _serve(cfg, params, work, **kw)
    capped, eng = _serve(cfg, params, work, module_batch=True,
                         module_stage_tokens=3, **kw)
    assert capped == base
    assert eng._mg == 1                       # clamped all the way down
    for (prompt, quota), toks in zip(work, capped.values()):
        assert len(toks) == quota             # nothing dropped


def test_module_batch_halves_expert_traffic(moe_cfg):
    """ISSUE 6 acceptance: ≥2× fewer H2D expert-weight bytes per token
    than the PR 3 router-ahead lockstep path at the same r_w on a
    decode-dominated workload, transcripts bit-identical, and the
    counter-derived module_groups_effective agrees."""
    cfg, params = moe_cfg
    work = _decode_heavy_workload(cfg, seed=0, n=16)
    kw = dict(ubatch=4, num_ubs=4, max_seq=64, decode_chunk=4,
              expert_paged=True, page_elems=4096, w_gpu_ratio=0.25,
              # pin the PR 3 comparator: intra-pass accounting and the
              # gate predictor (PR 8, default-on) shrink the lockstep
              # side's traffic and would understate the amortization
              predict=False, intra_pass=False)
    base, eng_l = _serve(cfg, params, work, **kw)
    windowed, eng_w = _serve(cfg, params, work, module_batch=True,
                             module_groups=4, **kw)
    assert windowed == base

    tl, tw = eng_l.weight_traffic(), eng_w.weight_traffic()
    assert tl["module_groups"] == 1 and tw["module_groups"] == 4
    per_tok_l = tl["expert_phase_bytes"] / eng_l.tokens_out
    per_tok_w = tw["expert_phase_bytes"] / eng_w.tokens_out
    assert per_tok_l >= 2.0 * per_tok_w, (per_tok_l, per_tok_w)
    assert tw["module_groups_effective"] >= 2.0
    # the counter ratio and the byte ratio are the same measurement
    assert tw["module_groups_effective"] == pytest.approx(
        tl["expert_phase_bytes"] / tw["expert_phase_bytes"], rel=0.35)
    assert tw["bytes_per_token_amortized"] < tl["bytes_per_token_amortized"]


# ---------------------------------------------------------------------------
# Policy search over module_groups
# ---------------------------------------------------------------------------

def test_policy_search_module_groups_grid():
    from repro.configs import get_config
    from repro.core import hrm, policy as P

    cfg = get_config("mixtral-8x7b")
    hw = hrm.preset("l4")
    wl = P.Workload(prompt_len=77, gen_len=64)
    base = P.search(cfg, hw, wl)
    widened = P.search(cfg, hw, wl, module_groups_grid=(1, 2, 4))
    # grid contains the lockstep point, so widening can only help
    assert (widened["best"]["throughput"]
            >= base["best"]["throughput"] - 1e-9)
    # staging memory is charged: G > 1 costs GPU bytes at equal tuple
    pol = base["best"]["policy"]
    if pol.ffn_on_gpu:
        m1 = P.memory_usage(cfg, wl, pol)
        m4 = P.memory_usage(cfg, wl, dataclasses.replace(
            pol, module_groups=4))
        assert m4["gpu"] > m1["gpu"]
    # and the HRM latency term amortizes: same tuple, G=4, less traffic
    est1 = P.estimate(cfg, hw, wl, pol)
    est4 = P.estimate(cfg, hw, wl,
                      dataclasses.replace(pol, module_groups=4))
    if pol.ffn_on_gpu and pol.w_gpu_ratio < 1.0:
        assert est4["comm_bytes"] < est1["comm_bytes"]

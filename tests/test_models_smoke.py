"""REQUIRED per-arch smoke tests: reduced same-family config, one forward
AND one train step on CPU, asserting output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_shape
from repro.models import forward, unembed
from repro.models.inputs import concrete_inputs
from repro.models.params import init_params
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step

pytestmark = pytest.mark.slow      # all-arch sweep, multi-minute


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).smoke()
    params = init_params(cfg, jax.random.key(0))
    batch = concrete_inputs(cfg, get_shape("train_4k").smoke())
    extras = {k: v for k, v in batch.items() if k in ("frames", "patches")}
    out = forward(cfg, params, batch["tokens"], mode="train", **extras)
    logits = unembed(cfg, params, out["hidden"])
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    if cfg.logit_softcap:
        assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    params = init_params(cfg, jax.random.key(0))
    opt = OptConfig(warmup_steps=2)
    opt_state = init_opt_state(params, opt)
    batch = concrete_inputs(cfg, get_shape("train_4k").smoke())
    step = jax.jit(make_train_step(cfg, opt))
    new_params, new_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    """decode shapes: one new token against a live cache."""
    cfg = get_config(arch).smoke()
    params = init_params(cfg, jax.random.key(0))
    from repro.models import kvcache
    B, S = 2, 16
    cache = kvcache.init_cache(cfg, B, 32)
    toks = jnp.ones((B, S), jnp.int32)
    extras = {}
    if cfg.encoder_layers:
        extras["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    if cfg.vision_tokens:
        extras["patches"] = jnp.zeros((B, cfg.vision_tokens, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
    out = forward(cfg, params, toks, cache=cache, mode="prefill", **extras)
    cache = out["cache"]
    out = forward(cfg, params, toks[:, :1], cache=cache, mode="decode")
    logits = unembed(cfg, params, out["hidden"][:, -1])
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(out["cache"]["pos"][0]) == S + 1

"""Roofline machinery: HLO collective parser on synthetic text, census
closed forms, and the quantization byte factors."""
import dataclasses

import pytest

from repro.configs import get_config, get_shape
from repro.core.census import census
from repro.core.roofline import (_shape_bytes, parse_collectives,
                                 model_flops_for)

HLO = """
ENTRY main {
  %x = bf16[8,128,256]{2,1,0} parameter(0)
  %ag = bf16[8,2048,256]{2,1,0} all-gather(bf16[8,128,256] %x), dimensions={1}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024] %y), to_apply=%add
  %start = f32[512]{0} all-reduce-start(f32[512] %z)
  %a2a = bf16[16,64]{1,0} all-to-all(bf16[16,64] %w)
  %cp = u32[32]{0} collective-permute(u32[32] %v)
  %notacoll = bf16[4,4]{1,0} add(bf16[4,4] %a, bf16[4,4] %b)
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 2,
                                "all-to-all": 1, "collective-permute": 1}
    # all-gather: max(output, operand) = 8*2048*256*2 bytes
    assert st.bytes_by_kind["all-gather"] == 8 * 2048 * 256 * 2
    assert st.bytes_by_kind["all-to-all"] == 16 * 64 * 2
    assert st.bytes_by_kind["collective-permute"] == 32 * 4
    assert st.bytes_by_kind["all-reduce"] == 1024 * 4 + 512 * 4


def test_parse_ignores_done_phase():
    txt = "%d = f32[64]{0} all-reduce-done(f32[64] %s)\n"
    assert parse_collectives(txt).total_bytes == 0


def test_shape_bytes():
    assert _shape_bytes("bf16", "2,3,4") == 48
    assert _shape_bytes("f32", "") == 4


@pytest.fixture(scope="module")
def mesh_shape():
    return {"data": 16, "model": 16}


def test_census_flops_closed_form_dense(mesh_shape):
    """olmo decode: census FLOPs = 2*N_active*D (weights) + the 32k-context
    attention term 4*B*H*Dh*S*L (which dominates for MHA at this context:
    ~550 GF vs 275 GF of weight matmuls)."""
    cfg = get_config("olmo-1b")
    shape = get_shape("decode_32k")
    c = census(cfg, shape, mesh_shape)
    mf = model_flops_for(cfg, shape)
    attn = (4 * shape.global_batch * cfg.num_heads * cfg.head_dim
            * shape.seq_len * cfg.num_layers)
    assert 0.85 * (mf + attn) < c.flops < 1.3 * (mf + attn)


def test_census_train_multiplier(mesh_shape):
    cfg = get_config("olmo-1b")
    tr = census(cfg, get_shape("train_4k"), mesh_shape)
    # train flops per token ~ 3x inference forward per token
    pf = census(cfg, dataclasses.replace(get_shape("train_4k"),
                                         mode="prefill"), mesh_shape)
    assert 2.5 < tr.flops / pf.flops < 3.5


def test_census_int8_experts_halve_weight_bytes(mesh_shape):
    cfg = get_config("mixtral-8x7b")
    shape = get_shape("decode_32k")
    from repro.distributed import sharding as SH
    import jax
    # plan-free census: compare via cfg flag only (no expert sharding)
    base = census(cfg, shape, mesh_shape)
    q = census(dataclasses.replace(cfg, expert_dtype="int8"), shape,
               mesh_shape)
    assert q.hbm_bytes < base.hbm_bytes
    # expert weights dominate mixtral decode: expect >30% reduction
    assert q.hbm_bytes < 0.7 * base.hbm_bytes


def test_census_int8_kv_reduces_bytes(mesh_shape):
    cfg = get_config("olmo-1b")            # fat KV (MHA kv=16)
    shape = get_shape("decode_32k")
    base = census(cfg, shape, mesh_shape)
    q = census(dataclasses.replace(cfg, kv_dtype="int8"), shape, mesh_shape)
    assert q.hbm_bytes < base.hbm_bytes


def test_census_collectives_scale_with_pod(mesh_shape):
    cfg = get_config("olmo-1b")
    c1 = census(cfg, get_shape("train_4k"), mesh_shape)
    c2 = census(cfg, get_shape("train_4k"),
                {"pod": 2, "data": 16, "model": 16})
    assert "all-reduce(pod)" not in c1.coll_bytes
    assert c2.coll_bytes.get("all-reduce(pod)", 0) > 0

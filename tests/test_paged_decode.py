"""Page-table-native paged flash-decode kernels: the interpret-mode
Pallas kernels must match the dense-view oracle (``kvcache.paged_view``
+ ``attention_partials``, the ops ``ref`` impl) over adversarial page
tables — permuted physical blocks, partial prefixes, unmapped (-1)
entries, garbage slot_pos — across block sizes, GQA group shapes, the
int8 arena (per-block scale folding), and the MLA latent variant.  The
running-max partial is **bit-identical** (max is exactly associative);
the o/l accumulators are pinned to a few ulps (blockwise online-softmax
accumulation reassociates the sum the oracle's single einsum performs —
1e-5 here is ~30× the worst observed drift).  The trash block must
never be read by the gather side, and at engine level greedy
transcripts must stay **bit-identical** between dense rings and the
paged-kernel path in every serving mode (the fast subset here is the
interpret-mode parity slice CPU CI runs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                          # CI installs it; the bare
    HAS_HYPOTHESIS = False                   # container runs the seeded
                                             # sweeps below instead

from repro.kernels import ops
from repro.models import kvcache
from repro.models.attention import combine_partials


# ---------------------------------------------------------------------------
# Random paged-cache construction
# ---------------------------------------------------------------------------

def _random_page_table(rng, B, MB, dev):
    """Adversarial (B, MB) table: per-row random mapped-prefix length,
    distinct physical blocks in permuted order, -1 beyond the prefix."""
    pt = np.full((B, MB), -1, np.int32)
    phys = list(rng.permutation(dev))
    for b in range(B):
        n = int(rng.integers(0, MB + 1))
        for lb in range(n):
            if not phys:
                break
            pt[b, lb] = phys.pop()
    return pt


def _gqa_case(rng, B, MB, bt, Hkv, G, D, Dv, int8=False, trash_nan=False):
    dev = int(rng.integers(1, B * MB + 1))
    NB = dev + 1                              # + trash block
    W = MB * bt
    pt = _random_page_table(rng, B, MB, dev)
    sp = rng.integers(-1, W, (NB, bt)).astype(np.int32)
    pos = rng.integers(0, W, (B,)).astype(np.int32)
    q = rng.normal(size=(B, Hkv * G, D)).astype(np.float32)
    cache = {"page_table": jnp.asarray(pt)}
    # built token-major (NB, bt, Hkv, D*) for readability, then retiled to
    # the head-major arena layout the kernels read natively
    if int8:
        k = rng.integers(-127, 128, (NB, bt, Hkv, D)).astype(np.int8)
        v = rng.integers(-127, 128, (NB, bt, Hkv, Dv)).astype(np.int8)
        cache["k_scale"] = kvcache.retile_arena_leaf("k_scale", jnp.asarray(
            (rng.random((NB, bt, Hkv)) * 0.02 + 1e-3).astype(np.float32)))
        cache["v_scale"] = kvcache.retile_arena_leaf("v_scale", jnp.asarray(
            (rng.random((NB, bt, Hkv)) * 0.02 + 1e-3).astype(np.float32)))
    else:
        k = rng.normal(size=(NB, bt, Hkv, D)).astype(np.float32)
        v = rng.normal(size=(NB, bt, Hkv, Dv)).astype(np.float32)
        if trash_nan:                         # scatter-only block: poison it
            k[-1], v[-1] = np.nan, np.nan
            sp[-1] = rng.integers(0, W, (bt,))   # plausible-looking ring
    cache["slot_pos"] = jnp.asarray(sp)
    cache["k"] = kvcache.retile_arena_leaf("k", jnp.asarray(k))
    cache["v"] = kvcache.retile_arena_leaf("v", jnp.asarray(v))
    return jnp.asarray(q), cache, jnp.asarray(pos)


def _match(a, b, m_exact=True):
    """Kernel partials vs oracle partials: m bit-exact (GQA — the score
    elements are identical dots, and max is exactly associative), o/l to
    ulps.  The MLA kernel scores via two partial dots where the oracle
    dots one concatenated key, so its m drifts by ulps too."""
    if m_exact:
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]),
                                      err_msg="running max diverged")
    else:
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg="running max diverged")
    for x, y, name in ((a[0], b[0], "o"), (a[2], b[2], "l")):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"partial {name} diverged")
    np.testing.assert_allclose(np.asarray(combine_partials(*a)),
                               np.asarray(combine_partials(*b)),
                               rtol=1e-5, atol=1e-5)


def _assert_kernel_is_oracle(q, cache, pos, *, scale, window=0, softcap=0.0):
    a = ops.paged_gqa_decode(q, cache, pos, scale=scale, window=window,
                             attn_softcap=softcap, impl="interpret")
    b = ops.paged_gqa_decode(q, cache, pos, scale=scale, window=window,
                             attn_softcap=softcap, impl="ref")
    _match(a, b)


# ---------------------------------------------------------------------------
# Kernel ≡ oracle, property-style
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 16, 32]),
           st.sampled_from([(1, 1), (2, 4), (1, 8)]), st.booleans())
    @settings(max_examples=12, deadline=None)
    def test_paged_gqa_kernel_bit_identical(seed, bt, heads, int8):
        rng = np.random.default_rng(seed)
        Hkv, G = heads
        q, cache, pos = _gqa_case(rng, B=2, MB=3, bt=bt, Hkv=Hkv, G=G,
                                  D=16, Dv=16, int8=int8)
        _assert_kernel_is_oracle(q, cache, pos, scale=16 ** -0.5)


@pytest.mark.parametrize("bt", [4, 8, 16, 32])
@pytest.mark.parametrize("heads", [(1, 1), (2, 4), (1, 8)])
def test_paged_gqa_kernel_bit_identical_seeded(bt, heads):
    """Seeded sweep (hypothesis-free containers): random page tables ×
    block sizes × GQA group shapes, f32 and int8, exact equality."""
    Hkv, G = heads
    for seed in range(4):
        rng = np.random.default_rng(hash((bt, Hkv, G, seed)) % 2 ** 31)
        for int8 in (False, True):
            q, cache, pos = _gqa_case(rng, B=2, MB=3, bt=bt, Hkv=Hkv, G=G,
                                      D=16, Dv=16, int8=int8)
            _assert_kernel_is_oracle(q, cache, pos, scale=16 ** -0.5)


def test_paged_gqa_kernel_softcap_and_dv():
    """Softcap and Dv != D (the MLA-latent shape) through the kernel."""
    rng = np.random.default_rng(11)
    q, cache, pos = _gqa_case(rng, B=1, MB=4, bt=8, Hkv=2, G=2, D=32, Dv=24)
    _assert_kernel_is_oracle(q, cache, pos, scale=32 ** -0.5, softcap=30.0)


def test_paged_gqa_kernel_window_mask():
    """Sliding-window validity evaluated in-kernel on the block's own
    slot_pos slab matches the dense-view decode_valid_mask."""
    rng = np.random.default_rng(12)
    q, cache, pos = _gqa_case(rng, B=2, MB=4, bt=8, Hkv=1, G=4, D=16, Dv=16)
    _assert_kernel_is_oracle(q, cache, pos, scale=16 ** -0.5, window=12)


def test_paged_gqa_all_unmapped_row():
    """A row mapping zero blocks (a free slot) must come back with l = 0
    everywhere — the combine guard then yields exactly 0 output."""
    rng = np.random.default_rng(13)
    q, cache, pos = _gqa_case(rng, B=2, MB=2, bt=8, Hkv=1, G=2, D=16, Dv=16)
    pt = np.asarray(cache["page_table"]).copy()
    pt[0] = -1
    cache["page_table"] = jnp.asarray(pt)
    o, m, l = ops.paged_gqa_decode(q, cache, pos, scale=0.25,
                                   impl="interpret")
    assert np.asarray(l)[0].sum() == 0.0
    assert np.abs(np.asarray(o)[0]).sum() == 0.0
    _assert_kernel_is_oracle(q, cache, pos, scale=0.25)


def test_paged_gqa_trash_block_never_read():
    """The arena's last block is a scatter-only target: poisoned with
    NaN, the kernel's output must stay finite and equal the oracle run
    on a zeroed trash block (the dense view *does* gather the trash
    block for unmapped spans, so the oracle needs it finite)."""
    rng = np.random.default_rng(14)
    q, cache, pos = _gqa_case(rng, B=2, MB=3, bt=8, Hkv=2, G=2, D=16,
                              Dv=16, trash_nan=True)
    clean = dict(cache)
    clean["k"] = cache["k"].at[:, -1].set(0.0)    # block axis 1 (head-major)
    clean["v"] = cache["v"].at[:, -1].set(0.0)
    a = ops.paged_gqa_decode(q, cache, pos, scale=0.25, impl="interpret")
    b = ops.paged_gqa_decode(q, clean, pos, scale=0.25, impl="ref")
    assert np.isfinite(np.asarray(a[0])).all()
    _match(a, b)


# ---------------------------------------------------------------------------
# MLA variant
# ---------------------------------------------------------------------------

def _mla_case(rng, B, MB, bt, H, lat, dr):
    dev = int(rng.integers(1, B * MB + 1))
    NB = dev + 1
    W = MB * bt
    cache = {
        "ckv": jnp.asarray(rng.normal(size=(NB, bt, lat)).astype(np.float32)),
        "kr": jnp.asarray(rng.normal(size=(NB, bt, dr)).astype(np.float32)),
        "slot_pos": jnp.asarray(rng.integers(-1, W, (NB, bt)).astype(np.int32)),
        "page_table": jnp.asarray(_random_page_table(rng, B, MB, dev)),
    }
    qcat = jnp.asarray(rng.normal(size=(B, H, lat + dr)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, W, (B,)).astype(np.int32))
    return qcat, cache, pos


@pytest.mark.parametrize("bt", [8, 16])
def test_paged_mla_kernel_matches_oracle(bt):
    for seed in range(4):
        rng = np.random.default_rng(100 + seed * 10 + bt)
        qcat, cache, pos = _mla_case(rng, B=2, MB=3, bt=bt, H=4,
                                     lat=16, dr=8)
        _match(ops.paged_mla_decode(qcat, cache, pos, scale=24 ** -0.5,
                                    lat=16, impl="interpret"),
               ops.paged_mla_decode(qcat, cache, pos, scale=24 ** -0.5,
                                    lat=16, impl="ref"), m_exact=False)


if HAS_HYPOTHESIS:
    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_paged_mla_kernel_matches_oracle_prop(seed):
        rng = np.random.default_rng(seed)
        qcat, cache, pos = _mla_case(rng, B=2, MB=3, bt=8, H=4, lat=16, dr=8)
        _match(ops.paged_mla_decode(qcat, cache, pos, scale=24 ** -0.5,
                                    lat=16, impl="interpret"),
               ops.paged_mla_decode(qcat, cache, pos, scale=24 ** -0.5,
                                    lat=16, impl="ref"), m_exact=False)


# ---------------------------------------------------------------------------
# Dense int8 per-tile dequant (the un-paged satellite): folded scales in
# the ref partials and the dense Pallas kernel agree with the
# dequantize-then-compute composition
# ---------------------------------------------------------------------------

def test_dense_int8_folded_scales_match_dequant():
    rng = np.random.default_rng(21)
    B, W, Hkv, G, D = 2, 32, 2, 4, 16
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)).astype(np.float32))
    k = jnp.asarray(rng.integers(-127, 128, (B, W, Hkv, D)).astype(np.int8))
    v = jnp.asarray(rng.integers(-127, 128, (B, W, Hkv, D)).astype(np.int8))
    ks = jnp.asarray((rng.random((B, W, Hkv)) * 0.02 + 1e-3).astype(np.float32))
    vs = jnp.asarray((rng.random((B, W, Hkv)) * 0.02 + 1e-3).astype(np.float32))
    valid = jnp.asarray(rng.random((B, W)) > 0.3)
    folded = ops.gqa_decode(q, k, v, valid, scale=0.25,
                            k_scale=ks, v_scale=vs, impl="ref")
    kern = ops.gqa_decode(q, k, v, valid, scale=0.25,
                          k_scale=ks, v_scale=vs, block_w=8,
                          impl="interpret")
    kf = k.astype(jnp.float32) * ks[..., None]
    vf = v.astype(jnp.float32) * vs[..., None]
    deq = ops.gqa_decode(q, kf, vf, valid, scale=0.25, impl="ref")
    a = combine_partials(*folded)
    np.testing.assert_allclose(np.asarray(a),
                               np.asarray(combine_partials(*deq)),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(combine_partials(*kern)),
                               np.asarray(a), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Engine-level: dense vs paged-kernel greedy transcripts, every mode
# ---------------------------------------------------------------------------

def _engine_work(cfg, seed, n, max_len=24, max_quota=8):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(1, max_len))),
             int(rng.integers(1, max_quota))) for _ in range(n)]


def _engine_run(cfg, params, work, policy=None, **kw):
    from repro.serving.engine import Engine, EngineConfig
    ecfg = dict(ubatch=2, num_ubs=2, max_seq=64, decode_chunk=4)
    ecfg.update(kw)
    eng = Engine(cfg, params, EngineConfig(**ecfg), policy=policy)
    for p, q in work:
        eng.submit(p, q)
    return eng.run_until_idle()


def _kernel_policy():
    from repro.models.model import ExecPolicy
    return ExecPolicy(paged_attn_impl="interpret")


def _smoke(arch, dtype_kw=None, seed=3):
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config(arch).smoke(), dtype="float32",
                              **(dtype_kw or {}))
    return cfg, init_params(cfg, jax.random.key(seed))


def test_engine_paged_kernel_transcripts_fast():
    """Fast CI slice: dense rings vs the paged dispatcher's ref impl vs
    the interpret-mode Pallas kernel — bit-identical greedy output."""
    cfg, params = _smoke("qwen2.5-3b")
    work = _engine_work(cfg, seed=0, n=4)
    dense = _engine_run(cfg, params, work)
    ref = _engine_run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25)
    kern = _engine_run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                       policy=_kernel_policy())
    assert ref == dense
    assert kern == dense


def test_engine_paged_kernel_int8_fast():
    cfg, params = _smoke("qwen2.5-3b", {"kv_dtype": "int8"}, seed=5)
    work = _engine_work(cfg, seed=5, n=4)
    dense = _engine_run(cfg, params, work)
    kern = _engine_run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                       policy=_kernel_policy())
    assert kern == dense


def test_engine_paged_kernel_mla_fast():
    cfg, params = _smoke("deepseek-v3-671b", seed=7)
    work = _engine_work(cfg, seed=7, n=4)
    dense = _engine_run(cfg, params, work)
    kern = _engine_run(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                       policy=_kernel_policy())
    assert kern == dense


@pytest.mark.slow
def test_engine_paged_kernel_every_mode():
    """The full mode sweep through the interpret kernel: static,
    continuous, overlapped staged prefill, EWMA reservations with
    recompute preemption, prefetch off — all bit-identical to dense."""
    cfg, params = _smoke("qwen2.5-3b", seed=9)
    work = _engine_work(cfg, seed=9, n=6, max_len=32)
    dense = _engine_run(cfg, params, work)
    pol = _kernel_policy()
    variants = {
        "kernel_cont": dict(kv_paged=True, kv_gpu_ratio=0.25, policy=pol),
        "kernel_static": dict(mode="static", kv_paged=True,
                              kv_gpu_ratio=0.25, policy=pol),
        "kernel_overlap": dict(overlap=True, prefill_chunk=8, kv_paged=True,
                               kv_gpu_ratio=0.25, policy=pol),
        "kernel_ewma": dict(reserve_mode="ewma", cache_tokens=100,
                            kv_paged=True, kv_gpu_ratio=0.25, policy=pol),
        "kernel_bt4": dict(kv_paged=True, block_tokens=4,
                           kv_gpu_ratio=0.25, policy=pol),
        "kernel_bt8": dict(kv_paged=True, block_tokens=8,
                           kv_gpu_ratio=0.25, policy=pol),
        "kernel_bt32": dict(kv_paged=True, block_tokens=32,
                            kv_gpu_ratio=0.25, policy=pol),
        "kernel_noprefetch": dict(kv_paged=True, kv_gpu_ratio=0.25,
                                  kv_prefetch=False, policy=pol),
    }
    for name, kw in variants.items():
        assert _engine_run(cfg, params, work, **kw) == dense, name


@pytest.mark.slow
def test_engine_paged_kernel_with_expert_paged():
    """Kernel-path paged KV composed with expert-granular paged weights
    in overlap mode (the overlap+expert-paged combo of the acceptance
    bar)."""
    cfg, params = _smoke("mixtral-8x7b", seed=4)
    work = _engine_work(cfg, seed=4, n=4, max_len=20, max_quota=6)
    dense = _engine_run(cfg, params, work)
    kern = _engine_run(cfg, params, work, overlap=True, prefill_chunk=8,
                       expert_paged=True, page_elems=4096, w_gpu_ratio=0.25,
                       kv_paged=True, kv_gpu_ratio=0.25,
                       policy=_kernel_policy())
    assert kern == dense


def test_engine_gathered_bytes_scale_with_mapped_blocks():
    """kv_traffic()'s decode-gather accounting: bytes/step follow the
    page table's mapped-block count, strictly below the max_seq-wide
    dense-view equivalent on a short-prompt workload."""
    cfg, params = _smoke("qwen2.5-3b", seed=2)
    work = _engine_work(cfg, seed=2, n=4, max_len=12, max_quota=4)
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4, kv_paged=True,
                                           kv_gpu_ratio=1.0))
    for p, q in work:
        eng.submit(p, q)
    eng.run_until_idle()
    t = eng.kv_traffic()
    assert t["gathered_bytes"] > 0
    assert t["gathered_bytes_per_step"] < t["paged_view_bytes_per_step"]
    assert t["gather_reduction_vs_view"] > 1.5
    # the dense-view equivalent is exactly the group's full ring span
    mb = eng.ecfg.max_seq // eng.ecfg.block_tokens
    assert t["paged_view_bytes_per_step"] == pytest.approx(
        eng.ecfg.ubatch * mb * eng._kv.block_bytes)

"""REQUIRED per-kernel tests: sweep shapes/dtypes in interpret mode and
assert_allclose against the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gqa_decode import gqa_decode
from repro.kernels.moe_ffn import moe_ffn
from repro.models.attention import combine_partials

GQA_SHAPES = [
    # (B, H, Hkv, D, Dv, W, block_w)
    (2, 8, 2, 64, 64, 512, 128),          # standard GQA
    (1, 4, 1, 128, 96, 256, 64),          # MQA, Dv != D (MLA-latent shape)
    (3, 16, 16, 32, 32, 128, 128),        # MHA, single block
    (2, 8, 4, 256, 256, 384, 128),        # gemma-style head_dim 256
]


@pytest.mark.parametrize("shape", GQA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_decode_kernel_vs_oracle(rng, shape, dtype):
    B, H, Hkv, D, Dv, W, bw = shape
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, Dv)), dtype)
    valid = jnp.asarray(rng.random((B, W)) > 0.3)
    o1, m1, l1 = gqa_decode(q, k, v, valid, scale=D ** -0.5, block_w=bw,
                            interpret=True)
    o2, m2, l2 = ref.gqa_decode_ref(q, k, v, valid, scale=D ** -0.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(combine_partials(o1, m1, l1),
                               combine_partials(o2, m2, l2),
                               rtol=tol, atol=tol)


def test_gqa_decode_kernel_softcap(rng):
    B, H, Hkv, D, W = 1, 8, 4, 64, 256
    q = jnp.asarray(rng.normal(0, 2, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 2, (B, W, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    valid = jnp.ones((B, W), bool)
    o1, m1, l1 = gqa_decode(q, k, v, valid, scale=D ** -0.5,
                            attn_softcap=50.0, block_w=64, interpret=True)
    o2, m2, l2 = ref.gqa_decode_ref(q, k, v, valid, scale=D ** -0.5,
                                    attn_softcap=50.0)
    np.testing.assert_allclose(combine_partials(o1, m1, l1),
                               combine_partials(o2, m2, l2),
                               rtol=2e-5, atol=2e-5)


def test_gqa_decode_kernel_all_invalid_shard(rng):
    """A shard with zero valid slots must return l=0 (sequence-sharded
    combine relies on this)."""
    B, H, Hkv, D, W = 1, 4, 2, 32, 128
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    valid = jnp.zeros((B, W), bool)
    o, m, l = gqa_decode(q, k, v, valid, scale=1.0, block_w=64,
                         interpret=True)
    np.testing.assert_allclose(l, jnp.zeros_like(l))
    np.testing.assert_allclose(o, jnp.zeros_like(o))


MOE_SHAPES = [
    # (E, C, D, F, bc, bf, act)
    (4, 64, 32, 128, 32, 64, "silu"),
    (2, 100, 64, 300, 32, 128, "gelu"),   # non-multiple C/F (padding path)
    (8, 16, 128, 64, 16, 64, "silu"),
    (1, 128, 256, 512, 128, 512, "silu"),
]


@pytest.mark.parametrize("shape", MOE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_ffn_kernel_vs_oracle(rng, shape, dtype):
    E, C, D, F, bc, bf, act = shape
    x = jnp.asarray(rng.normal(0, 1, (E, C, D)), dtype)
    wi = jnp.asarray(rng.normal(0, 0.1, (E, D, 2, F)), dtype)
    wo = jnp.asarray(rng.normal(0, 0.1, (E, F, D)), dtype)
    a = moe_ffn(x, wi, wo, act=act, block_c=bc, block_f=bf, interpret=True)
    b = ref.moe_ffn_ref(x, wi, wo, act=act)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


def test_moe_ffn_kernel_fused_int8_dequant(rng):
    """int8 weights + per-expert scales fused in the tile loop must match
    the dequantize-then-compute oracle (tolerance covers the matmul/scale
    reassociation)."""
    E, C, D, F = 4, 32, 64, 128
    x = jnp.asarray(rng.normal(0, 1, (E, C, D)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 128, (E, D, 2, F)), jnp.int8)
    woq = jnp.asarray(rng.integers(-127, 128, (E, F, D)), jnp.int8)
    si = jnp.asarray(rng.random(E) * 0.01 + 0.001, jnp.float32)
    so = jnp.asarray(rng.random(E) * 0.01 + 0.001, jnp.float32)
    a = moe_ffn(x, wq, woq, wi_scale=si, wo_scale=so, block_c=16,
                block_f=64, interpret=True)
    b = ref.moe_ffn_ref(x, wq.astype(jnp.float32) * si[:, None, None, None],
                        woq.astype(jnp.float32) * so[:, None, None])
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


FLASH_SHAPES = [
    # (B, S, Skv, H, Hkv, D, Dv, causal, window, cap, bq, bk)
    (2, 64, 64, 4, 2, 32, 32, True, 0, 0.0, 16, 16),
    (1, 50, 50, 8, 1, 16, 24, True, 16, 0.0, 16, 16),   # window+ragged+Dv
    (2, 32, 32, 4, 4, 64, 64, True, 0, 30.0, 32, 32),   # softcap
    (1, 24, 48, 2, 2, 32, 32, False, 0, 0.0, 8, 16),    # cross attention
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_kernel_vs_oracle(shape, dtype):
    from repro.kernels.flash_prefill import flash_prefill
    from repro.models.common import attention_reference
    B, S, Skv, H, Hkv, D, Dv, causal, win, cap, bq, bk = shape
    rng = np.random.default_rng(hash(shape) % 2 ** 31)  # order-independent
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Skv, Hkv, Dv)), dtype)
    lens = jnp.asarray(rng.integers(Skv // 2, Skv + 1, (B,)))
    a = flash_prefill(q, k, v, causal=causal, window=win, attn_softcap=cap,
                      kv_len=lens, block_q=bq, block_k=bk, interpret=True)
    b = attention_reference(q, k, v, causal=causal, window=win,
                            attn_softcap=cap, kv_len=lens)
    # fully-masked rows (q beyond kv_len+window) are don't-care: the kernel
    # returns 0, the reference's softmax-of-neg-inf returns mean(v)
    qp = np.arange(S)[None, :]
    kp = np.arange(Skv)
    m = kp[None, None, :] < np.asarray(lens)[:, None, None]
    if causal:
        cm = kp[None, None, :] <= qp[..., None]
        if win:
            cm &= kp[None, None, :] > (qp[..., None] - win)
        m = m & cm
    has_ctx = np.broadcast_to(m.any(-1), (B, S))         # (B, S)
    tol = 3e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(a, np.float32)[has_ctx],
                               np.asarray(b, np.float32)[has_ctx],
                               rtol=tol, atol=tol)


def test_ops_dispatch_cpu_uses_ref(rng):
    """On CPU, ops.* auto-dispatch must hit the jnp reference path (fast),
    with identical results to the interpret kernel."""
    B, H, Hkv, D, W = 1, 4, 2, 32, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, W, Hkv, D)), jnp.float32)
    valid = jnp.ones((B, W), bool)
    o_auto = combine_partials(*ops.gqa_decode(q, k, v, valid, scale=1.0))
    o_int = combine_partials(*ops.gqa_decode(q, k, v, valid, scale=1.0,
                                             impl="interpret"))
    np.testing.assert_allclose(o_auto, o_int, rtol=2e-5, atol=2e-5)

"""Sharding plans (spec construction, divisibility, expert-axis choice)
plus a REAL multi-device numerics check in a subprocess (8 fake host
devices — isolated so the main pytest process keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.distributed import sharding as SH


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_divisibility_guard(mesh1):
    # vocab 51865 (whisper) is odd -> must not shard even on a 1-wide axis
    # (guard is size-based; on width-1 axes everything divides, so check
    # the rule table instead on a fat fake mesh via spec_for_axes)
    import numpy as np
    mesh = jax.make_mesh((1,), ("model",))
    rules = {"vocab": "model"}
    spec = SH.spec_for_axes(("vocab", "embed"), (51865, 768), rules, mesh)
    assert spec == P(None) or spec == P("model")  # width-1: trivially ok


def test_expert_axis_choice():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        def __init__(self, sizes):
            self.shape = sizes
            self.axis_names = tuple(sizes)
    m = FakeMesh({"data": 16, "model": 16})
    axes, ffn_data = SH.expert_sharding_for(get_config("deepseek-v3-671b"), m)
    assert axes == ("data", "model") and not ffn_data
    axes, ffn_data = SH.expert_sharding_for(get_config("moonshot-v1-16b-a3b"), m)
    assert axes == ("model",)
    axes, ffn_data = SH.expert_sharding_for(
        get_config("jamba-1.5-large-398b"), m)
    assert axes == ("model",) and ffn_data    # 43GB/chip slice -> shard ffn
    axes, _ = SH.expert_sharding_for(get_config("mixtral-8x7b"), m)
    assert axes == ()                          # 8 experts can't split 16


def test_make_plan_smoke(mesh1):
    cfg = get_config("mixtral-8x7b")
    plan = SH.make_plan(cfg, get_shape("decode_32k"), mesh1)
    assert plan.moe_variant in ("grouped_pjit", "ep_psum")
    leaves = jax.tree.leaves(
        plan.param_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert all(isinstance(s, jax.sharding.PartitionSpec) for s in leaves)


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config, get_shape
from repro.distributed import sharding as SH
from repro.models.inputs import concrete_inputs
from repro.models.params import init_params
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step

cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(), dtype="float32",
                          num_experts=8, top_k=2, capacity_factor=8.0)
shape = get_shape("train_4k").smoke()
batch = concrete_inputs(cfg, shape)
params = init_params(cfg, jax.random.key(0))
opt = OptConfig(warmup_steps=1)
opt_state = init_opt_state(params, opt)

# single-device reference
ref_step = jax.jit(make_train_step(cfg, opt, None))
_, _, m_ref = ref_step(params, opt_state, batch)

# 2x4 mesh with the production sharding plan (ep paths exercised)
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = SH.make_plan(cfg, shape, mesh, remat=False)
named = lambda tree: jax.tree.map(
    lambda s: jax.sharding.NamedSharding(mesh, s), tree,
    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
p_sh = named(plan.param_specs)
params_d = jax.device_put(params, p_sh)
opt_d = {"mu": jax.device_put(opt_state["mu"], p_sh),
         "nu": jax.device_put(opt_state["nu"], p_sh),
         "step": opt_state["step"]}
b_sh = named(SH.batch_specs(batch, plan.dp_axes))
batch_d = jax.device_put(batch, b_sh)
step = jax.jit(make_train_step(cfg, opt, plan.policy),
               in_shardings=(p_sh, {"mu": p_sh, "nu": p_sh, "step": None},
                             b_sh),
               out_shardings=(p_sh, {"mu": p_sh, "nu": p_sh, "step": None},
                              None))
_, _, m_dist = step(params_d, opt_d, batch_d)
print(json.dumps({"ref": float(m_ref["loss"]), "dist": float(m_dist["loss"]),
                  "variant": plan.moe_variant,
                  "gref": float(m_ref["grad_norm"]),
                  "gdist": float(m_dist["grad_norm"])}))
"""


@pytest.mark.slow
def test_multidevice_train_step_matches_single(tmp_path):
    """8 fake devices, MoE arch on the production sharding plan: the
    distributed loss/grad-norm must match the single-device reference."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert abs(out["ref"] - out["dist"]) / out["ref"] < 2e-3, out
    assert abs(out["gref"] - out["gdist"]) / out["gref"] < 2e-2, out


DECODE2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, get_shape
from repro.distributed import sharding as SH
from repro.models import kvcache
from repro.models.params import init_params
from repro.serving.steps import make_serve_step

cfg = dataclasses.replace(get_config("jamba-1.5-large-398b").smoke(),
                          dtype="float32", num_experts=4, top_k=2,
                          capacity_factor=8.0)
B, S = 4, 32
params = init_params(cfg, jax.random.key(0))
cache = kvcache.init_cache(cfg, B, S, dtype=jnp.float32)
cache["pos"] = jnp.full((B,), 7, jnp.int32)
toks = jnp.ones((B, 1), jnp.int32) * 5
tok_ref, logits_ref, _ = jax.jit(make_serve_step(cfg, None))(
    params, cache, toks)
mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = dataclasses.replace(get_shape("decode_32k"), global_batch=B,
                            seq_len=S)
plan = SH.make_plan(cfg, shape, mesh, decode_2d=True)
named = lambda t: jax.tree.map(
    lambda s: jax.sharding.NamedSharding(mesh, s), t,
    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
p_sh, c_sh = named(plan.param_specs), named(
    SH.cache_specs(cfg, cache, plan.dp_axes, plan.kv_axes, plan.rules, mesh))
step = jax.jit(make_serve_step(cfg, plan.policy),
               in_shardings=(p_sh, c_sh, jax.sharding.NamedSharding(
                   mesh, jax.sharding.PartitionSpec())),
               out_shardings=(None, None, c_sh))
_, logits_d, _ = step(jax.device_put(params, p_sh),
                      jax.device_put(cache, c_sh), toks)
rel = float(jnp.max(jnp.abs(logits_d - logits_ref))) / \
    float(jnp.max(jnp.abs(logits_ref)))
print(json.dumps({"rel": rel}))
"""


@pytest.mark.slow
def test_decode_2d_stationary_weights_matches_single():
    """The 2D stationary-weights decode plan (batch replicated, weights
    sharded over data x model, activation psums) must be numerically
    identical to the single-device decode (hybrid MoE arch)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", DECODE2D_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["rel"] < 2e-4, out

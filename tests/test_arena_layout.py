"""Head-major (bt-major) arena layout property suite.

The paged KV arena stores its kv leaves head-major — k/v
``(Hkv, NB, bt, D)``, scales ``(Hkv, NB, bt)`` (``kvcache`` layout
block) — so a (block, head) DMA is a contiguous ``(bt, D)`` slab for
every block size.  This suite pins the layout helpers (retile/untile
round-trip identity, block-axis bookkeeping), proves the paged
scatter/gather path **bit-identical** to the dense ring across
``bt ∈ {4, 8, 16, 32}`` × int8 × MLA (including ring wrap), and proves
the fused decode-write dispatchers (``ops.paged_*_decode_fused`` — the
kernel merges the fresh token into its gathered tile in-register)
bit-identical to write-then-attend in both the interpret-kernel and ref
impls, including the ring-wrap overwrite and the unmapped-target
(trash-block) cases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.models import kvcache

BTS = (4, 8, 16, 32)


# ---------------------------------------------------------------------------
# Layout helpers: round trip + axis bookkeeping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stacked", [False, True])
def test_retile_untile_round_trip(stacked):
    rng = np.random.default_rng(0)
    NB, bt, Hkv, D = 5, 4, 2, 8
    lead = (3,) if stacked else ()
    cases = {
        "k": lead + (NB, bt, Hkv, D),
        "v": lead + (NB, bt, Hkv, D),
        "k_scale": lead + (NB, bt, Hkv),
        "v_scale": lead + (NB, bt, Hkv),
        "slot_pos": lead + (NB, bt),          # no head axis: identity
        "ckv": lead + (NB, bt, 16),
        "kr": lead + (NB, bt, 8),
    }
    for name, shape in cases.items():
        a = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        r = kvcache.retile_arena_leaf(name, a, stacked=stacked)
        # the block axis lands where arena_block_axis says
        ax = kvcache.arena_block_axis(name, stacked=stacked)
        assert r.shape[ax] == NB, (name, r.shape, ax)
        back = kvcache.untile_arena_leaf(name, r, stacked=stacked)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(a))
        if name not in ("k", "v", "k_scale", "v_scale"):
            assert r.shape == a.shape        # identity for head-free leaves


def test_init_paged_arena_head_major_shapes():
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    NB, bt = 6, 4
    arena = kvcache.init_paged_arena(cfg, NB, bt)
    P, Hkv, Dh = cfg.num_periods, cfg.num_kv_heads, cfg.head_dim
    for key, g in arena.items():
        assert g["k"].shape == (P, Hkv, NB + 1, bt, Dh)
        assert g["v"].shape == (P, Hkv, NB + 1, bt, Dh)
        assert g["slot_pos"].shape == (P, NB + 1, bt)
    int8 = dataclasses.replace(cfg, kv_dtype="int8")
    g = next(iter(kvcache.init_paged_arena(int8, NB, bt).values()))
    assert g["k_scale"].shape == (P, Hkv, NB + 1, bt)
    mla = dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                              dtype="float32")
    g = next(iter(kvcache.init_paged_arena(mla, NB, bt).values()))
    assert g["ckv"].shape == (mla.num_periods, NB + 1, bt, mla.kv_lora_rank)


# ---------------------------------------------------------------------------
# Paged scatter/gather ≡ dense ring, every bt × int8 × MLA, incl. wrap
# ---------------------------------------------------------------------------

def _paired_caches(rng, B, MB, bt, Hkv, D, *, int8=False, mla=False,
                   lat=16, dr=8):
    """A dense ring cache and a fully-mapped paged cache (row b owns
    physical blocks [b·MB, (b+1)·MB), permuted) over the same W."""
    W = MB * bt
    NB = B * MB + 1
    perm = rng.permutation(B * MB)
    pt = perm.reshape(B, MB).astype(np.int32)
    if mla:
        dense = {"ckv": jnp.zeros((B, W, lat)), "kr": jnp.zeros((B, W, dr)),
                 "slot_pos": jnp.full((B, W), -1, jnp.int32)}
        arena = {"ckv": jnp.zeros((NB, bt, lat)),
                 "kr": jnp.zeros((NB, bt, dr))}
    elif int8:
        dense = {"k": jnp.zeros((B, W, Hkv, D), jnp.int8),
                 "v": jnp.zeros((B, W, Hkv, D), jnp.int8),
                 "k_scale": jnp.zeros((B, W, Hkv)),
                 "v_scale": jnp.zeros((B, W, Hkv)),
                 "slot_pos": jnp.full((B, W), -1, jnp.int32)}
        arena = {
            "k": kvcache.retile_arena_leaf(
                "k", jnp.zeros((NB, bt, Hkv, D), jnp.int8)),
            "v": kvcache.retile_arena_leaf(
                "v", jnp.zeros((NB, bt, Hkv, D), jnp.int8)),
            "k_scale": kvcache.retile_arena_leaf(
                "k_scale", jnp.zeros((NB, bt, Hkv))),
            "v_scale": kvcache.retile_arena_leaf(
                "v_scale", jnp.zeros((NB, bt, Hkv)))}
    else:
        dense = {"k": jnp.zeros((B, W, Hkv, D)),
                 "v": jnp.zeros((B, W, Hkv, D)),
                 "slot_pos": jnp.full((B, W), -1, jnp.int32)}
        arena = {"k": kvcache.retile_arena_leaf(
                     "k", jnp.zeros((NB, bt, Hkv, D))),
                 "v": kvcache.retile_arena_leaf(
                     "v", jnp.zeros((NB, bt, Hkv, D)))}
    arena["slot_pos"] = jnp.full((NB, bt), -1, jnp.int32)
    arena["page_table"] = jnp.asarray(pt)
    return dense, arena


def _new_token(rng, B, Hkv, D, *, int8=False, mla=False, lat=16, dr=8):
    if mla:
        return {"ckv": jnp.asarray(rng.normal(size=(B, 1, lat)),
                                   jnp.float32),
                "kr": jnp.asarray(rng.normal(size=(B, 1, dr)), jnp.float32)}
    k = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)), jnp.float32)
    return kvcache.quantize_kv(k, v) if int8 else {"k": k, "v": v}


@pytest.mark.parametrize("bt", BTS)
@pytest.mark.parametrize("kind", ["f32", "int8", "mla"])
def test_paged_decode_scatter_matches_dense_ring(bt, kind):
    """write_decode_paged through the head-major arena, viewed densely,
    is bit-identical to write_decode on a plain ring — for every decode
    position through a full wrap of the ring."""
    rng = np.random.default_rng(hash((bt, kind)) % 2 ** 31)
    B, MB, Hkv, D = 2, 3, 2, 8
    W = MB * bt
    dense, paged = _paired_caches(rng, B, MB, bt, Hkv, D,
                                  int8=kind == "int8", mla=kind == "mla")
    # wrap past W to cover the ring-overwrite path; sparse probe points
    # keep the walk cheap for large bt
    probes = sorted({0, 1, bt - 1, bt, W // 2, W - 1, W, W + bt // 2})
    for t in range(W + bt // 2 + 1):
        new = _new_token(rng, B, Hkv, D, int8=kind == "int8",
                         mla=kind == "mla")
        pos = jnp.full((B,), t, jnp.int32)
        dense = kvcache.write_decode(dense, new, pos)
        paged = kvcache.write_decode_paged(paged, new, pos)
        if t in probes:
            ring = kvcache.paged_view(paged)
            for name in dense:
                np.testing.assert_array_equal(
                    np.asarray(ring[name]), np.asarray(dense[name]),
                    err_msg=f"{kind} bt={bt} t={t} leaf={name}")


def test_paged_view_unmapped_blocks_invisible():
    """An unmapped logical block reads as slot_pos=-1 regardless of what
    the trash block holds."""
    rng = np.random.default_rng(7)
    _, paged = _paired_caches(rng, 2, 3, 4, 2, 8)
    pt = np.asarray(paged["page_table"]).copy()
    pt[1, 2] = -1
    paged["page_table"] = jnp.asarray(pt)
    paged["slot_pos"] = paged["slot_pos"].at[-1].set(5)   # poisoned trash
    ring = kvcache.paged_view(paged)
    assert (np.asarray(ring["slot_pos"])[1, 2 * 4:3 * 4] == -1).all()


# ---------------------------------------------------------------------------
# Fused decode-write ≡ write-then-attend, bit-exact
# ---------------------------------------------------------------------------

def _fill_paged(rng, paged, upto, B, Hkv, D, *, int8=False, mla=False):
    for t in range(upto):
        new = _new_token(rng, B, Hkv, D, int8=int8, mla=mla)
        paged = kvcache.write_decode_paged(
            paged, new, jnp.full((B,), t, jnp.int32))
    return paged


@pytest.mark.parametrize("bt", BTS)
@pytest.mark.parametrize("impl", ["interpret", "ref"])
@pytest.mark.parametrize("int8", [False, True])
def test_fused_gqa_bit_identical_to_write_then_attend(bt, impl, int8):
    rng = np.random.default_rng(hash((bt, impl, int8)) % 2 ** 31)
    B, MB, Hkv, G, D = 2, 3, 2, 2, 16
    W = MB * bt
    # positions probing mid-ring, block boundary, and the wrap overwrite
    for t in (bt - 1, W // 2, W, W + 1):
        _, paged = _paired_caches(rng, B, MB, bt, Hkv, D, int8=int8)
        paged = _fill_paged(rng, paged, t, B, Hkv, D, int8=int8)
        new = _new_token(rng, B, Hkv, D, int8=int8)
        pos = jnp.full((B,), t, jnp.int32)
        q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)), jnp.float32)
        part, fused_cache = ops.paged_gqa_decode_fused(
            q, paged, new, pos, scale=D ** -0.5, impl=impl)
        written = kvcache.write_decode_paged(paged, new, pos)
        ref_part = ops.paged_gqa_decode(q, written, pos, scale=D ** -0.5,
                                        impl=impl)
        for a, b, nm in zip(part, ref_part, ("o", "m", "l")):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"bt={bt} t={t} impl={impl} int8={int8} "
                        f"partial {nm}")
        for name in written:
            np.testing.assert_array_equal(
                np.asarray(fused_cache[name]), np.asarray(written[name]),
                err_msg=f"cache leaf {name}")


@pytest.mark.parametrize("impl", ["interpret", "ref"])
def test_fused_gqa_unmapped_target_matches(impl):
    """When the decode position's block is unmapped, write_decode_paged
    scatters into the trash block (never read) — the fused kernel must
    skip the in-tile merge identically."""
    rng = np.random.default_rng(21)
    B, MB, bt, Hkv, G, D = 2, 3, 8, 2, 2, 16
    t = MB * bt // 2
    _, paged = _paired_caches(rng, B, MB, bt, Hkv, D)
    paged = _fill_paged(rng, paged, t, B, Hkv, D)
    pt = np.asarray(paged["page_table"]).copy()
    pt[0, t // bt] = -1                    # row 0's target block unmapped
    paged["page_table"] = jnp.asarray(pt)
    new = _new_token(rng, B, Hkv, D)
    pos = jnp.full((B,), t, jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, D)), jnp.float32)
    part, fused_cache = ops.paged_gqa_decode_fused(
        q, paged, new, pos, scale=D ** -0.5, impl=impl)
    written = kvcache.write_decode_paged(paged, new, pos)
    ref_part = ops.paged_gqa_decode(q, written, pos, scale=D ** -0.5,
                                    impl=impl)
    for a, b in zip(part, ref_part):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in written:
        np.testing.assert_array_equal(np.asarray(fused_cache[name]),
                                      np.asarray(written[name]))


@pytest.mark.parametrize("bt", [4, 8, 16])
@pytest.mark.parametrize("impl", ["interpret", "ref"])
def test_fused_mla_bit_identical_to_write_then_attend(bt, impl):
    rng = np.random.default_rng(hash((bt, impl)) % 2 ** 31)
    B, MB, H, lat, dr = 2, 3, 4, 16, 8
    W = MB * bt
    for t in (bt - 1, W // 2, W):
        _, paged = _paired_caches(rng, B, MB, bt, 1, 8, mla=True,
                                  lat=lat, dr=dr)
        paged = _fill_paged(rng, paged, t, B, 1, 8, mla=True)
        new = _new_token(rng, B, 1, 8, mla=True, lat=lat, dr=dr)
        pos = jnp.full((B,), t, jnp.int32)
        qcat = jnp.asarray(rng.normal(size=(B, H, lat + dr)), jnp.float32)
        part, fused_cache = ops.paged_mla_decode_fused(
            qcat, paged, new, pos, scale=(lat + dr) ** -0.5, lat=lat,
            impl=impl)
        written = kvcache.write_decode_paged(paged, new, pos)
        ref_part = ops.paged_mla_decode(qcat, written, pos,
                                        scale=(lat + dr) ** -0.5, lat=lat,
                                        impl=impl)
        for a, b, nm in zip(part, ref_part, ("o", "m", "l")):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"bt={bt} t={t} impl={impl} partial {nm}")
        for name in written:
            np.testing.assert_array_equal(
                np.asarray(fused_cache[name]), np.asarray(written[name]))

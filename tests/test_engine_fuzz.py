"""Cross-mode fuzz: seeded random serving workloads must produce
bit-identical per-request greedy transcripts across every execution
strategy the engine offers — static whole-micro-batch, continuous
slot-pool at several decode-chunk sizes, overlapped chunked-prefill
admission at several prefill-chunk widths, EOS-aware (EWMA)
reservations with recompute preemption under a tight budget, and (on
the MoE config) the paged weight layouts: whole-layer streaming and
expert-granular residency in hit-heavy / miss-heavy / prefetch-off
regimes, module-based batching (decoupled attention/expert phases
accumulating num_ubs rotation groups per expert-weight stream), and the
intra-pass prediction + replication layer (gate-predictor prefetch,
intra-pass accounting, hot-expert replication — on × off × module-batch
× kv-paged × overlap × static) in every combination — continuous,
static, overlap, kv-paged, expert-paged, and the staging-capacity
fallback.  A small instance runs in the fast CI subset; the wide sweep
(more seeds, chunk sizes 1/4/8, early-EOS round, paged sweeps) carries
the `slow` marker."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(3))
    return cfg, params


def _workload(cfg, seed, n_requests, max_len=40, max_quota=10):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(1, max_len))),
             int(rng.integers(1, max_quota)))
            for _ in range(n_requests)]


def _run(cfg, params, work, **ecfg_kw):
    kw = dict(ubatch=3, num_ubs=2, max_seq=64)
    kw.update(ecfg_kw)
    eng = Engine(cfg, params, EngineConfig(**kw))
    for p, q in work:
        eng.submit(p, q)
    out = eng.run_until_idle()
    assert all(r.done for r in eng.scheduler.requests.values())
    return out


def _assert_all_identical(cfg, params, work, variants):
    outs = {name: _run(cfg, params, work, **kw)
            for name, kw in variants.items()}
    names = list(outs)
    base = outs[names[0]]
    for name in names[1:]:
        assert outs[name] == base, f"{name} diverged from {names[0]}"
    return base


def test_cross_mode_transcripts_identical_fast(setup):
    cfg, params = setup
    work = _workload(cfg, seed=0, n_requests=6)
    _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static"),
        "continuous": dict(decode_chunk=4),
        "overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4),
        "module": dict(decode_chunk=4, module_batch=True),
    })


def test_kv_paged_transcripts_identical_fast(setup):
    """KV tier regime must never change greedy output: dense rings,
    paged-resident (r_c=1), and paged with host-RAM spill agree."""
    cfg, params = setup
    work = _workload(cfg, seed=1, n_requests=6)
    _assert_all_identical(cfg, params, work, {
        "dense": dict(decode_chunk=4),
        "kv_resident": dict(decode_chunk=4, kv_paged=True, kv_gpu_ratio=1.0),
        "kv_spill": dict(decode_chunk=4, kv_paged=True, kv_gpu_ratio=0.25),
    })


@pytest.fixture(scope="module")
def moe_setup():
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(4))
    return cfg, params


def test_paged_expert_transcripts_identical_fast(moe_setup):
    """Weight layout / residency regime must never change greedy output:
    resident, whole-layer paged, and expert-granular (tight pool) agree."""
    cfg, params = moe_setup
    work = _workload(cfg, seed=0, n_requests=5, max_len=24, max_quota=8)
    _assert_all_identical(cfg, params, work, {
        "resident": dict(decode_chunk=4),
        "paged_layer": dict(decode_chunk=4, paged=True, page_elems=4096),
        "expert_tight": dict(decode_chunk=4, expert_paged=True,
                             page_elems=4096, w_gpu_ratio=0.25),
        "expert_module": dict(decode_chunk=4, expert_paged=True,
                              page_elems=4096, w_gpu_ratio=0.25,
                              module_batch=True),
        "expert_nopredict": dict(decode_chunk=4, expert_paged=True,
                                 page_elems=4096, w_gpu_ratio=0.25,
                                 predict=False, intra_pass=False),
        "expert_replicate": dict(decode_chunk=4, expert_paged=True,
                                 page_elems=4096, w_gpu_ratio=0.25,
                                 replicate_frac=0.5),
    })


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2])
def test_paged_expert_transcripts_identical_sweep(moe_setup, seed):
    cfg, params = moe_setup
    work = _workload(cfg, seed=seed, n_requests=8, max_len=32)
    _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static"),
        "resident": dict(decode_chunk=4),
        "paged_layer": dict(decode_chunk=4, paged=True, page_elems=4096),
        "expert_stream": dict(decode_chunk=4, expert_paged=True,
                              page_elems=4096, w_gpu_ratio=0.0),
        "expert_hit": dict(decode_chunk=4, expert_paged=True,
                           page_elems=4096, w_gpu_ratio=1.0),
        "expert_miss": dict(decode_chunk=4, expert_paged=True,
                            page_elems=4096, expert_slots=1),
        "expert_noprefetch": dict(decode_chunk=4, expert_paged=True,
                                  page_elems=4096, w_gpu_ratio=0.25,
                                  prefetch=False),
        "expert_overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                               expert_paged=True, page_elems=4096,
                               w_gpu_ratio=0.5),
        "expert_static": dict(mode="static", expert_paged=True,
                              page_elems=4096, w_gpu_ratio=0.25),
        "expert_ewma": dict(decode_chunk=4, expert_paged=True,
                            page_elems=4096, w_gpu_ratio=0.25,
                            reserve_mode="ewma", cache_tokens=100),
        "expert_module": dict(decode_chunk=4, expert_paged=True,
                              page_elems=4096, w_gpu_ratio=0.5,
                              module_batch=True),
        "expert_module_static": dict(mode="static", expert_paged=True,
                                     page_elems=4096, w_gpu_ratio=0.25,
                                     module_batch=True),
        "expert_module_noprefetch": dict(decode_chunk=4, expert_paged=True,
                                         page_elems=4096, w_gpu_ratio=0.25,
                                         prefetch=False, module_batch=True),
        # intra-pass prediction + replication: on x off x module-batch x
        # kv-paged x overlap x static — WHEN spans move must never change
        # WHAT is computed
        "expert_nopredict": dict(decode_chunk=4, expert_paged=True,
                                 page_elems=4096, w_gpu_ratio=0.25,
                                 predict=False),
        "expert_pr3_accounting": dict(decode_chunk=4, expert_paged=True,
                                      page_elems=4096, w_gpu_ratio=0.25,
                                      predict=False, intra_pass=False),
        "expert_replicate": dict(decode_chunk=4, expert_paged=True,
                                 page_elems=4096, w_gpu_ratio=0.25,
                                 predict=False, replicate_frac=0.5),
        "expert_predict_replicate": dict(decode_chunk=4, expert_paged=True,
                                         page_elems=4096, w_gpu_ratio=0.25,
                                         replicate_frac=0.5),
        "expert_predict_module": dict(decode_chunk=4, expert_paged=True,
                                      page_elems=4096, w_gpu_ratio=0.25,
                                      replicate_frac=0.5,
                                      module_batch=True),
        "expert_predict_kv": dict(decode_chunk=4, expert_paged=True,
                                  page_elems=4096, w_gpu_ratio=0.25,
                                  replicate_frac=0.5, kv_paged=True,
                                  kv_gpu_ratio=0.25),
        "expert_predict_overlap": dict(overlap=True, prefill_chunk=8,
                                       decode_chunk=4, expert_paged=True,
                                       page_elems=4096, w_gpu_ratio=0.25,
                                       replicate_frac=0.5),
        "expert_predict_static": dict(mode="static", expert_paged=True,
                                      page_elems=4096, w_gpu_ratio=0.25,
                                      replicate_frac=0.5),
    })


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2])
def test_kv_paged_transcripts_identical_sweep(setup, seed):
    """Wide paged-KV sweep: block sizes, tier ratios (incl. the
    everything-spills r_c=0 floor), static admission booked against the
    arena, overlapped staged prefill landing in mapped blocks, EWMA
    preemption composing with arena-exhaustion preemption, and prefetch
    off — all bit-identical to dense rings."""
    cfg, params = setup
    work = _workload(cfg, seed=seed, n_requests=8)
    _assert_all_identical(cfg, params, work, {
        "dense": dict(decode_chunk=4),
        "kv_bt8": dict(decode_chunk=4, kv_paged=True, block_tokens=8,
                       kv_gpu_ratio=0.25),
        "kv_bt32": dict(decode_chunk=4, kv_paged=True, block_tokens=32,
                        kv_gpu_ratio=0.5),
        "kv_floor": dict(decode_chunk=4, kv_paged=True, kv_gpu_ratio=0.0),
        "kv_static": dict(mode="static", kv_paged=True, kv_gpu_ratio=0.25),
        "kv_overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                           kv_paged=True, kv_gpu_ratio=0.25),
        "kv_ewma": dict(reserve_mode="ewma", cache_tokens=100,
                        decode_chunk=4, kv_paged=True, kv_gpu_ratio=0.25),
        "kv_noprefetch": dict(decode_chunk=4, kv_paged=True,
                              kv_gpu_ratio=0.25, kv_prefetch=False),
        "kv_module": dict(decode_chunk=4, kv_paged=True, kv_gpu_ratio=0.25,
                          module_batch=True),
        "kv_module_static": dict(mode="static", kv_paged=True,
                                 kv_gpu_ratio=0.25, module_batch=True),
    })


@pytest.mark.slow
def test_kv_paged_with_expert_paged(moe_setup):
    """Both paging subsystems at once: expert-granular weights through
    the residency pool AND block-paged KV through the host tier."""
    cfg, params = moe_setup
    work = _workload(cfg, seed=3, n_requests=6, max_len=24, max_quota=8)
    _assert_all_identical(cfg, params, work, {
        "resident": dict(decode_chunk=4),
        "both_paged": dict(decode_chunk=4, expert_paged=True,
                           page_elems=4096, w_gpu_ratio=0.25,
                           kv_paged=True, kv_gpu_ratio=0.25),
        "both_paged_module": dict(decode_chunk=4, expert_paged=True,
                                  page_elems=4096, w_gpu_ratio=0.25,
                                  kv_paged=True, kv_gpu_ratio=0.25,
                                  module_batch=True),
    })


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_cross_mode_transcripts_identical_sweep(setup, seed):
    cfg, params = setup
    work = _workload(cfg, seed=seed, n_requests=8)
    base = _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static"),
        "continuous_c1": dict(decode_chunk=1),
        "continuous_c4": dict(decode_chunk=4),
        "continuous_c8": dict(decode_chunk=8),
        "overlap_p4": dict(overlap=True, prefill_chunk=4, decode_chunk=4),
        "overlap_p16": dict(overlap=True, prefill_chunk=16, decode_chunk=8),
        "ewma_tight": dict(reserve_mode="ewma", cache_tokens=100,
                           decode_chunk=4),
        "overlap_ewma": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                             reserve_mode="ewma", cache_tokens=100),
        "kv_spill": dict(decode_chunk=4, kv_paged=True, kv_gpu_ratio=0.25),
        "module": dict(decode_chunk=4, module_batch=True),
        "module_overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                               module_batch=True),
        "module_static": dict(mode="static", module_batch=True),
        "module_stage_cap": dict(decode_chunk=4, module_batch=True,
                                 module_stage_tokens=3),
    })
    # early-EOS round: pick a token observed mid-transcript and re-run
    # with it as eos_id, so EOS-terminated rows are exercised everywhere
    eos_id = next((toks[len(toks) // 2] for toks in base.values()
                   if len(toks) >= 2), None)
    if eos_id is None:
        return
    work = [(p, q + 2) for p, q in work]     # leave room to EOS early
    _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static", eos_id=eos_id),
        "continuous": dict(decode_chunk=4, eos_id=eos_id),
        "overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                        eos_id=eos_id),
    })

"""Cross-mode fuzz: seeded random serving workloads must produce
bit-identical per-request greedy transcripts across every execution
strategy the engine offers — static whole-micro-batch, continuous
slot-pool at several decode-chunk sizes, overlapped chunked-prefill
admission at several prefill-chunk widths, and EOS-aware (EWMA)
reservations with recompute preemption under a tight budget.  A small
instance runs in the fast CI subset; the wide sweep (more seeds, chunk
sizes 1/4/8, early-EOS round) carries the `slow` marker."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(3))
    return cfg, params


def _workload(cfg, seed, n_requests, max_len=40, max_quota=10):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(1, max_len))),
             int(rng.integers(1, max_quota)))
            for _ in range(n_requests)]


def _run(cfg, params, work, **ecfg_kw):
    kw = dict(ubatch=3, num_ubs=2, max_seq=64)
    kw.update(ecfg_kw)
    eng = Engine(cfg, params, EngineConfig(**kw))
    for p, q in work:
        eng.submit(p, q)
    out = eng.run_until_idle()
    assert all(r.done for r in eng.scheduler.requests.values())
    return out


def _assert_all_identical(cfg, params, work, variants):
    outs = {name: _run(cfg, params, work, **kw)
            for name, kw in variants.items()}
    names = list(outs)
    base = outs[names[0]]
    for name in names[1:]:
        assert outs[name] == base, f"{name} diverged from {names[0]}"
    return base


def test_cross_mode_transcripts_identical_fast(setup):
    cfg, params = setup
    work = _workload(cfg, seed=0, n_requests=6)
    _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static"),
        "continuous": dict(decode_chunk=4),
        "overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4),
    })


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_cross_mode_transcripts_identical_sweep(setup, seed):
    cfg, params = setup
    work = _workload(cfg, seed=seed, n_requests=8)
    base = _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static"),
        "continuous_c1": dict(decode_chunk=1),
        "continuous_c4": dict(decode_chunk=4),
        "continuous_c8": dict(decode_chunk=8),
        "overlap_p4": dict(overlap=True, prefill_chunk=4, decode_chunk=4),
        "overlap_p16": dict(overlap=True, prefill_chunk=16, decode_chunk=8),
        "ewma_tight": dict(reserve_mode="ewma", cache_tokens=100,
                           decode_chunk=4),
        "overlap_ewma": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                             reserve_mode="ewma", cache_tokens=100),
    })
    # early-EOS round: pick a token observed mid-transcript and re-run
    # with it as eos_id, so EOS-terminated rows are exercised everywhere
    eos_id = next((toks[len(toks) // 2] for toks in base.values()
                   if len(toks) >= 2), None)
    if eos_id is None:
        return
    work = [(p, q + 2) for p, q in work]     # leave room to EOS early
    _assert_all_identical(cfg, params, work, {
        "static": dict(mode="static", eos_id=eos_id),
        "continuous": dict(decode_chunk=4, eos_id=eos_id),
        "overlap": dict(overlap=True, prefill_chunk=8, decode_chunk=4,
                        eos_id=eos_id),
    })

"""Continuous-batching slot-pool engine: slot lifecycle invariants,
masked-row emission, static/continuous greedy equivalence, incremental
Algorithm-2 placement, per-slot cache reset isolation, and the over-long
prompt guard."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.batching import place_request
from repro.models import kvcache
from repro.models.params import init_params
from repro.serving.engine import Engine, EngineConfig
from repro.serving.scheduler import Scheduler, SlotState


@pytest.fixture(scope="module")
def qwen_engine_setup():
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(3))
    return cfg, params


# ------------------------------------------------------------ lifecycle

def test_drained_slot_reused_by_next_queued_request(qwen_engine_setup):
    """More requests than slots: freed slots must be refilled mid-flight,
    and every slot transition must end back at FREE."""
    cfg, params = qwen_engine_setup
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=1, max_seq=64,
                                           decode_chunk=2))
    rng = np.random.default_rng(1)
    rids = [eng.submit(rng.integers(2, cfg.vocab_size, 4), 3)
            for _ in range(5)]
    out = eng.run_until_idle()
    assert set(out) == set(rids)
    assert all(len(v) == 3 for v in out.values())
    slots = [s for grp in eng.scheduler.slots for s in grp]
    # 5 requests over 2 slots: at least one slot served >= 3 requests
    assert sorted(len(s.history) for s in slots) == [2, 3]
    served = [rid for s in slots for rid in s.history]
    assert sorted(served) == sorted(rids)          # each rid exactly once
    assert all(s.state is SlotState.FREE for s in slots)


def test_masked_done_rows_never_emit(qwen_engine_setup):
    """Skewed max_new_tokens: rows that finish early are masked — each
    request gets exactly its quota, nothing more, and the engine's token
    count matches the transcripts."""
    cfg, params = qwen_engine_setup
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4))
    rng = np.random.default_rng(2)
    quotas = [2, 11, 3, 9, 2, 7]
    rids = [eng.submit(rng.integers(2, cfg.vocab_size, 6), q)
            for q in quotas]
    out = eng.run_until_idle()
    for rid, q in zip(rids, quotas):
        assert len(out[rid]) == q, (rid, q, out[rid])
    # tokens_out counts decode emissions: everything but the prefill token
    assert eng.tokens_out == sum(q - 1 for q in quotas)


def test_static_and_continuous_greedy_identical(qwen_engine_setup):
    """The tentpole invariant: per-request greedy transcripts must be
    bit-identical between whole-micro-batch (static) and slot-pool
    (continuous) execution, across mixed lengths and quotas."""
    cfg, params = qwen_engine_setup
    rng = np.random.default_rng(3)
    lens = (5, 9, 3, 7, 11, 6, 14)
    quotas = (3, 9, 5, 9, 2, 7, 4)
    prompts = [rng.integers(2, cfg.vocab_size, n) for n in lens]
    outs = {}
    for mode in ("static", "continuous"):
        eng = Engine(cfg, params,
                     EngineConfig(ubatch=3, num_ubs=2, max_seq=64,
                                  mode=mode, decode_chunk=4))
        for p, q in zip(prompts, quotas):
            eng.submit(p, q)
        outs[mode] = eng.run_until_idle()
    assert outs["static"] == outs["continuous"]


def test_continuous_paged_matches_resident(qwen_engine_setup):
    cfg, params = qwen_engine_setup
    prompts = [np.arange(2, 9), np.arange(3, 6), np.arange(2, 12)]
    outs = []
    for paged in (False, True):
        eng = Engine(cfg, params, EngineConfig(ubatch=3, num_ubs=1,
                                               max_seq=64, paged=paged,
                                               decode_chunk=3))
        for p in prompts:
            eng.submit(p, 5)
        outs.append(eng.run_until_idle())
    assert outs[0] == outs[1]


def test_static_admission_books_against_block_arena(qwen_engine_setup):
    """The ROADMAP's static-mode over-allocation note, resolved: with the
    paged pool, every static admission books its rows' blocks against the
    shared arena — a deep queue can never allocate device KV beyond the
    arena (the old failure was silent over-allocation past the policy
    budget), and drained batches give every block back."""
    cfg, params = qwen_engine_setup
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           mode="static", kv_paged=True,
                                           kv_gpu_ratio=0.5))
    rng = np.random.default_rng(7)
    for _ in range(11):                        # deep queue vs 4 slots
        eng.submit(rng.integers(2, cfg.vocab_size, int(rng.integers(3, 30))),
                   int(rng.integers(1, 8)))
    out = eng.run_until_idle()
    assert all(r.done for r in eng.scheduler.requests.values())
    assert sum(len(v) for v in out.values()) > 0
    # arena invariant: occupancy peaked at or below the device arena, and
    # every block was released when its micro-batch retired
    assert eng._kv.peak_in_use <= eng._kv.device_blocks
    assert eng._kv.in_use_device() == 0
    eng._kv.check_invariants()
    # and the whole pool honors the r_c sizing (ubatch-floor aside)
    total = 2 * 2 * (64 // eng.ecfg.block_tokens)
    assert eng._kv.device_blocks == max(2 * (64 // eng.ecfg.block_tokens),
                                        round(0.5 * total))


# ------------------------------------------------ long-prompt guard

def test_long_prompt_rejected_not_crashing(qwen_engine_setup):
    cfg, params = qwen_engine_setup
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=1, max_seq=32))
    rng = np.random.default_rng(4)
    rid_bad = eng.submit(rng.integers(2, cfg.vocab_size, 100), 4)
    # passes the raw length check but prompt+generation would wrap the ring
    rid_wrap = eng.submit(rng.integers(2, cfg.vocab_size, 30), 8)
    rid_ok = eng.submit(rng.integers(2, cfg.vocab_size, 8), 4)
    out = eng.run_until_idle()
    for rid in (rid_bad, rid_wrap):
        req = eng.scheduler.requests[rid]
        assert req.aborted and req.done
        assert out[rid] == []
    assert len(out[rid_ok]) == 4


def test_long_prompt_truncated_with_flag(qwen_engine_setup):
    cfg, params = qwen_engine_setup
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=1, max_seq=32,
                                           on_long_prompt="truncate"))
    rng = np.random.default_rng(5)
    rid = eng.submit(rng.integers(2, cfg.vocab_size, 100), 4)
    out = eng.run_until_idle()
    req = eng.scheduler.requests[rid]
    assert not req.aborted
    # prompt trimmed so prompt + generation fits the ring without wrapping
    assert req.input_len == 32 - 4
    assert len(out[rid]) == 4


# ------------------------------------- incremental Algorithm-2 placement

def test_place_request_balance_criterion():
    # least-loaded open partition wins
    assert place_request(10, [50, 20, 40], [2, 1, 2],
                         gen_len=8, cache_size=1000) == 1
    # closed partitions are skipped even when least loaded
    assert place_request(10, [50, 20, 40], [2, 1, 2], gen_len=8,
                         cache_size=1000,
                         open_mask=[True, False, True]) == 2
    # budget: sum + input + (1+count)*gen_len must fit
    assert place_request(10, [0], [0], gen_len=8, cache_size=17) is None
    assert place_request(10, [0], [0], gen_len=8, cache_size=18) == 0
    # nothing open
    assert place_request(10, [0, 0], [0, 0], gen_len=8, cache_size=100,
                         open_mask=[False, False]) is None
    # per-request reservation overrides the uniform gen_len for the
    # candidate (co-residents' reservations folded into partition_sums)
    assert place_request(10, [24], [1], gen_len=0, reserve=4,
                         cache_size=38) == 0
    assert place_request(10, [24], [1], gen_len=0, reserve=4,
                         cache_size=37) is None


def test_scheduler_aborts_never_fitting_request():
    s = Scheduler(ubatch=2, num_ubs=1, cache_tokens=40, gen_len=32,
                  max_input_len=None)
    rid = s.submit(np.arange(20, dtype=np.int32), 25)   # 20 + 25 > 40
    assigned = s.admit_to_slots()
    assert assigned == []
    assert s.requests[rid].aborted


def test_continuous_reserves_per_request_quota_not_uniform_gen_len():
    """A small-quota request must be admitted even when the batch-mode
    uniform gen_len=32 reservation would not fit (continuous admission
    reserves each request's own max_new_tokens)."""
    s = Scheduler(ubatch=1, num_ubs=1, cache_tokens=40, gen_len=32,
                  max_input_len=None)
    rid = s.submit(np.arange(20, dtype=np.int32), 4)    # 20 + 4 <= 40
    assigned = s.admit_to_slots()
    assert [sl.req.rid for sl in assigned] == [rid]
    assert not s.requests[rid].aborted


def test_static_admit_also_aborts_never_fitting_request():
    """Batch-mode admission must not re-queue a request that can never
    fit an empty partition (it would spin in the queue forever)."""
    s = Scheduler(ubatch=2, num_ubs=1, cache_tokens=40, gen_len=32,
                  max_input_len=None)
    rid_bad = s.submit(np.arange(20, dtype=np.int32), 4)    # 20 + 32 > 40
    rid_ok = s.submit(np.arange(4, dtype=np.int32), 4)      # 4 + 32 <= 40
    groups = s.admit()
    assert [[r.rid for r in g] for g in groups] == [[rid_ok]]
    assert s.requests[rid_bad].aborted and s.requests[rid_bad].done
    assert s.queue == []


# --------------------------------------------------- per-slot cache ops

def test_reset_slot_isolates_neighbors(qwen_f32):
    cfg = qwen_f32
    B, W = 3, 16
    cache = kvcache.init_cache(cfg, B, W)
    # dirty every row
    cache["pos"] = jnp.asarray([3, 5, 7], jnp.int32)
    spec = cfg.period[0]
    lc = cache["p0"]
    dirty = jax.tree.map(lambda a: a + 1 if a.dtype != jnp.int32 else a, lc)
    dirty["slot_pos"] = jnp.zeros_like(lc["slot_pos"])
    cache["p0"] = dirty
    fresh = kvcache.init_cache(cfg, B, W)

    out = kvcache.reset_slot(cache, 1)
    # row 1 equals the fresh init row
    for name in ("k", "v", "slot_pos"):
        np.testing.assert_array_equal(np.asarray(out["p0"][name][:, 1]),
                                      np.asarray(fresh["p0"][name][:, 1]))
    assert int(out["pos"][1]) == 0
    # neighbors untouched
    for row in (0, 2):
        for name in ("k", "v", "slot_pos"):
            np.testing.assert_array_equal(
                np.asarray(out["p0"][name][:, row]),
                np.asarray(cache["p0"][name][:, row]))
        assert int(out["pos"][row]) == int(cache["pos"][row])


def test_insert_slot_writes_single_row(qwen_f32):
    cfg = qwen_f32
    pool = kvcache.init_cache(cfg, 3, 16)
    single = kvcache.init_cache(cfg, 1, 16)
    single["pos"] = jnp.asarray([4], jnp.int32)
    single["p0"] = jax.tree.map(lambda a: a + 2, single["p0"])
    out = kvcache.insert_slot(pool, single, 2)
    for name in ("k", "v", "slot_pos"):
        np.testing.assert_array_equal(np.asarray(out["p0"][name][:, 2]),
                                      np.asarray(single["p0"][name][:, 0]))
        for row in (0, 1):
            np.testing.assert_array_equal(
                np.asarray(out["p0"][name][:, row]),
                np.asarray(pool["p0"][name][:, row]))
    assert int(out["pos"][2]) == 4

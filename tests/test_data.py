"""Data pipeline: determinism, host-disjointness, packing alignment,
prefetch liveness, skip-for-resume."""
import numpy as np

from repro.data.pipeline import (DataConfig, DataPipeline, SyntheticCorpus,
                                 pack_documents)


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=32, batch_size=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_stream():
    a = DataPipeline(_cfg())
    b = DataPipeline(_cfg())
    for _ in range(3):
        x, y = next(a), next(b)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    a.close(), b.close()


def test_hosts_draw_disjoint_streams():
    a = DataPipeline(_cfg(host_id=0, num_hosts=2))
    b = DataPipeline(_cfg(host_id=1, num_hosts=2))
    x, y = next(a), next(b)
    assert not np.array_equal(x["tokens"], y["tokens"])
    a.close(), b.close()


def test_targets_are_shifted_tokens():
    p = DataPipeline(_cfg())
    batch = next(p)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["targets"][:, :-1])
    p.close()


def test_packing_rows_exact_length():
    c = _cfg()
    rows = pack_documents(SyntheticCorpus(c).documents(), c.seq_len)
    for _ in range(5):
        assert len(next(rows)) == c.seq_len + 1


def test_skip_matches_sequential():
    a = DataPipeline(_cfg(), prefetch=1)
    for _ in range(3):
        ref = next(a)
    a.close()
    b = DataPipeline(_cfg(), prefetch=1)
    # note: prefetch already buffered batch 1; use direct skip before any next
    b2 = DataPipeline(_cfg(), prefetch=1)
    b2.close()
    # sequential draw of 3 batches equals 3rd batch of a fresh pipeline
    c = DataPipeline(_cfg(), prefetch=1)
    for _ in range(3):
        got = next(c)
    np.testing.assert_array_equal(got["tokens"], ref["tokens"])
    c.close(), b.close()

"""Deterministic seeded trace tests over the shared driver
(tests/scheduler_trace.py) — the non-hypothesis half of the scheduler
property suite, so the lifecycle invariants are exercised even where
hypothesis is unavailable — plus targeted unit tests for the EOS-aware
(EWMA) reservation path and recompute preemption."""
import numpy as np
import pytest

from repro.core.batching import GenLenEWMA
from repro.serving.scheduler import Scheduler, SlotState

from scheduler_trace import run_trace


def _eos_none(rid, k):
    return False


def _eos_hash(salt, mod):
    def draw(rid, k):
        return (rid * 2654435761 + k * 40503 + salt) % mod == 0
    return draw


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("reserve_mode", ["worst", "ewma"])
def test_random_traces_uphold_invariants(seed, reserve_mode):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 20))
    requests = [(int(rng.integers(1, 24)), int(rng.integers(1, 12)))
                for _ in range(n)]
    arrivals = sorted(int(rng.integers(0, 10)) for _ in range(n))
    res = run_trace(
        ubatch=int(rng.integers(1, 4)), num_ubs=int(rng.integers(1, 4)),
        cache_tokens=int(rng.integers(8, 64)), reserve_mode=reserve_mode,
        requests=requests, arrivals=arrivals,
        chunk=int(rng.integers(1, 8)), prefill_chunk=int(rng.integers(1, 8)),
        eos_draw=_eos_hash(seed, 5) if seed % 2 else _eos_none)
    assert len(res.served) + len(res.aborted) == n


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("reserve_mode", ["worst", "ewma"])
def test_shed_traces_drop_only_sheddable_work(seed, reserve_mode):
    """Seeded half of the admission-shed property (the hypothesis twin
    lives in test_scheduler_props.py): a shed window over a mixed-priority
    trace drops only new priority>=1 work, never anything with a
    transcript, and the trace still drains fully accounted."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(6, 20))
    requests = [(int(rng.integers(1, 24)), int(rng.integers(1, 12)))
                for _ in range(n)]
    arrivals = sorted(int(rng.integers(0, 12)) for _ in range(n))
    priorities = [int(rng.integers(0, 3)) for _ in range(n)]
    a = int(rng.integers(0, 8))
    res = run_trace(
        ubatch=int(rng.integers(1, 4)), num_ubs=int(rng.integers(1, 4)),
        cache_tokens=int(rng.integers(8, 64)), reserve_mode=reserve_mode,
        requests=requests, arrivals=arrivals,
        chunk=int(rng.integers(1, 8)), prefill_chunk=int(rng.integers(1, 8)),
        eos_draw=_eos_hash(seed, 5) if seed % 2 else _eos_none,
        priorities=priorities, shed_window=(a, a + int(rng.integers(0, 16))),
        shed_priority=1)
    assert len(res.served) + len(res.aborted) == n
    assert not set(res.shed) & set(res.served)


def test_ewma_tracks_observations():
    e = GenLenEWMA(alpha=0.5)
    assert e.expected(40) == 40                    # no signal: worst case
    e.observe(4)
    assert e.expected(40) == 4
    e.observe(12)                                  # 4 + 0.5*(12-4) = 8
    assert e.expected(40) == 8
    assert e.expected(6) == 6                      # capped at the quota
    assert e.expected(0) == 1                      # never below 1


def test_ewma_reservations_admit_more_concurrently():
    """After observing short generations, EOS-aware mode co-admits
    requests whose worst-case reservations would not fit together."""
    for mode, expect in (("worst", 1), ("ewma", 2)):
        s = Scheduler(ubatch=2, num_ubs=1, cache_tokens=40, gen_len=8,
                      reserve_mode=mode)
        s.gen_ewma.observe(4)
        for _ in range(2):
            s.submit(np.arange(10, dtype=np.int32), 25)   # worst: 35 each
        assert len(s.admit_to_slots()) == expect


def test_enforce_budget_preempts_youngest_and_requeues_fcfs():
    s = Scheduler(ubatch=2, num_ubs=1, cache_tokens=40, gen_len=8,
                  reserve_mode="ewma")
    s.gen_ewma.observe(2)                          # optimistic estimate
    r0 = s.submit(np.arange(10, dtype=np.int32), 25)
    r1 = s.submit(np.arange(10, dtype=np.int32), 25)
    slots = s.admit_to_slots()
    assert [sl.req.rid for sl in slots] == [r0, r1]
    for sl in slots:
        sl.req.generated.append(0)                 # prefill's first token
        s.start_decode(sl)
    # both run long: footprints 10+9 each; next chunk of 8 would need
    # 2*(19+8) = 54 > 40 -> the YOUNGEST must be evicted
    for sl in slots:
        sl.req.generated.extend([0] * 8)
    preempted = s.enforce_budget(0, chunk=8)
    assert [r.rid for r in preempted] == [r1]
    assert s.queue and s.queue[0].rid == r1        # re-queued at the head
    assert s.requests[r1].preemptions == 1
    assert len(s.requests[r1].generated) == 9      # transcript intact
    # its re-admission prefills prompt + transcript
    assert len(s.requests[r1].effective_prompt) == 19
    # survivor untouched; solo always fits, so no further eviction
    assert s.slots[0][0].req.rid == r0
    assert s.enforce_budget(0, chunk=8) == []


def test_preempted_request_keeps_fcfs_priority_over_later_arrivals():
    s = Scheduler(ubatch=1, num_ubs=1, cache_tokens=30, gen_len=8,
                  reserve_mode="ewma")
    r0 = s.submit(np.arange(4, dtype=np.int32), 20)
    (slot,) = s.admit_to_slots()
    slot.req.generated.append(0)
    s.start_decode(slot)
    r1 = s.submit(np.arange(4, dtype=np.int32), 20)   # arrives later
    s.preempt(slot)
    assert [r.rid for r in s.queue] == [r0, r1]


def test_prefill_progress_substate():
    s = Scheduler(ubatch=1, num_ubs=1, cache_tokens=64, gen_len=8)
    s.submit(np.arange(20, dtype=np.int32), 4)
    (slot,) = s.admit_to_slots()
    assert slot.state is SlotState.PREFILL and slot.prefill_pos == 0
    s.prefill_progress(slot, 8)
    s.prefill_progress(slot, 8)
    assert slot.prefill_pos == 16
    s.start_decode(slot)
    slot.req.generated.extend([0] * 4)
    s.finish(slot)
    assert slot.state is SlotState.FREE and slot.prefill_pos == 0
    assert s.gen_ewma.count == 1

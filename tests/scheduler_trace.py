"""Model-free scheduler trace driver shared by the property-based suite
(tests/test_scheduler_props.py, hypothesis) and the deterministic seeded
trace tests.  It emulates exactly the engine's per-tick contract against
a real Scheduler — staged chunked prefill, enforce_budget before every
group's decode chunk, masked advancement, EOS, recompute preemption —
and checks the lifecycle invariants after every tick:

  * per-group KV footprint never exceeds cache_tokens (both reservation
    modes; under "ewma" this is exactly what enforce_budget guarantees),
  * no slot double-occupancy: a live request sits in exactly one slot,
    a slot holds at most one request, and no live request is queued,
  * FCFS: every admission takes the current head of the queue,
  * abort-or-admit: the trace drains — every request ends done (served
    or EOS-shortened) or aborted; the queue head can never livelock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.serving.scheduler import Scheduler, SlotState


@dataclass
class TraceResult:
    served: List[int] = field(default_factory=list)     # rids finished
    aborted: List[int] = field(default_factory=list)
    shed: List[int] = field(default_factory=list)       # degraded-mode drops
    preemptions: int = 0
    ticks: int = 0
    max_group_footprint: int = 0


def _live(sched, gid):
    return [s for s in sched.slots[gid]
            if s.state in (SlotState.PREFILL, SlotState.DECODE)]


def check_invariants(sched: Scheduler, res: TraceResult) -> None:
    live_rids = []
    for gid in range(sched.num_ubs):
        occ = 0
        for s in _live(sched, gid):
            assert s.req is not None, "live slot without a request"
            live_rids.append(s.req.rid)
            assert not s.req.done and not s.req.aborted
            occ += s.req.footprint
        assert occ <= sched.cache_tokens, \
            f"group {gid} footprint {occ} > budget {sched.cache_tokens}"
        res.max_group_footprint = max(res.max_group_footprint, occ)
    assert len(live_rids) == len(set(live_rids)), "request in two slots"
    queued = [r.rid for r in sched.queue]
    assert len(queued) == len(set(queued)), "request queued twice"
    assert not set(queued) & set(live_rids), "request queued while live"
    for grp in sched.slots:
        for s in grp:
            if s.state in (SlotState.FREE, SlotState.DRAINED):
                assert s.req is None or s.state is SlotState.DRAINED
    # degraded-mode shedding may only drop NEW work: a request with any
    # transcript (admitted once, possibly preempted since) is never shed,
    # and protected priorities are never shed at any rung
    for r in sched.requests.values():
        if r.shed:
            assert not r.generated, "shed a request with a transcript"
            assert r.aborted and r.done


def run_trace(*, ubatch: int, num_ubs: int, cache_tokens: int,
              reserve_mode: str, requests: List[Tuple[int, int]],
              arrivals: List[int], chunk: int, prefill_chunk: int,
              eos_draw, priorities: Optional[List[int]] = None,
              shed_window: Optional[Tuple[int, int]] = None,
              shed_priority: int = 1,
              max_ticks: int = 2000) -> TraceResult:
    """Drive a Scheduler through a full serving trace.

    requests: (prompt_len, max_new_tokens) pairs; arrivals[i] is the tick
    request i is submitted on.  eos_draw(rid, k) -> bool decides whether
    the request hits EOS at its k-th generated token (1-based).
    shed_window=(a, b) turns degraded-mode admission shedding on for
    ticks a <= t < b (the ladder's admission_shed rung), dropping new
    work with priority >= shed_priority.  Returns the TraceResult after
    the system fully drains."""
    sched = Scheduler(ubatch=ubatch, num_ubs=num_ubs,
                      cache_tokens=cache_tokens, gen_len=8,
                      max_input_len=None, reserve_mode=reserve_mode)
    res = TraceResult()
    pending = sorted(range(len(requests)), key=lambda i: arrivals[i])
    prio = priorities or [0] * len(requests)
    rid_of = {}

    def finish(slot):
        res.served.append(slot.req.rid)
        sched.finish(slot)

    for tick in range(max_ticks):
        res.ticks = tick
        if shed_window is not None:
            sched.shed_priority = (shed_priority if shed_window[0] <= tick
                                   < shed_window[1] else None)
        while pending and arrivals[pending[0]] <= tick:
            i = pending.pop(0)
            n, q = requests[i]
            rid_of[i] = sched.submit(list(range(2, 2 + n)), q,
                                     priority=prio[i])

        queue_before = [r.rid for r in sched.queue]
        admitted = sched.admit_to_slots()
        # FCFS: admissions are exactly the head of the queue in order —
        # heads may be *aborted* (can never fit) but never skipped over
        placeable = [rid for rid in queue_before
                     if not sched.requests[rid].aborted]
        assert [s.req.rid for s in admitted] == \
            placeable[:len(admitted)], "admission skipped the queue head"

        # staged chunked prefill: drain prefill_chunk tokens per tick
        for grp in sched.slots:
            for s in grp:
                if s.state is not SlotState.PREFILL:
                    continue
                target = s.req.footprint          # prompt + prior transcript
                sched.prefill_progress(
                    s, min(prefill_chunk, target - s.prefill_pos))
                if s.prefill_pos >= target:       # final chunk: first token
                    s.req.generated.append(0)
                    if len(s.req.generated) >= s.req.max_new_tokens or \
                            eos_draw(s.req.rid, len(s.req.generated)):
                        finish(s)
                    else:
                        sched.start_decode(s)
        check_invariants(sched, res)

        # decode chunks, one per group, budget-guarded like the engine
        for gid in range(sched.num_ubs):
            preempted = sched.enforce_budget(gid, chunk)
            res.preemptions += len(preempted)
            if reserve_mode == "worst":
                assert not preempted, \
                    "worst-case reservations must never need preemption"
            for s in list(sched.slots[gid]):
                if s.state is not SlotState.DECODE:
                    continue
                for _ in range(min(chunk, s.req.remaining)):
                    s.req.generated.append(0)
                    if eos_draw(s.req.rid, len(s.req.generated)):
                        break
                if s.req.remaining == 0 or \
                        eos_draw(s.req.rid, len(s.req.generated)):
                    finish(s)
            check_invariants(sched, res)

        if not pending and not sched.queue and not sched.has_live_slots():
            break
    else:
        raise AssertionError("trace did not drain (livelock?)")

    res.aborted = [r.rid for r in sched.requests.values() if r.aborted]
    res.shed = [r.rid for r in sched.requests.values() if r.shed]
    # abort-or-admit: every request ended served or aborted, exactly once
    # (shed requests are a flavour of abort — counted there, flagged here)
    assert sorted(res.served + res.aborted) == sorted(rid_of.values())
    prio_of = {rid_of[i]: prio[i] for i in rid_of}
    for r in sched.requests.values():
        assert r.done
        if not r.aborted:
            assert 1 <= len(r.generated) <= r.max_new_tokens
        if r.shed:
            assert prio_of[r.rid] >= shed_priority, \
                "shed a protected-priority request"
    return res

"""MoE: router semantics, dense-vs-grouped equivalence, EP shard bodies on
a 1-device mesh, capacity drop accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import moe
from repro.models.params import init_params


def _moe_params(cfg, key=0):
    params = init_params(cfg, jax.random.key(key))
    return jax.tree.map(lambda a: a[0], params["blocks"]["p0"]["moe"])


@pytest.fixture(scope="module")
def mixtral_smoke():
    return dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                               dtype="float32")


def test_router_topk_and_weights(mixtral_smoke, rng):
    cfg = mixtral_smoke
    p = _moe_params(cfg)
    x = jnp.asarray(rng.normal(0, 1, (32, cfg.d_model)), jnp.float32)
    w, idx, aux = moe.route(cfg, p["router"], x)
    assert w.shape == (32, cfg.top_k) and idx.shape == (32, cfg.top_k)
    assert float(jnp.min(w)) >= 0
    # softmax routing: top-k weights sum <= 1
    assert float(jnp.max(jnp.sum(w, -1))) <= 1.0 + 1e-5
    # distinct experts per token
    assert bool(jnp.all(idx[:, 0] != idx[:, 1]))
    assert float(aux) >= 1.0 - 1e-3     # lower bound: perfectly balanced


def test_sigmoid_router_renormalizes(rng):
    cfg = dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                              dtype="float32")
    p = _moe_params(cfg)
    x = jnp.asarray(rng.normal(0, 1, (16, cfg.d_model)), jnp.float32)
    w, idx, _ = moe.route(cfg, p["router"], x)
    np.testing.assert_allclose(jnp.sum(w, -1), jnp.ones(16), rtol=1e-5)


def test_dense_vs_grouped(mixtral_smoke, rng):
    cfg = mixtral_smoke
    p = _moe_params(cfg)
    x = jnp.asarray(rng.normal(0, 0.5, (64, cfg.d_model)), jnp.float32)
    yd, _ = moe.moe_dense(cfg, p, x)
    yg, _ = moe.moe_grouped(cfg, p, x, capacity_factor=8.0)  # no drops
    np.testing.assert_allclose(yd, yg, rtol=2e-4, atol=2e-4)


def test_grouped_capacity_drops_reduce_output(mixtral_smoke, rng):
    cfg = mixtral_smoke
    p = _moe_params(cfg)
    x = jnp.asarray(rng.normal(0, 0.5, (64, cfg.d_model)), jnp.float32)
    y_full, _ = moe.moe_grouped(cfg, p, x, capacity_factor=8.0)
    y_tight, _ = moe.moe_grouped(cfg, p, x, capacity_factor=0.25)
    # tight capacity must drop some tokens' expert contributions
    assert float(jnp.max(jnp.abs(y_full - y_tight))) > 1e-6


@pytest.mark.parametrize("variant", ["ep_psum", "ep_a2a"])
def test_ep_bodies_match_dense_on_unit_mesh(mixtral_smoke, rng, variant):
    """With a single shard and drop-free capacity the EP bodies must agree
    with the dense oracle (all_to_all and psum are identities)."""
    cfg = mixtral_smoke
    p = _moe_params(cfg)
    x = jnp.asarray(rng.normal(0, 0.5, (4, 8, cfg.d_model)), jnp.float32)
    mesh = jax.make_mesh((1,), ("model",))
    from repro.distributed.collectives import make_moe_shard_fn
    fn = make_moe_shard_fn(mesh, cfg, variant=variant, dp_axes=(),
                           expert_axes=("model",), capacity_factor=8.0)
    y, aux = fn(cfg, p, x)
    yd, auxd = moe.moe_dense(cfg, p, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), yd,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(auxd), rtol=1e-3)


def test_shared_expert_added(rng):
    cfg = dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                              dtype="float32")
    p = _moe_params(cfg)
    x = jnp.asarray(rng.normal(0, 0.5, (8, cfg.d_model)), jnp.float32)
    y, _ = moe.moe_dense(cfg, p, x)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y2, _ = moe.moe_dense(cfg, p2, x)
    assert float(jnp.max(jnp.abs(y - y2))) > 1e-6

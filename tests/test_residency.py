"""Expert residency cache: hypothesis property suite over random
observe/pin/admit/evict traces (occupancy ≤ budget, slot bijection,
pinned spans never evicted, counters sum to total fetches), popularity
EWMA behavior, and the end-to-end transcript-identity guarantee —
greedy outputs bit-identical between whole-layer streaming and
expert-granular paging in hit-heavy and miss-heavy residency regimes on
the mixtral smoke config."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                          # CI installs it; the bare
    HAS_HYPOTHESIS = False                   # container runs the seeded
                                             # trace test below instead

from repro.core import residency


# ---------------------------------------------------------------------------
# Property suite on the manager itself
# ---------------------------------------------------------------------------

_CAUSES = ("demand", "router", "predicted", "replica")

if HAS_HYPOTHESIS:
    @st.composite
    def _trace(draw):
        L = draw(st.integers(1, 4))
        E = draw(st.integers(1, 8))
        cap = draw(st.integers(0, L * E))
        n_steps = draw(st.integers(1, 12))
        steps = []
        for _ in range(n_steps):
            activated = draw(st.lists(st.booleans(), min_size=L * E,
                                      max_size=L * E))
            hidden = draw(st.lists(st.booleans(), min_size=L * E,
                                   max_size=L * E))
            pin = draw(st.booleans())
            n_admit = draw(st.integers(0, 4))
            admits = [(draw(st.integers(0, L - 1)),
                       draw(st.integers(0, E - 1)),
                       draw(st.sampled_from(_CAUSES)))
                      for _ in range(n_admit)]
            steps.append((activated, hidden, pin, admits))
        return L, E, cap, steps


def _random_trace(rng):
    """Seeded stand-in for the hypothesis strategy (same shape)."""
    L = int(rng.integers(1, 5))
    E = int(rng.integers(1, 9))
    cap = int(rng.integers(0, L * E + 1))
    steps = []
    for _ in range(int(rng.integers(1, 13))):
        activated = rng.random(L * E) < 0.4
        hidden = rng.random(L * E) < 0.3
        pin = bool(rng.integers(0, 2))
        admits = [(int(rng.integers(0, L)), int(rng.integers(0, E)),
                   _CAUSES[int(rng.integers(0, len(_CAUSES)))])
                  for _ in range(int(rng.integers(0, 5)))]
        steps.append((activated.tolist(), hidden.tolist(), pin, admits))
    return L, E, cap, steps


def _check_bijection(r):
    occupied = np.flatnonzero(r.slot_of.reshape(-1) >= 0)
    owners = [o for o in r.owner if o >= 0]
    assert sorted(owners) == sorted(occupied.tolist())
    for pid in owners:
        l, e = divmod(int(pid), r.num_experts)
        assert r.owner[r.slot_of[l, e]] == pid
    assert len(r.free) == r.capacity - len(owners)
    assert sorted(r.free + [int(s) for s in
                            r.slot_of.reshape(-1)[occupied]]) \
        == list(range(r.capacity))


def _run_invariant_trace(trace):
    L, E, cap, steps = trace
    r = residency.ExpertResidency(L, E, capacity=cap, span_bytes=1000)
    total_activated = 0
    for activated, hidden, pin, admits in steps:
        act = np.asarray(activated, bool).reshape(L, E)
        hid = np.asarray(hidden, bool).reshape(L, E)
        total_activated += int(act.sum())
        if pin:
            r.pin_resident()
            pinned_before = {divmod(int(p), E) for p in r.pinned}
        missed = r.observe(act, hidden_mask=hid)
        # missed = exactly the activated non-resident pairs
        assert set(missed) == {(int(l), int(e))
                               for l, e in zip(*np.nonzero(act))
                               if not r.is_resident(l, e)}
        for l, e, cause in admits:
            demand = cause == "demand"
            slot = r.admit(l, e, demand=demand, allow_evict=not demand,
                           cause=None if demand else cause)
            if slot is not None:
                assert r.slot_of[l, e] == slot
        if pin:
            # pinned spans were never evicted while pinned
            for l, e in pinned_before:
                assert r.is_resident(l, e)
            r.unpin_all()
        # replica-pinned spans are never displaced, pin or no pin
        for pid in r.replicas:
            assert r.is_resident(*divmod(int(pid), E))
        assert r.occupancy() <= r.capacity
        _check_bijection(r)
    c = r.counters
    # counters sum to total fetches: every activated expert observation
    # was booked exactly once as a hit or a miss
    assert c.fetches == c.hits + c.misses
    assert c.fetches == total_activated
    # the cause split partitions the hits ...
    assert (c.demand_hits + c.router_hits + c.predicted_hits
            + c.replicated_hits == c.hits)
    # ... and the stall split partitions the misses
    assert 0 <= c.hidden_misses <= c.misses
    assert c.stall_misses == c.misses - c.hidden_misses
    assert int(r.miss_stall_bytes.sum()) == 1000 * c.stall_misses
    # predicted accounting is consistent
    assert 0 <= c.predicted_used <= c.predicted_prefetches
    assert 0.0 <= c.prefetch_accuracy <= 1.0
    assert c.predicted_prefetches + c.replications <= c.prefetches
    # every byte booked is a miss stream or a prefetch transfer
    assert c.h2d_bytes == 1000 * (c.misses + c.prefetches)


if HAS_HYPOTHESIS:
    @given(_trace())
    @settings(max_examples=100, deadline=None)
    def test_residency_invariants(trace):
        _run_invariant_trace(trace)


def test_residency_invariants_seeded():
    """The same invariant checks over seeded random traces, so the bare
    container (no hypothesis) still exercises them in tier-1."""
    for seed in range(25):
        _run_invariant_trace(_random_trace(np.random.default_rng(seed)))


@pytest.mark.parametrize("L,E", [(1, 2), (3, 4), (6, 8)])
def test_pinned_never_evicted_under_pressure(L, E):
    """With every slot pinned, admission of an arbitrarily hot candidate
    must refuse rather than evict (the in-flight chunk may read any
    resident span in place)."""
    r = residency.ExpertResidency(L, E, capacity=1, span_bytes=8)
    assert r.admit(0, 0) is not None
    r.pin_resident()
    act = np.zeros((L, E), bool)
    act[L - 1, E - 1] = True
    for _ in range(5):                      # make the candidate hot
        r.observe(act)
    assert r.admit(L - 1, E - 1) is None
    assert r.is_resident(0, 0)
    r.unpin_all()
    assert r.admit(L - 1, E - 1) is not None     # now evictable
    assert not r.is_resident(0, 0)


def test_victim_quota_lets_demand_misses_converge():
    """PR-3 follow-up: with a reserved victim quota, a demand miss
    (allow_evict=False) may still displace up to `victim_quota` strictly
    colder residents per chunk — a cold cache under a hot steady state
    converges without waiting for the prefetch path.  Quota 0 keeps the
    old refuse-only behavior; the quota refreshes at begin_chunk."""
    def make(quota):
        r = residency.ExpertResidency(1, 4, capacity=1, span_bytes=8,
                                      victim_quota=quota)
        assert r.admit(0, 0) is not None         # pool full of a cold span
        hot = np.zeros((1, 4), bool)
        hot[0, 1] = True
        for _ in range(5):
            r.observe(hot)                       # candidate strictly hotter
        return r

    r0 = make(quota=0)
    assert r0.admit(0, 1, demand=True, allow_evict=False) is None
    assert r0.counters.refusals == 1

    r1 = make(quota=1)
    r1.begin_chunk()
    assert r1.admit(0, 1, demand=True, allow_evict=False) is not None
    assert r1.is_resident(0, 1) and not r1.is_resident(0, 0)
    # quota spent: a second demand eviction this chunk is refused
    cold = np.zeros((1, 4), bool)
    cold[0, 2] = True
    for _ in range(8):
        r1.observe(cold)                         # make (0,2) hottest
    assert r1.admit(0, 2, demand=True, allow_evict=False) is None
    r1.begin_chunk()                             # next chunk: refreshed
    assert r1.admit(0, 2, demand=True, allow_evict=False) is not None


def test_popularity_ewma_prefers_hot_expert():
    r = residency.ExpertResidency(1, 4, capacity=2, span_bytes=8)
    hot = np.array([[True, False, False, False]])
    cold = np.array([[False, True, True, True]])
    for _ in range(8):
        r.observe(hot)
    r.observe(cold)
    assert r.popularity[0, 0] > r.popularity[0, 1]


def test_slots_from_ratio_bounds():
    assert residency.slots_from_ratio(0.0, 4, 8) == 0
    assert residency.slots_from_ratio(1.0, 4, 8) == 32
    assert residency.slots_from_ratio(0.25, 4, 8) == 8
    assert residency.slots_from_ratio(2.0, 4, 8) == 32


# ---------------------------------------------------------------------------
# End-to-end: transcript identity across residency regimes
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixtral_setup():
    import jax
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    return cfg, init_params(cfg, jax.random.key(1))


def _serve(cfg, params, work, **kw):
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           page_elems=4096, **kw))
    for p, q in work:
        eng.submit(p, q)
    return eng, eng.run_until_idle()


def test_transcripts_identical_across_residency_regimes(mixtral_setup):
    """Whole-layer streaming, expert-granular hit-heavy (every span fits
    resident), and expert-granular miss-heavy (one slot) must produce
    bit-identical greedy transcripts — residency decides only where bytes
    come from, never what is computed."""
    cfg, params = mixtral_setup
    rng = np.random.default_rng(7)
    work = [(rng.integers(2, cfg.vocab_size, int(rng.integers(2, 20))),
             int(rng.integers(1, 8))) for _ in range(6)]
    _, whole = _serve(cfg, params, work, paged=True)
    hit_eng, hit = _serve(cfg, params, work, expert_paged=True,
                          w_gpu_ratio=1.0)
    miss_eng, miss = _serve(cfg, params, work, expert_paged=True,
                            expert_slots=1)
    assert hit == whole
    assert miss == whole
    # the regimes actually differ as labeled
    th, tm = hit_eng.weight_traffic(), miss_eng.weight_traffic()
    assert th["hit_rate"] > 0.8 > tm["hit_rate"]
    assert th["h2d_bytes"] < tm["h2d_bytes"]


def test_expert_traffic_reduction_vs_whole_layer(mixtral_setup):
    """Acceptance bar: measured H2D weight bytes/token ≥ 2× lower than
    whole-layer streaming on the mixtral smoke config (top-2 of 8) under
    a tight w_gpu_ratio."""
    cfg, params = mixtral_setup
    rng = np.random.default_rng(3)
    work = [(rng.integers(2, cfg.vocab_size, 12), 12) for _ in range(8)]
    base_eng, base = _serve(cfg, params, work, paged=True)
    exp_eng, exp = _serve(cfg, params, work, expert_paged=True,
                          w_gpu_ratio=0.25)
    assert exp == base
    tb, te = base_eng.weight_traffic(), exp_eng.weight_traffic()
    per_tok_base = tb["h2d_bytes"] / max(1, tb["tokens_out"])
    per_tok_exp = te["h2d_bytes"] / max(1, te["tokens_out"])
    assert per_tok_base >= 2.0 * per_tok_exp
    assert te["hits"] + te["misses"] > 0


def test_router_ahead_prefetch_improves_hit_rate(mixtral_setup):
    """The group j+1 lookahead must do observable work: prefetch counters
    advance and the hit rate does not degrade vs. demand-only."""
    cfg, params = mixtral_setup
    rng = np.random.default_rng(5)
    work = [(rng.integers(2, cfg.vocab_size, 12), 16) for _ in range(10)]
    on_eng, on = _serve(cfg, params, work, expert_paged=True,
                        w_gpu_ratio=0.25, prefetch=True)
    off_eng, off = _serve(cfg, params, work, expert_paged=True,
                          w_gpu_ratio=0.25, prefetch=False)
    assert on == off
    t_on, t_off = on_eng.weight_traffic(), off_eng.weight_traffic()
    assert t_on["prefetches"] > 0 == t_off["prefetches"]
    assert t_on["hit_rate"] >= t_off["hit_rate"]


def test_prefetch_drains_through_transfer_plan(mixtral_setup, monkeypatch):
    """The engine's prefetch interleaving is scheduled by
    paging.transfer_plan (satellite decision: wired, not deleted): the
    pending queue must be sliced through it."""
    from repro.core import paging
    cfg, params = mixtral_setup
    calls = []
    orig = paging.transfer_plan

    def spy(pages_per_layer, n_ubs):
        calls.append((pages_per_layer, n_ubs))
        return orig(pages_per_layer, n_ubs)

    monkeypatch.setattr(paging, "transfer_plan", spy)
    rng = np.random.default_rng(5)
    work = [(rng.integers(2, cfg.vocab_size, 12), 16) for _ in range(8)]
    _serve(cfg, params, work, expert_paged=True, w_gpu_ratio=0.25,
           prefetch=True)
    assert calls, "prefetch never consulted transfer_plan"
    assert all(n == 2 for _, n in calls)          # num_ubs slices

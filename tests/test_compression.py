"""Gradient compression: quantization error bounds, error feedback
accumulation, psum correctness on a 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (compressed_psum, quantize_int8,
                                           tree_compressed_psum)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.max(np.abs(np.asarray(q, np.float32) * scale - np.asarray(x)))
    assert err <= float(scale) * 0.5 + 1e-6


def _on_mesh(fn, *args):
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P
    return jax.shard_map(fn, mesh=mesh,
                         in_specs=tuple(P() for _ in args),
                         out_specs=(P(), P()), check_vma=False)(*args)


def test_compressed_psum_single_device_identity(rng):
    g = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)
    out, err = _on_mesh(
        lambda x: compressed_psum(x, "pod", method="int8"), g)
    np.testing.assert_allclose(out + err, g, rtol=1e-5, atol=1e-5)
    # bf16 path
    out2, err2 = _on_mesh(
        lambda x: compressed_psum(x, "pod", method="bf16"), g)
    np.testing.assert_allclose(out2 + err2, g, rtol=1e-5, atol=1e-5)


def test_error_feedback_converges():
    """Summing compressed estimates WITH error feedback over T steps must
    track the true running sum to within one quantization step (the EF
    telescoping property)."""
    rng = np.random.default_rng(3)
    true_sum = np.zeros(16, np.float32)
    est_sum = np.zeros(16, np.float32)
    err = jnp.zeros(16)
    for _ in range(50):
        g = jnp.asarray(rng.normal(0, 1, (16,)), jnp.float32)
        true_sum += np.asarray(g)
        out, err = _on_mesh(
            lambda x, e: compressed_psum(x, "pod", method="int8", error=e),
            g, err)
        est_sum += np.asarray(out)
    # telescoping: |true - est| == |final error| <= one quant step
    resid = np.abs(true_sum - est_sum)
    assert np.max(resid) <= float(jnp.max(jnp.abs(err))) + 1e-4


def test_tree_compression_threads_state(rng):
    g = {"a": jnp.asarray(rng.normal(0, 1, (8,)), jnp.float32),
         "b": {"c": jnp.asarray(rng.normal(0, 1, (4,)), jnp.float32)}}
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P

    def body(tree):
        return tree_compressed_psum(tree, "pod", method="bf16")

    out, errs = jax.shard_map(
        body, mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), g),),
        out_specs=(jax.tree.map(lambda _: P(), g),
                   jax.tree.map(lambda _: P(), g)), check_vma=False)(g)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    for k in ("a",):
        np.testing.assert_allclose(out[k] + errs[k], g[k], rtol=1e-5,
                                   atol=1e-5)

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def smoke_cfg(arch: str, dtype: str = "bfloat16"):
    cfg = get_config(arch).smoke()
    if dtype != cfg.dtype:
        cfg = dataclasses.replace(cfg, dtype=dtype)
    return cfg


@pytest.fixture(scope="session")
def qwen_f32():
    return smoke_cfg("qwen2.5-3b", "float32")

"""CGOPipe simulator: schedule validity (deps, resource exclusivity) and
the paper's Fig. 6/7 qualitative ordering near the balance point."""
import pytest

from repro.configs import get_config
from repro.core import cgopipe as CG
from repro.core import hrm as H
from repro.core.policy import Policy, Workload


def test_simulator_respects_deps_and_exclusivity():
    tasks = [
        CG.Task("a", "gpu", 1.0),
        CG.Task("b", "gpu", 1.0, ("a",)),
        CG.Task("c", "h2d", 0.5, ("a",)),
        CG.Task("d", "gpu", 1.0, ("c",)),
    ]
    r = CG.simulate(tasks)
    assert r.starts["b"] >= r.ends["a"]
    assert r.starts["d"] >= r.ends["c"]
    # gpu exclusivity: b and d cannot overlap
    assert (r.starts["d"] >= r.ends["b"]) or (r.starts["b"] >= r.ends["d"])
    assert r.makespan == pytest.approx(3.0)


def test_simulator_detects_cycles():
    with pytest.raises(ValueError):
        CG.simulate([CG.Task("a", "gpu", 1.0, ("b",)),
                     CG.Task("b", "gpu", 1.0, ("a",))])


@pytest.fixture(scope="module")
def times():
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    # near the balance point: moderate batch, partial weight residency
    pol = Policy(batch=128, ubatch=32, attn_on_gpu=False, ffn_on_gpu=True,
                 w_gpu_ratio=0.0, kv_gpu_ratio=0.0)
    return CG.times_from_policy(cfg, hw, Workload(77, 64), pol)


def test_cgopipe_beats_serialized_schedules(times):
    lat = {name: CG.per_layer_latency(name, times, 16)
           for name in ("cgopipe", "s2", "s3", "s4")}
    # Fig. 6/7: CGOPipe <= overlapped-unpaged (s2) <= serialized (s3);
    # GPU-attention FlexGen (s4) pays KV transfers on the H2D link.
    assert lat["cgopipe"] <= lat["s2"] * 1.001
    assert lat["cgopipe"] < lat["s3"]
    assert lat["cgopipe"] < lat["s4"]


def test_paging_fills_io_bubbles(times):
    """With paged weights, H2D utilization in steady state must be at
    least as high as with whole-block transfers (s2)."""
    a = CG.run_schedule("cgopipe", times, 8)
    b = CG.run_schedule("s2", times, 8)
    assert a.utilization("h2d") >= b.utilization("h2d") * 0.99


@pytest.fixture(scope="module")
def weight_bound_times():
    """Weight-bound regime: small batch, nothing resident — streaming the
    expert weights dominates every other resource (the regime where Fig. 6
    separates the schedules most cleanly)."""
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    pol = Policy(batch=32, ubatch=8, attn_on_gpu=False, ffn_on_gpu=True,
                 w_gpu_ratio=0.0, kv_gpu_ratio=0.0)
    return CG.times_from_policy(cfg, hw, Workload(77, 64), pol)


def test_fig6_makespan_ordering(times, weight_bound_times):
    """Regression pin on the paper's Fig. 6 ordering so `build_*`
    refactors can't silently invert it: CGOPipe's makespan <= the
    overlapped-unpaged schedule (s2) <= the fully serialized one (s3),
    in both the balance-point and weight-bound regimes."""
    for t in (times, weight_bound_times):
        res = {s: CG.run_schedule(s, t, 8) for s in ("cgopipe", "s2", "s3")}
        assert res["cgopipe"].makespan <= res["s2"].makespan
        assert res["s2"].makespan <= res["s3"].makespan


def test_fig6_gpu_utilization_ordering(weight_bound_times):
    """On a weight-bound policy the schedules do identical GPU work, so
    paging's shorter makespan must show up as GPU utilization: cgopipe >=
    s2 > s3 (equivalently, smaller GPU bubble fraction)."""
    res = {s: CG.run_schedule(s, weight_bound_times, 8)
           for s in ("cgopipe", "s2", "s3")}
    assert res["cgopipe"].utilization("gpu") >= res["s2"].utilization("gpu")
    assert res["s2"].utilization("gpu") > res["s3"].utilization("gpu")
    assert res["cgopipe"].bubble_fraction("gpu") <= \
        res["s2"].bubble_fraction("gpu")


def test_deepspeed_single_microbatch_is_worse():
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    # DeepSpeed-like: KV on GPU caps N at a small value
    pol_ds = Policy(batch=32, ubatch=32, attn_on_gpu=True, ffn_on_gpu=True,
                    w_gpu_ratio=0.0, kv_gpu_ratio=1.0)
    t_ds = CG.times_from_policy(cfg, hw, Workload(77, 64), pol_ds)
    pol = Policy(batch=512, ubatch=64, attn_on_gpu=False, ffn_on_gpu=True,
                 w_gpu_ratio=0.0, kv_gpu_ratio=0.0)
    t = CG.times_from_policy(cfg, hw, Workload(77, 64), pol)
    thr_ds = pol_ds.batch / CG.per_layer_latency("deepspeed", t_ds, 16)
    thr = pol.batch / CG.per_layer_latency("cgopipe", t, 16)
    assert thr > thr_ds

"""CGOPipe simulator: schedule validity (deps, resource exclusivity) and
the paper's Fig. 6/7 qualitative ordering near the balance point."""
import pytest

from repro.configs import get_config
from repro.core import cgopipe as CG
from repro.core import hrm as H
from repro.core.policy import Policy, Workload


def test_simulator_respects_deps_and_exclusivity():
    tasks = [
        CG.Task("a", "gpu", 1.0),
        CG.Task("b", "gpu", 1.0, ("a",)),
        CG.Task("c", "h2d", 0.5, ("a",)),
        CG.Task("d", "gpu", 1.0, ("c",)),
    ]
    r = CG.simulate(tasks)
    assert r.starts["b"] >= r.ends["a"]
    assert r.starts["d"] >= r.ends["c"]
    # gpu exclusivity: b and d cannot overlap
    assert (r.starts["d"] >= r.ends["b"]) or (r.starts["b"] >= r.ends["d"])
    assert r.makespan == pytest.approx(3.0)


def test_simulator_detects_cycles():
    with pytest.raises(ValueError):
        CG.simulate([CG.Task("a", "gpu", 1.0, ("b",)),
                     CG.Task("b", "gpu", 1.0, ("a",))])


@pytest.fixture(scope="module")
def times():
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    # near the balance point: moderate batch, partial weight residency
    pol = Policy(batch=128, ubatch=32, attn_on_gpu=False, ffn_on_gpu=True,
                 w_gpu_ratio=0.0, kv_gpu_ratio=0.0)
    return CG.times_from_policy(cfg, hw, Workload(77, 64), pol)


def test_cgopipe_beats_serialized_schedules(times):
    lat = {name: CG.per_layer_latency(name, times, 16)
           for name in ("cgopipe", "s2", "s3", "s4")}
    # Fig. 6/7: CGOPipe <= overlapped-unpaged (s2) <= serialized (s3);
    # GPU-attention FlexGen (s4) pays KV transfers on the H2D link.
    assert lat["cgopipe"] <= lat["s2"] * 1.001
    assert lat["cgopipe"] < lat["s3"]
    assert lat["cgopipe"] < lat["s4"]


def test_paging_fills_io_bubbles(times):
    """With paged weights, H2D utilization in steady state must be at
    least as high as with whole-block transfers (s2)."""
    a = CG.run_schedule("cgopipe", times, 8)
    b = CG.run_schedule("s2", times, 8)
    assert a.utilization("h2d") >= b.utilization("h2d") * 0.99


def test_deepspeed_single_microbatch_is_worse():
    cfg = get_config("mixtral-8x7b")
    hw = H.preset("l4")
    # DeepSpeed-like: KV on GPU caps N at a small value
    pol_ds = Policy(batch=32, ubatch=32, attn_on_gpu=True, ffn_on_gpu=True,
                    w_gpu_ratio=0.0, kv_gpu_ratio=1.0)
    t_ds = CG.times_from_policy(cfg, hw, Workload(77, 64), pol_ds)
    pol = Policy(batch=512, ubatch=64, attn_on_gpu=False, ffn_on_gpu=True,
                 w_gpu_ratio=0.0, kv_gpu_ratio=0.0)
    t = CG.times_from_policy(cfg, hw, Workload(77, 64), pol)
    thr_ds = pol_ds.batch / CG.per_layer_latency("deepspeed", t_ds, 16)
    thr = pol.batch / CG.per_layer_latency("cgopipe", t, 16)
    assert thr > thr_ds

"""Checkpointing: roundtrip, async, atomicity (tmp never visible), GC,
elastic restore path."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager


def _tree(x=0.0):
    return {"a": jnp.full((3, 4), 1.0 + x), "b": {"c": jnp.arange(5) + int(x)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(10, _tree(), extra={"data_step": 10})
    step, tree, extra = cm.restore()
    assert step == 10 and extra["data_step"] == 10
    np.testing.assert_array_equal(tree["a"], _tree()["a"])
    np.testing.assert_array_equal(tree["b"]["c"], _tree()["b"]["c"])


def test_async_save_and_keep_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save_async(s, _tree(s))
    cm.wait()
    assert cm.all_steps() == [3, 4]
    step, tree, _ = cm.restore()
    assert step == 4
    np.testing.assert_array_equal(tree["a"], _tree(4.0)["a"])


def test_no_tmp_dirs_after_save(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, _tree())
    assert not list(tmp_path.glob("*.tmp"))


def test_restore_specific_step(tmp_path):
    cm = CheckpointManager(tmp_path, keep=5)
    cm.save(1, _tree(1))
    cm.save(2, _tree(2))
    step, tree, _ = cm.restore(step=1)
    assert step == 1
    np.testing.assert_array_equal(tree["a"], _tree(1.0)["a"])


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore with explicit (single-device) shardings."""
    cm = CheckpointManager(tmp_path)
    cm.save(5, _tree())
    mesh = jax.make_mesh((1,), ("model",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = {"a": sh, "b": {"c": sh}, "step": sh}
    step, tree, _ = cm.restore(shardings=shardings)
    assert tree["a"].sharding == sh


def test_elastic_remesh_subprocess(tmp_path):
    """Elastic re-scaling: checkpoint written on an 8-device (2x4) mesh
    restores onto a 4-device (2x2) mesh with correct values/shardings."""
    import os
    import subprocess
    import sys
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.runtime.checkpoint import CheckpointManager

d = sys.argv[1]
cm = CheckpointManager(d)
mesh8 = jax.make_mesh((2, 4), ("data", "model"))
x = jax.device_put(jnp.arange(64.).reshape(8, 8),
                   NamedSharding(mesh8, P("data", "model")))
cm.save(1, {"w": x})
# restore onto a DIFFERENT mesh (first 4 devices)
mesh4 = jax.sharding.Mesh(
    np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh4, P("model", "data"))}
step, tree, _ = cm.restore(shardings=sh)
ok = bool(jnp.all(tree["w"] == jnp.arange(64.).reshape(8, 8)))
print(json.dumps({"ok": ok, "ndev": len(tree["w"].sharding.device_set)}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    import json as _json
    out = _json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["ndev"] == 4


@pytest.mark.slow
def test_trainer_resume(tmp_path):
    """Kill-and-restart: a second Trainer on the same ckpt dir resumes at
    the saved step with identical params."""
    from repro.configs import get_config
    from repro.training.trainer import Trainer, TrainConfig
    cfg = get_config("olmo-1b").smoke()
    t1 = Trainer(cfg, TrainConfig(steps=4, batch_size=2, seq_len=32,
                                  ckpt_dir=str(tmp_path), ckpt_every=2))
    t1.run()
    t2 = Trainer(cfg, TrainConfig(steps=6, batch_size=2, seq_len=32,
                                  ckpt_dir=str(tmp_path), ckpt_every=2))
    assert t2.step == 4                      # resumed, not restarted
    a = jax.tree.leaves(t1.params)[0]
    b = jax.tree.leaves(t2.params)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    t2.run()
    assert t2.step == 6

"""HRM math (paper §3) and the policy optimizer (§4.2): turning points,
balance point, the paper's qualitative results (CPU attention on L4/T4,
A_g=0 F_g=1 best policy, FFN intensity ∝ batch), and the §6.3 hardware
case study directionality."""
import pytest

from repro.configs import get_config
from repro.core import hrm as H
from repro.core import policy as P


@pytest.fixture(scope="module")
def mixtral():
    return get_config("mixtral-8x7b")


@pytest.fixture(scope="module")
def l4():
    return H.preset("l4")


def test_roofline_reduces_to_classic(l4):
    # Eq. 8: local attainable = min(P, B*I)
    lo = H.attainable_local(l4, "gpu", 0.001)
    hi = H.attainable_local(l4, "gpu", 1e9)
    assert lo == pytest.approx(300e9 * 0.001)
    assert hi == l4.level("gpu").p_peak


def test_cross_level_roof_binds(l4):
    # Eq. 7: tiny cross-level intensity -> bound by link bw
    p = H.attainable_cross(l4, "gpu", "cpu", i_exec=1e9, i_data=1.0)
    assert p == pytest.approx(l4.link_bw("cpu", "gpu") * 1.0)


def test_turning_points_order(l4):
    # P1 (Eq. 9) must lie below P2 (Eq. 10) for any intensity where the
    # CPU is slower than the GPU
    i = 10.0
    p1 = H.turning_point_p1(l4, "gpu", "cpu", i)
    p2 = H.turning_point_p2(l4, "gpu", "cpu", i)
    assert p1 < p2


def test_paper_fig4_attention_on_cpu(mixtral, l4):
    """Fig. 4: decode GQA attention intensity is below P1 on the L4
    instance → compute on CPU."""
    lw = H.LayerWorkload.decode(mixtral, batch=256, ctx=512)
    i_attn = lw.intensity_attn_vs_kv()
    assert i_attn < H.turning_point_p1(l4, "gpu", "cpu", i_attn)
    assert H.should_compute_at_data(l4, "gpu", "cpu", i_attn)


def test_paper_fig5_ffn_intensity_grows_with_batch(mixtral):
    i = [H.LayerWorkload.decode(mixtral, batch=n, ctx=576)
         .intensity_ffn_vs_weights() for n in (32, 128, 512, 2048)]
    assert i == sorted(i)
    assert i[-1] > 10 * i[0]


def test_balance_point(l4):
    i_j = H.balance_point_intensity(l4, "gpu", "cpu", i_exec=10.0)
    # at the balance point the two bandwidth roofs are equal
    lhs = l4.level("gpu").b_peak * 10.0
    rhs = l4.link_bw("cpu", "gpu") * i_j
    assert lhs == pytest.approx(rhs)


def test_policy_search_matches_paper(mixtral, l4):
    """§4.2: 'For our major setting, we always get A_g=0 and F_g=1'."""
    res = P.search(mixtral, l4, P.Workload(prompt_len=77, gen_len=64))
    best = res["best"]["policy"]
    assert best.attn_on_gpu is False
    assert best.ffn_on_gpu is True
    assert res["best"]["throughput"] > 0
    # CPU-attention optimum beats forced-GPU-attention optimum
    assert (res["best_cpu_attn"]["throughput"]
            >= res["best_gpu_attn"]["throughput"])


def test_policy_memory_constraints(mixtral, l4):
    res = P.search(mixtral, l4, P.Workload(prompt_len=77, gen_len=64))
    assert res["best"]["mem_gpu"] <= l4.level("gpu").capacity
    assert res["best"]["mem_cpu"] <= l4.level("cpu").capacity


def test_fig10_more_link_bw_more_offload(mixtral):
    """§6.3: increasing CPU→GPU bandwidth shifts weights toward the CPU
    (r_w decreases or stays) for the 2xA100 setup."""
    import dataclasses
    base = H.preset("a100x2")
    rws = []
    for bw in (25e9, 100e9, 400e9):
        hw = H.Hardware(levels=base.levels, links={("cpu", "gpu"): bw},
                        name="sweep")
        res = P.search(mixtral, hw, P.Workload(prompt_len=512, gen_len=32))
        rws.append(res["best"]["policy"].w_gpu_ratio)
    assert rws[-1] <= rws[0]


def test_expert_hit_rate_uniform_equals_ratio(mixtral):
    """Uniform routing: the r_w-sized residency cache hits at exactly r_w
    — the expert-granular traffic term then reduces to the whole-layer
    (1 - r_w) stream, keeping the legacy policy-search results intact."""
    for r in (0.0, 0.25, 0.5, 1.0):
        assert H.expert_hit_rate(r, 8) == pytest.approx(r)
    import numpy as np
    uniform = np.full(8, 1 / 8)
    assert H.expert_hit_rate(0.25, 8, uniform) == pytest.approx(0.25)


def test_expert_hit_rate_skew_beats_uniform():
    """Skewed routing makes a small cache disproportionately effective:
    the retained top mass exceeds r_w."""
    import numpy as np
    skew = np.array([0.5, 0.3, 0.1, 0.04, 0.03, 0.02, 0.005, 0.005])
    assert H.expert_hit_rate(0.25, 8, skew) == pytest.approx(0.8)
    # per-layer (L, E) tables average over layers
    two = np.stack([skew, np.full(8, 1 / 8)])
    assert H.expert_hit_rate(0.25, 8, two) == pytest.approx((0.8 + 0.25) / 2)


def test_skewed_popularity_cuts_weight_traffic(mixtral, l4):
    """The policy's weight-traffic term is expected activated-expert bytes
    × miss rate: measured skew lowers per-layer comm bytes at the same
    r_w, so r_w genuinely trades against hit rate."""
    import dataclasses as dc
    import numpy as np
    pol = P.Policy(batch=256, ubatch=32, attn_on_gpu=False, ffn_on_gpu=True,
                   w_gpu_ratio=0.25, kv_gpu_ratio=0.0)
    wl_uni = H.LayerWorkload.decode(mixtral, batch=256, ctx=512)
    skew = np.array([0.5, 0.3, 0.1, 0.04, 0.03, 0.02, 0.005, 0.005])
    wl_skew = dc.replace(wl_uni, popularity=skew)
    lat_uni = H.layer_latency(l4, wl_uni, pol)
    lat_skew = H.layer_latency(l4, wl_skew, pol)
    assert lat_skew["comm_bytes"] < lat_uni["comm_bytes"]
    # uniform == the legacy whole-layer formula (D2 hidden + weight stream)
    expect = wl_uni.bytes_hidden + wl_uni.bytes_w * (1 - pol.w_gpu_ratio)
    assert lat_uni["comm_bytes"] == pytest.approx(expect)


def test_kv_block_hit_rate_bounds():
    """num_ubs = 1 degenerates to the dense placement assumption
    (hit = r_c); rotation multiplies the effective hit rate because only
    the decoding group's blocks are touched per step; always in [0, 1]
    and monotone in r_c."""
    for r in (0.0, 0.25, 0.5, 1.0):
        assert H.kv_block_hit_rate(r, 1) == pytest.approx(r)
    assert H.kv_block_hit_rate(0.25, 2) == pytest.approx(0.5)
    assert H.kv_block_hit_rate(0.25, 4) == pytest.approx(1.0)
    assert H.kv_block_hit_rate(0.9, 4) == 1.0
    assert H.kv_block_hit_rate(-1.0, 2) == 0.0
    assert H.kv_block_hit_rate(0.1, 3) <= H.kv_block_hit_rate(0.2, 3)


def test_kv_hit_cuts_attention_traffic(mixtral, l4):
    """The KV traffic term is miss rate × touched block bytes: a measured
    (or rotation-modelled) hit rate above r_c lowers per-layer comm bytes
    at the same r_c, so the paged pool lets the search trade r_c down
    and spend the memory elsewhere."""
    import dataclasses as dc
    pol = P.Policy(batch=256, ubatch=32, attn_on_gpu=True, ffn_on_gpu=True,
                   w_gpu_ratio=0.25, kv_gpu_ratio=0.25)
    wl = H.LayerWorkload.decode(mixtral, batch=256, ctx=512)
    lat_dense = H.layer_latency(l4, wl, pol)
    wl_paged = dc.replace(wl, kv_hit=H.kv_block_hit_rate(0.25, 4))
    lat_paged = H.layer_latency(l4, wl_paged, pol)
    assert lat_paged["comm_bytes"] < lat_dense["comm_bytes"]
    # kv_hit=None reproduces the legacy r_c-linear stream exactly
    assert lat_dense["comm_bytes"] == pytest.approx(
        wl.bytes_kv * (1 - pol.kv_gpu_ratio)
        + wl.bytes_w * (1 - pol.w_gpu_ratio))


def test_kv_paged_search_feasible_at_lower_rc(mixtral, l4):
    """policy.search(kv_paged=True) must never do worse than the dense
    assumption — the rotation hit model only removes link traffic — and
    estimate() accepts a measured kv_hit_rate override."""
    wl = P.Workload(prompt_len=77, gen_len=64)
    dense = P.search(mixtral, l4, wl)
    paged = P.search(mixtral, l4, wl, kv_paged=True)
    assert paged["best"]["throughput"] >= dense["best"]["throughput"]
    pol = dense["best"]["policy"]
    est_meas = P.estimate(mixtral, l4, wl, pol, kv_hit_rate=1.0)
    est_none = P.estimate(mixtral, l4, wl, pol)
    assert est_meas["t_layer"] <= est_none["t_layer"]


def test_tpu_adaptation_compute_at_kv_shard(mixtral):
    """The §6.3 case study re-run with v5e constants — the HRM derivation
    behind DESIGN.md §2:

    (a) decode-attention intensity (I≈4) is far below P1 for the
        peer-HBM→chip link: do NOT ship KV shards over ICI — compute the
        partial attention on the chip that owns the shard and move only
        q/o (= collectives.make_seq_sharded_attn);
    (b) a peer-HBM KV placement strictly dominates host-DRAM placement
        (ICI ≫ PCIe and the peer has an MXU, the host does not)."""
    v5e = H.preset("v5e")
    lw = H.LayerWorkload.decode(mixtral, batch=256, ctx=512)
    i_attn = lw.intensity_attn_vs_kv()
    # (a) below P1 → compute where the data lives (Eq. 9)
    assert H.should_compute_at_data(v5e, "gpu", "ici", i_attn)
    # (b) attainable perf of the peer-resident path dominates host paths
    peer = H.attainable_local(v5e, "ici", i_attn)
    host = H.attainable_local(v5e, "cpu", i_attn)
    ship_from_host = H.attainable_cross(v5e, "gpu", "cpu", i_attn, i_attn)
    assert peer > 10 * max(host, ship_from_host)

"""Mamba2/SSD: chunked algorithm vs step-by-step recurrence; decode-step
consistency with prefill; conv cache behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import kvcache
from repro.models.mamba import (causal_conv, conv_step, mamba_forward,
                                ssd_chunked, ssd_recurrent_ref, ssd_step)
from repro.models.params import init_params


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_chunked_matches_recurrence(rng, chunk):
    b, S, nh, hd, N = 2, 37, 4, 8, 16
    x = jnp.asarray(rng.normal(0, 1, (b, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S, nh)) * 0.5 + 0.01, jnp.float32)
    A = -jnp.asarray(rng.random((nh,)) + 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(0, 1, (b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(0, 1, (b, S, N)), jnp.float32)
    y1, s1 = ssd_recurrent_ref(x, dt, A, B, C)
    y2, s2 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_ssd_state_carry(rng):
    """Running two halves with state carry == running the whole sequence."""
    b, S, nh, hd, N = 1, 24, 2, 4, 8
    x = jnp.asarray(rng.normal(0, 1, (b, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S, nh)) * 0.3 + 0.01, jnp.float32)
    A = -jnp.asarray(rng.random((nh,)) + 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(0, 1, (b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(0, 1, (b, S, N)), jnp.float32)
    y, s = ssd_chunked(x, dt, A, B, C, chunk=8)
    h = S // 2
    y1, s1 = ssd_chunked(x[:, :h], dt[:, :h], A, B[:, :h], C[:, :h], chunk=8)
    y2, s2 = ssd_chunked(x[:, h:], dt[:, h:], A, B[:, h:], C[:, h:],
                         state0=s1, chunk=8)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s2, s, rtol=2e-4, atol=2e-4)


def test_ssd_step_matches_chunked(rng):
    b, S, nh, hd, N = 1, 10, 2, 4, 8
    x = jnp.asarray(rng.normal(0, 1, (b, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S, nh)) * 0.3 + 0.01, jnp.float32)
    A = -jnp.asarray(rng.random((nh,)) + 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(0, 1, (b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(0, 1, (b, S, N)), jnp.float32)
    yc, _ = ssd_chunked(x, dt, A, B, C, chunk=4)
    s = jnp.zeros((b, nh, hd, N), jnp.float32)
    for t in range(S):
        yt, s = ssd_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], s)
        np.testing.assert_allclose(yt, yc[:, t], rtol=2e-4, atol=2e-4)


def test_conv_step_matches_causal_conv(rng):
    B, S, C = 2, 12, 6
    cw = 4
    x = jnp.asarray(rng.normal(0, 1, (B, S, C)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (cw, C)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (C,)), jnp.float32)
    full = causal_conv(x, w, b)
    cache = jnp.zeros((B, cw - 1, C))
    for t in range(S):
        yt, cache = conv_step(x[:, t], cache, w, b)
        np.testing.assert_allclose(yt, full[:, t], rtol=1e-5, atol=1e-5)


def test_mamba_forward_decode_matches_full(rng):
    cfg = dataclasses.replace(get_config("mamba2-1.3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["p0"]["mamba"])
    B, S = 2, 11
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, cfg.d_model)), jnp.float32)
    full, _ = mamba_forward(cfg, p, x, cache=None, mode="full")
    cache = jax.tree.map(lambda a: a[0],
                         kvcache._spec_cache(cfg, cfg.period[0], 1, B, 16,
                                             jnp.float32))
    _, cache = mamba_forward(cfg, p, x[:, :S - 1], cache=cache, mode="full")
    dec, _ = mamba_forward(cfg, p, x[:, S - 1:], cache=cache, mode="decode")
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=5e-4, atol=5e-4)

"""End-to-end system tests: training drives loss down; the serving engine
generates correctly under continuous batching (resident + paged weights);
the watchdog flags stragglers; the engine honors Algorithm 2 admission."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.training.trainer import Trainer, TrainConfig


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = get_config("olmo-1b").smoke()
    t = Trainer(cfg, TrainConfig(steps=30, batch_size=4, seq_len=64,
                                 log_every=5))
    t.run()
    losses = [m["loss"] for m in t.metrics_log]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.2, losses


@pytest.mark.slow
def test_training_moe_reduces_loss():
    cfg = get_config("mixtral-8x7b").smoke()
    t = Trainer(cfg, TrainConfig(steps=20, batch_size=4, seq_len=48,
                                 log_every=4))
    t.run()
    losses = [m["loss"] for m in t.metrics_log]
    assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.slow
def test_microbatched_equals_full_batch_gradients():
    """Gradient accumulation must match the single-step update."""
    from repro.models.inputs import concrete_inputs
    from repro.configs import get_shape
    from repro.models.params import init_params
    from repro.training.optimizer import OptConfig, init_opt_state
    from repro.training.train_step import (make_microbatched_train_step,
                                           make_train_step)
    cfg = dataclasses.replace(get_config("olmo-1b").smoke(), dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    opt = OptConfig(warmup_steps=1)
    batch = concrete_inputs(cfg, get_shape("train_4k").smoke())
    s1 = jax.jit(make_train_step(cfg, opt))
    s2 = jax.jit(make_microbatched_train_step(cfg, opt, None, num_micro=2))
    p1, _, m1 = s1(params, init_opt_state(params, opt), batch)
    p2, _, m2 = s2(params, init_opt_state(params, opt), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    la, lb = jax.tree.leaves(p1), jax.tree.leaves(p2)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("paged", [False, True])
def test_engine_generates(paged):
    cfg = get_config("qwen2.5-3b").smoke()
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(ubatch=3, num_ubs=2, max_seq=96,
                                           paged=paged))
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(2, cfg.vocab_size, n), 6)
            for n in (5, 9, 3, 7, 11)]
    out = eng.run_until_idle()
    assert set(out) == set(rids)
    for v in out.values():
        assert 1 <= len(v) <= 6
        assert all(0 <= t < cfg.vocab_size for t in v)


def test_engine_paged_matches_resident_greedy():
    """Paged weight streaming must not change greedy outputs."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.key(3))
    prompts = [np.arange(2, 9), np.arange(3, 6), np.arange(2, 12)]
    outs = []
    for paged in (False, True):
        eng = Engine(cfg, params, EngineConfig(ubatch=3, num_ubs=1,
                                               max_seq=64, paged=paged))
        for p in prompts:
            eng.submit(p, 5)
        outs.append(eng.run_until_idle())
    assert outs[0] == outs[1]


def test_engine_deferred_admission():
    """More requests than num_ubs×ubatch: the rest are admitted when
    capacity frees (continuous batching)."""
    cfg = get_config("olmo-1b").smoke()
    from repro.models.params import init_params
    params = init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=1, max_seq=64))
    rng = np.random.default_rng(1)
    rids = [eng.submit(rng.integers(2, cfg.vocab_size, 4), 3)
            for _ in range(6)]
    out = eng.run_until_idle()
    assert set(out) == set(rids)
    assert all(len(v) >= 1 for v in out.values())


def test_watchdog_flags_straggler():
    from repro.runtime.watchdog import StragglerError, Watchdog
    wd = Watchdog(deadline_factor=2.0, min_deadline_s=0.01, policy="abort")
    for _ in range(3):
        wd.step_start()
        time.sleep(0.01)
        wd.step_end()
    wd.step_start()
    time.sleep(0.08)
    with pytest.raises(StragglerError):
        wd.step_end()

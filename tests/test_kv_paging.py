"""Block-granular paged KV cache: hypothesis property suite over random
BlockPool traces (free-list conservation, no double-mapped physical
block, page-table bijection, device/host exclusivity, prefix
contiguity), a data-plane spill-then-fetch round-trip identity check,
and the end-to-end guarantees — greedy transcripts bit-identical across
dense / paged-resident / paged-with-host-spill regimes, the arena bound
by r_c, and ≥2× fewer device KV bytes than the dense max_seq pool on
the mixtral smoke skewed workload (the acceptance bar; the matching
report is benchmarks/bench_kv_paging.py)."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                          # CI installs it; the bare
    HAS_HYPOTHESIS = False                   # container runs the seeded
                                             # trace test below instead

from repro.core.batching import blocks_for_tokens, round_to_blocks
from repro.core.blockpool import BlockPool


# ---------------------------------------------------------------------------
# Property suite on the control plane
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def _trace(draw):
        n_slots = draw(st.integers(1, 6))
        mb = draw(st.integers(1, 6))
        dev = draw(st.integers(1, n_slots * mb))
        n_steps = draw(st.integers(1, 15))
        steps = []
        for _ in range(n_steps):
            kind = draw(st.sampled_from(["ensure", "free", "prefetch"]))
            slot = draw(st.integers(0, n_slots - 1))
            n_tok = draw(st.integers(0, mb * 4))
            steps.append((kind, slot, n_tok))
        return n_slots, mb, dev, steps


def _random_trace(rng):
    """Seeded stand-in for the hypothesis strategy (same shape)."""
    n_slots = int(rng.integers(1, 7))
    mb = int(rng.integers(1, 7))
    dev = int(rng.integers(1, n_slots * mb + 1))
    steps = []
    for _ in range(int(rng.integers(1, 16))):
        kind = ("ensure", "free", "prefetch")[int(rng.integers(0, 3))]
        steps.append((kind, int(rng.integers(0, n_slots)),
                      int(rng.integers(0, mb * 4 + 1))))
    return n_slots, mb, dev, steps


def _run_trace(trace, block_tokens=4):
    n_slots, mb, dev, steps = trace
    pool = BlockPool(n_slots, mb, dev, block_bytes=1000)
    for kind, slot, n_tok in steps:
        if kind == "ensure":
            # a slot's worst case must fit the arena for ensure to be
            # obliged to succeed; over-demand may legitimately fail
            ops, ok, nxt = pool.ensure_tokens(slot, n_tok, block_tokens,
                                              protect=(slot,))
            need = min(blocks_for_tokens(n_tok, block_tokens), mb)
            if need <= dev:
                assert ok, (slot, n_tok, dev)
            if ok:
                # every needed block is now device-resident
                assert nxt == need
                assert (pool.dev[slot, :need] >= 0).all()
            else:
                # resume point: everything before nxt was satisfied
                assert 0 <= nxt < need
                assert (pool.dev[slot, :nxt] >= 0).all()
            # ops are well-formed and reference real ids
            for op in ops:
                assert op[0] in ("spill", "fetch", "alloc")
        elif kind == "free":
            pool.free_slot(slot)
            assert not pool.slot_in_use(slot)
        else:                                     # prefetch
            for lb in pool.host_resident_blocks(slot)[:2]:
                pool.prefetch(slot, lb)
        pool.check_invariants()
    c = pool.counters
    assert c.fetches == c.hits + c.misses
    assert c.h2d_bytes == 1000 * (c.misses + c.prefetches)
    assert c.d2h_bytes == 1000 * c.spills
    assert pool.peak_in_use <= dev


if HAS_HYPOTHESIS:
    @given(_trace())
    @settings(max_examples=100, deadline=None)
    def test_blockpool_invariants(trace):
        _run_trace(trace)


def test_blockpool_invariants_seeded():
    """The same invariant checks over seeded random traces, so the bare
    container (no hypothesis) still exercises them in tier-1."""
    for seed in range(30):
        _run_trace(_random_trace(np.random.default_rng(seed)))


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2
    assert round_to_blocks(17, 16) == 32
    assert round_to_blocks(17, None) == 17


def test_protected_slot_never_spilled():
    """The dispatching group's blocks are the paged-attention analogue of
    residency's pinned spans: spilling must take victims elsewhere."""
    pool = BlockPool(n_slots=3, blocks_per_slot=2, device_blocks=2,
                     block_bytes=8)
    _, ok, _ = pool.ensure_tokens(0, 8, 4, protect=(0,))
    assert ok and (pool.dev[0] >= 0).all()
    # slot 1 needs both blocks: slot 0 (unprotected now) is the victim
    _, ok, _ = pool.ensure_tokens(1, 8, 4, protect=(1,))
    assert ok
    assert (pool.host[0] >= 0).all() and (pool.dev[0] == -1).all()
    # slot 0 re-protected: slot 1's residency cannot be evicted for it
    _, ok, _ = pool.ensure_tokens(0, 8, 4, protect=(0, 1))
    assert not ok
    pool.check_invariants()


def test_spill_oldest_block_first():
    pool = BlockPool(n_slots=2, blocks_per_slot=3, device_blocks=3,
                     block_bytes=8)
    pool.ensure_tokens(0, 12, 4, protect=(0,))
    _, ok, _ = pool.ensure_tokens(1, 4, 4, protect=(1,))
    assert ok
    # slot 0's lowest logical block (its oldest tokens) was the victim
    assert pool.host[0, 0] >= 0 and pool.dev[0, 1] >= 0 \
        and pool.dev[0, 2] >= 0


# ---------------------------------------------------------------------------
# Data plane: spill-then-fetch round-trip is byte-exact
# ---------------------------------------------------------------------------

def test_spill_fetch_round_trip_identity(qwen_f32):
    import jax
    import jax.numpy as jnp
    from repro.models import kvcache
    cfg = qwen_f32
    arena = kvcache.init_paged_arena(cfg, device_blocks=4, block_tokens=8)
    key = jax.random.key(0)
    g = arena["p0"]
    filled = {}
    for name, a in g.items():
        key, k = jax.random.split(key)
        filled[name] = (jax.random.normal(k, a.shape).astype(a.dtype)
                        if a.dtype != jnp.int32
                        else jax.random.randint(k, a.shape, 0, 64, a.dtype))
    def _blk(a, name, pb):
        ax = kvcache.arena_block_axis(name, stacked=True)
        return a[(slice(None),) * ax + (pb,)]

    def _set_blk(a, name, pb, v):
        ax = kvcache.arena_block_axis(name, stacked=True)
        return a.at[(slice(None),) * ax + (pb,)].set(v)

    before = {n: np.asarray(_blk(a, n, 2)) for n, a in filled.items()}
    host = {n: np.asarray(_blk(filled[n], n, 2)) for n in filled}  # spill pb=2
    zeroed = {n: _set_blk(filled[n], n, 2, 0) for n in filled}     # reused
    back = {n: _set_blk(zeroed[n], n, 3, jnp.asarray(host[n]))     # fetch→pb=3
            for n in zeroed}
    for n in back:
        np.testing.assert_array_equal(np.asarray(_blk(back[n], n, 3)),
                                      before[n])


# ---------------------------------------------------------------------------
# End-to-end: transcript identity + the device-bytes acceptance bar
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixtral_setup():
    import jax
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32")
    return cfg, init_params(cfg, jax.random.key(1))


def _serve(cfg, params, work, **kw):
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(cfg, params, EngineConfig(ubatch=2, num_ubs=2, max_seq=64,
                                           decode_chunk=4, **kw))
    for p, q in work:
        eng.submit(p, q)
    return eng, eng.run_until_idle()


def _skewed_work(cfg, seed=0, n=8):
    """Half short, half long generations over varied prompts — the
    workload whose actual footprints a max_seq-wide pool over-allocates
    hardest (the bench_kv_paging workload)."""
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 20))),
             4 if i % 2 == 0 else 12) for i in range(n)]


def test_transcripts_identical_across_kv_tiers(mixtral_setup):
    """Dense, paged-resident (r_c=1), and paged-with-host-spill must
    produce bit-identical greedy transcripts — the tier decides only
    where KV bytes live, never what attention computes."""
    cfg, params = mixtral_setup
    work = _skewed_work(cfg)
    _, dense = _serve(cfg, params, work)
    res_eng, resident = _serve(cfg, params, work, kv_paged=True,
                               kv_gpu_ratio=1.0)
    spill_eng, spilled = _serve(cfg, params, work, kv_paged=True,
                                kv_gpu_ratio=0.25)
    assert resident == dense
    assert spilled == dense
    # the regimes actually differ as labeled
    tr, ts = res_eng.kv_traffic(), spill_eng.kv_traffic()
    assert tr["spills"] == 0 == tr["misses"]
    assert ts["spills"] > 0 and ts["misses"] > 0
    assert ts["d2h_bytes"] > 0
    res_eng._kv.check_invariants()
    spill_eng._kv.check_invariants()


def test_arena_bounded_by_kv_gpu_ratio(mixtral_setup):
    """The acceptance bound: the arena never exceeds r_c × the dense
    pool's block count (modulo the one-slot progress floor, inactive
    here), and occupancy never exceeds the arena."""
    cfg, params = mixtral_setup
    for rc in (0.25, 0.5):
        eng, _ = _serve(cfg, params, _skewed_work(cfg), kv_paged=True,
                        kv_gpu_ratio=rc)
        total = eng.ecfg.num_ubs * eng.ecfg.ubatch \
            * (eng.ecfg.max_seq // eng.ecfg.block_tokens)
        assert eng._kv.device_blocks <= max(round(rc * total),
                                            total // (eng.ecfg.num_ubs
                                                      * eng.ecfg.ubatch))
        assert eng._kv.peak_in_use <= eng._kv.device_blocks
        assert eng._kv.counters.frees > 0       # drained slots released


def test_paged_pool_halves_device_kv_bytes(mixtral_setup):
    """Acceptance bar: the paged pool serves the same request set with
    ≥ 2× fewer device KV bytes than the dense max_seq-wide pool on the
    skewed workload (BENCH_kv.json reports the same row)."""
    cfg, params = mixtral_setup
    work = _skewed_work(cfg)
    _, dense = _serve(cfg, params, work)
    eng, paged = _serve(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25)
    assert paged == dense                       # same request set, same output
    t = eng.kv_traffic()
    assert t["dense_equiv_bytes"] >= 2.0 * t["device_kv_bytes"], t


def test_kv_prefetch_rides_transfer_plan(mixtral_setup, monkeypatch):
    """Spilled blocks stream back through paging.transfer_plan rotation
    slices (the KV analogue of the weight-prefetch drain), and prefetch
    does not change output."""
    from repro.core import paging
    cfg, params = mixtral_setup
    calls = []
    orig = paging.transfer_plan

    def spy(pages, n_ubs):
        calls.append((pages, n_ubs))
        return orig(pages, n_ubs)

    monkeypatch.setattr(paging, "transfer_plan", spy)
    work = _skewed_work(cfg, seed=3)
    on_eng, on = _serve(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25,
                        kv_prefetch=True)
    assert calls, "KV prefetch never consulted transfer_plan"
    off_eng, off = _serve(cfg, params, work, kv_paged=True,
                          kv_gpu_ratio=0.25, kv_prefetch=False)
    assert on == off
    t_on, t_off = on_eng.kv_traffic(), off_eng.kv_traffic()
    assert t_on["prefetches"] > 0 == t_off["prefetches"]


def test_int8_kv_paged_matches_dense():
    """The paged arena carries the quantized KV leaves (int8 values +
    f32 scales) generically; greedy output must match the dense int8
    path bit-for-bit."""
    import jax
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32", kv_dtype="int8")
    params = init_params(cfg, jax.random.key(5))
    rng = np.random.default_rng(5)
    work = [(rng.integers(2, cfg.vocab_size, int(rng.integers(2, 24))),
             int(rng.integers(1, 8))) for _ in range(5)]
    _, dense = _serve(cfg, params, work)
    _, paged = _serve(cfg, params, work, kv_paged=True, kv_gpu_ratio=0.25)
    assert paged == dense


# ---------------------------------------------------------------------------
# Concurrent faults: injected fetch/pool failures + budget preemption
# ---------------------------------------------------------------------------

def test_injected_pool_exhaustion_refusal_shape():
    """An injected 'exhaust' makes ensure_range refuse exactly like a
    real full arena — empty plan, resume point unchanged — and flags
    the refusal as injected so the engine retries instead of
    preempting; invariants hold throughout."""
    from repro.runtime.faults import FaultEvent, FaultInjector, FaultPlan
    inj = FaultInjector(FaultPlan(
        trace=[FaultEvent("kv_pool", "exhaust", after=1, count=1)]))
    pool = BlockPool(n_slots=2, blocks_per_slot=4, device_blocks=8,
                     block_bytes=64, faults=inj)
    _, ok, _ = pool.ensure_tokens(0, 8, 4, protect=(0,))
    assert ok and not pool.last_refusal_injected
    ops, ok, nxt = pool.ensure_range(0, 2, 4, protect=(0,))
    assert not ok and pool.last_refusal_injected
    assert ops == [] and nxt == 2
    pool.check_invariants()
    _, ok, _ = pool.ensure_range(0, 2, 4, protect=(0,))   # retry: lands
    assert ok and not pool.last_refusal_injected
    pool.check_invariants()


def test_concurrent_faults_with_budget_preemption(mixtral_setup):
    """The satellite acceptance: injected mid-dispatch fetch failures
    AND arena-exhaustion recompute-preemption in the same rotation
    groups (tight ewma budget forces real preemptions while the fault
    plan fails fetches and fakes pool exhaustion).  Free-list
    conservation, map invariants, and slot-state coherence must hold,
    and transcripts stay bit-identical to the fault-free run of the
    same tight-budget config."""
    from repro.runtime.faults import FaultPlan
    from repro.serving.scheduler import SlotState
    cfg, params = mixtral_setup
    # longer generations against a tight optimistic budget: enforce_budget
    # must preempt mid-run (recompute preemption) in the same groups the
    # fault plan is failing fetches in
    work = [(p, q + 8) for p, q in _skewed_work(cfg, seed=11)]
    # cache_tokens=64 = two 16-token blocks per row: a long row crossing
    # its third block while sharing a group must evict its partner
    tight = dict(kv_paged=True, kv_gpu_ratio=0.3, reserve_mode="ewma",
                 cache_tokens=64)
    base_eng, baseline = _serve(cfg, params, work, **tight)
    base_preempts = sum(r.preemptions
                       for r in base_eng.scheduler.requests.values())
    plan = FaultPlan(seed=4,
                     probs={"kv_fetch": {"fail": 0.4},
                            "kv_pool": {"exhaust": 0.2},
                            "kv_spill": {"fail": 0.25}},
                     max_faults=120)
    eng, out = _serve(cfg, params, work, fault_plan=plan, **tight)
    assert out == baseline
    preempts = sum(r.preemptions for r in eng.scheduler.requests.values())
    assert base_preempts > 0 and preempts > 0, \
        "budget never preempted: the concurrency this test exists for " \
        "did not happen"
    ft = eng.fault_traffic()
    assert ft["injected"].get("kv_fetch/fail", 0) > 0
    assert ft["injected"].get("kv_pool/exhaust", 0) > 0
    assert ft["retries"] > 0
    pool = eng._kv
    pool.check_invariants()
    # free-list conservation: every device/host block is free xor owned
    assert len(pool.free_dev) + int((pool.dev >= 0).sum()) \
        == pool.device_blocks
    assert len(set(pool.free_dev)) == len(pool.free_dev)
    assert len(set(pool.free_host)) == len(pool.free_host)
    # slot-state coherence: drained requests hold no blocks; live rows
    # only map blocks for slots the scheduler says are live
    for grp in eng.scheduler.slots:
        for s in grp:
            idx = eng._slot_of(s)
            if s.state == SlotState.FREE:
                assert not pool.slot_in_use(idx), \
                    f"FREE slot {idx} still owns blocks"

"""Paged weights: pack/fetch roundtrip (property-based), page table math,
transfer plan coverage, in-scan span reconstruction, paged forward equals
resident forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                  # CI installs it; the deterministic
    HAS_HYPOTHESIS = False           # tests below still run bare

from repro.core import paging


def _tree(rng, L, shapes):
    return {f"w{i}": jnp.asarray(rng.normal(0, 1, (L,) + s), jnp.float32)
            for i, s in enumerate(shapes)}


def _check_pack_fetch_roundtrip(L, shapes, page_elems):
    rng = np.random.default_rng(L * 1000 + page_elems)
    tree = _tree(rng, L, shapes)
    pages, manifest = paging.pack_layer_stack(tree, page_elems)
    assert pages.shape == (L * manifest.pages_per_layer, page_elems)
    for layer in range(L):
        got = paging.fetch_layer(pages, manifest, layer)
        for k in tree:
            np.testing.assert_array_equal(got[k], tree[k][layer])


if HAS_HYPOTHESIS:
    @given(st.integers(1, 5), st.integers(1, 4),
           st.lists(st.tuples(st.integers(1, 7), st.integers(1, 9)),
                    min_size=1, max_size=4),
           st.sampled_from([16, 64, 257]))
    @settings(max_examples=40, deadline=None)
    def test_pack_fetch_roundtrip(L, _unused, shapes, page_elems):
        _check_pack_fetch_roundtrip(L, shapes, page_elems)


def test_pack_fetch_roundtrip_seeded():
    for seed in range(8):
        r = np.random.default_rng(seed)
        shapes = [tuple(r.integers(1, 8, 2)) for _ in range(r.integers(1, 5))]
        _check_pack_fetch_roundtrip(int(r.integers(1, 6)), shapes,
                                    int(r.choice([16, 64, 257])))


def test_unflatten_span_equals_fetch_layer(rng):
    tree = _tree(rng, 3, [(4, 5), (2,), (3, 3)])
    pages, manifest = paging.pack_layer_stack(tree, 32)
    span = pages.reshape(3, manifest.pages_per_layer, 32)[1]
    a = paging.unflatten_span(span, manifest)
    b = paging.fetch_layer(pages, manifest, 1)
    for k in tree:
        np.testing.assert_array_equal(a[k], b[k])


def _check_transfer_plan(pages_per_layer, n_ubs):
    plan = paging.transfer_plan(pages_per_layer, n_ubs)
    flat = [p for g in plan for p in g]
    assert flat == list(range(pages_per_layer))
    assert len(plan) == n_ubs
    sizes = [len(g) for g in plan]
    assert max(sizes) - min(sizes) <= 1          # balanced interleave


if HAS_HYPOTHESIS:
    @given(st.integers(1, 64), st.integers(1, 16))
    @settings(max_examples=50, deadline=None)
    def test_transfer_plan_partitions_pages(pages_per_layer, n_ubs):
        _check_transfer_plan(pages_per_layer, n_ubs)


def test_transfer_plan_partitions_pages_seeded():
    for ppl, n in [(1, 1), (5, 2), (64, 16), (7, 9), (16, 4)]:
        _check_transfer_plan(ppl, n)


def test_double_buffer_semantics():
    db = paging.DoubleBuffer()
    s0 = db.load(0)
    s1 = db.load(1)
    assert s0 != s1
    assert db.is_resident(0) and db.is_resident(1)
    db.load(2)                                    # evicts layer 0
    assert db.is_resident(2) and not db.is_resident(0)


def test_paged_forward_matches_resident(rng):
    from repro.configs import get_config
    from repro.models import forward, unembed
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 16)), jnp.int32)
    ref = unembed(cfg, params, forward(cfg, params, toks)["hidden"])
    paged = paging.pack_block_groups(params["blocks"], page_elems=1 << 12)
    got = unembed(cfg, params,
                  forward(cfg, params, toks, paged_blocks=paged)["hidden"])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Split manifests (shared span + per-(layer, expert) spans)
# ---------------------------------------------------------------------------

def _moe_group(rng, L=3, E=4, D=6, F=10):
    return {
        "attn": {"wq": jnp.asarray(rng.normal(0, 1, (L, D, D)), jnp.float32)},
        "attn_norm": {"scale": jnp.asarray(rng.normal(0, 1, (L, D)),
                                           jnp.float32)},
        "moe": {
            "router": jnp.asarray(rng.normal(0, 1, (L, D, E)), jnp.float32),
            "wi": jnp.asarray(rng.normal(0, 1, (L, E, D, 2, F)), jnp.float32),
            "wo": jnp.asarray(rng.normal(0, 1, (L, E, F, D)), jnp.float32),
        },
    }


@pytest.mark.parametrize("page_elems", [16, 64, 257])
def test_split_pack_roundtrip(rng, page_elems):
    """Shared span excludes expert leaves; expert spans rebuild each
    (layer, expert) slice exactly; the page-id table is dense & disjoint."""
    tree = _moe_group(rng)
    shared, experts, sm = paging.pack_layer_stack_split(tree, page_elems)
    L, E = 3, 4
    # shared manifest holds everything except the routed expert leaves
    shared_paths = {e.path for e in sm.shared.leaves}
    assert ("moe", "router") in shared_paths
    assert ("moe", "wi") not in shared_paths
    for layer in range(L):
        got = paging.fetch_layer(shared, sm.shared, layer)
        np.testing.assert_array_equal(got["attn"]["wq"],
                                      tree["attn"]["wq"][layer])
        np.testing.assert_array_equal(got["moe"]["router"],
                                      tree["moe"]["router"][layer])
        assert "wi" not in got["moe"]
    # expert spans: exact per-(layer, expert) reconstruction
    em = sm.experts
    assert experts.shape == (L, E, em.pages_per_expert, em.page_elems)
    for layer in range(L):
        for e in range(E):
            got = paging.unflatten_expert_span(experts[layer, e], em)
            np.testing.assert_array_equal(got["wi"],
                                          tree["moe"]["wi"][layer, e])
            np.testing.assert_array_equal(got["wo"],
                                          tree["moe"]["wo"][layer, e])
    # batched gather unflattens with a leading expert axis
    sel = jnp.asarray([2, 0, 1], jnp.int32)
    got = paging.unflatten_expert_span(experts[1][sel], em)
    np.testing.assert_array_equal(got["wi"], tree["moe"]["wi"][1][sel])
    # page-id table: dense, disjoint cover of the flat pool
    ids = np.concatenate([em.expert_pages(l, e)
                          for l in range(L) for e in range(E)])
    assert sorted(ids.tolist()) == list(range(L * E * em.pages_per_expert))


def test_split_pack_without_experts_matches_whole_layer(rng):
    """A dense group split-packs to shared-only (experts=None), identical
    to the whole-layer manifest."""
    tree = {"ffn": {"wi": jnp.asarray(rng.normal(0, 1, (2, 4, 8)),
                                      jnp.float32)}}
    shared, experts, sm = paging.pack_layer_stack_split(tree, 32)
    assert experts is None and sm.experts is None
    whole, manifest = paging.pack_layer_stack(tree, 32)
    np.testing.assert_array_equal(shared, whole)
    assert sm.shared == manifest


def test_expert_paged_forward_int8_scales_survive(rng):
    """int8 experts: the float32 dequant scales must NOT ride in the
    int8-packed expert pool (that cast truncates them to zero) — they
    stay in the shared span and are gathered per activated expert, so
    the expert-granular forward matches the resident forward."""
    from repro.configs import get_config
    from repro.models import forward, unembed
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("mixtral-8x7b").smoke(),
                              dtype="float32", expert_dtype="int8")
    params = init_params(cfg, jax.random.key(0))
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 8)), jnp.int32)
    ref = unembed(cfg, params, forward(cfg, params, toks)["hidden"])
    assert float(jnp.max(jnp.abs(ref))) > 0
    pw = paging.pack_block_groups_split(params["blocks"], 4096)
    em = pw.expert_manifests["p0"]
    assert {e.path[-1] for e in em.leaves} == {"wi", "wo"}
    assert str(pw.expert_pages["p0"].dtype) == "int8"
    got = unembed(cfg, params,
                  forward(cfg, params, toks, paged_blocks=pw)["hidden"])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_pack_block_groups_split_shapes(rng):
    pw = paging.pack_block_groups_split({"p0": _moe_group(rng)}, 64)
    assert set(pw.expert_manifests) == {"p0"}
    em = pw.expert_manifests["p0"]
    assert pw.pages["p0"].shape[0] == em.num_layers == 3
    assert em.num_experts == 4
    assert em.span_bytes == em.pages_per_expert * em.page_elems * 4
    assert pw.shared_layer_bytes("p0") == \
        pw.manifests["p0"].pages_per_layer * 64 * 4

"""Paged weights: pack/fetch roundtrip (property-based), page table math,
transfer plan coverage, in-scan span reconstruction, paged forward equals
resident forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import paging


def _tree(rng, L, shapes):
    return {f"w{i}": jnp.asarray(rng.normal(0, 1, (L,) + s), jnp.float32)
            for i, s in enumerate(shapes)}


@given(st.integers(1, 5), st.integers(1, 4),
       st.lists(st.tuples(st.integers(1, 7), st.integers(1, 9)),
                min_size=1, max_size=4),
       st.sampled_from([16, 64, 257]))
@settings(max_examples=40, deadline=None)
def test_pack_fetch_roundtrip(L, _unused, shapes, page_elems):
    rng = np.random.default_rng(L * 1000 + page_elems)
    tree = _tree(rng, L, shapes)
    pages, manifest = paging.pack_layer_stack(tree, page_elems)
    assert pages.shape == (L * manifest.pages_per_layer, page_elems)
    for layer in range(L):
        got = paging.fetch_layer(pages, manifest, layer)
        for k in tree:
            np.testing.assert_array_equal(got[k], tree[k][layer])


def test_unflatten_span_equals_fetch_layer(rng):
    tree = _tree(rng, 3, [(4, 5), (2,), (3, 3)])
    pages, manifest = paging.pack_layer_stack(tree, 32)
    span = pages.reshape(3, manifest.pages_per_layer, 32)[1]
    a = paging.unflatten_span(span, manifest)
    b = paging.fetch_layer(pages, manifest, 1)
    for k in tree:
        np.testing.assert_array_equal(a[k], b[k])


@given(st.integers(1, 64), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_transfer_plan_partitions_pages(pages_per_layer, n_ubs):
    plan = paging.transfer_plan(pages_per_layer, n_ubs)
    flat = [p for g in plan for p in g]
    assert flat == list(range(pages_per_layer))
    assert len(plan) == n_ubs
    sizes = [len(g) for g in plan]
    assert max(sizes) - min(sizes) <= 1          # balanced interleave


def test_double_buffer_semantics():
    db = paging.DoubleBuffer()
    s0 = db.load(0)
    s1 = db.load(1)
    assert s0 != s1
    assert db.is_resident(0) and db.is_resident(1)
    db.load(2)                                    # evicts layer 0
    assert db.is_resident(2) and not db.is_resident(0)


def test_paged_forward_matches_resident(rng):
    from repro.configs import get_config
    from repro.models import forward, unembed
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_config("qwen2.5-3b").smoke(),
                              dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 16)), jnp.int32)
    ref = unembed(cfg, params, forward(cfg, params, toks)["hidden"])
    paged = paging.pack_block_groups(params["blocks"], page_elems=1 << 12)
    got = unembed(cfg, params,
                  forward(cfg, params, toks, paged_blocks=paged)["hidden"])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)

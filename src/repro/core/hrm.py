"""Hierarchical Roofline Model (paper §3).

Extends the classical roofline (Williams et al.) to a hierarchy of memory
levels, each optionally coupled to a processor, with cross-level bandwidth
links.  Implements Eqs. (1)–(11) of the paper:

  * per-level compute roof          P_x^i <= P_peak^i                  (4)
  * per-level memory roof           P_x^i <= B_peak^i * I_x^i          (5)
  * cross-level memory roof         P_x^i <= B_peak^{j,i} * I_x^j      (6)
  * attainable perf w/ fetch        min of the three                   (7)
  * attainable perf local           min(P_peak, B*I)                   (8)
  * turning point P1 (don't move)   Ī = min(P_j, B_j I_j) / B_{j,i}    (9)
  * turning point P2 (xfer-bound)   Ī = min(P_i, B_i I_i) / B_{j,i}    (10)
  * balance point                   B_i I_i == B_{j,i} I_j             (11)

Levels are identified by name ("gpu", "cpu", "hbm", "host", ...).  The same
code produces the paper's L4/T4 analysis (Figs. 4/5/10) and the TPU-v5e
analysis used by the launch-time policy search.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware description
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Level:
    name: str
    p_peak: float            # FLOP/s of the processor at this level (0: none)
    b_peak: float            # local memory bandwidth, bytes/s
    capacity: float          # bytes


@dataclass(frozen=True)
class Hardware:
    """A hierarchy: levels ordered fast->slow, plus cross-level links."""
    levels: Tuple[Level, ...]
    links: Dict[Tuple[str, str], float] = field(default_factory=dict)
    name: str = "custom"

    def level(self, name: str) -> Level:
        for l in self.levels:
            if l.name == name:
                return l
        raise KeyError(name)

    def link_bw(self, src: str, dst: str) -> float:
        if (src, dst) in self.links:
            return self.links[(src, dst)]
        if (dst, src) in self.links:
            return self.links[(dst, src)]
        raise KeyError((src, dst))


# Hardware presets.  GPU/CPU numbers follow the paper's Fig. 3 / §6.3 (L4
# instance; T4 from public specs); TPU v5e numbers are the task-assigned
# constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, plus an
# assumed 16 GB/s/host PCIe (8 chips/host -> 2 GB/s/chip) for host offload.

def preset(name: str) -> Hardware:
    G = 1e9
    presets = {
        "t4": Hardware(
            levels=(Level("gpu", 65e12, 300 * G, 16 * G),
                    Level("cpu", 1.6e12, 80 * G, 192 * G)),
            links={("cpu", "gpu"): 12 * G}, name="t4"),
        "l4": Hardware(
            levels=(Level("gpu", 121e12, 300 * G, 24 * G),
                    Level("cpu", 1.6e12, 80 * G, 192 * G)),
            links={("cpu", "gpu"): 25 * G}, name="l4"),
        "a100x2": Hardware(
            levels=(Level("gpu", 2 * 312e12, 2 * 2039 * G, 160 * G),
                    Level("cpu", 1.6e12, 200 * G, 1000 * G)),
            links={("cpu", "gpu"): 100 * G}, name="a100x2"),
        # TPU v5e: "gpu" = one chip; "ici" = a PEER chip's HBM (the peer has
        # its own MXU — computing where the KV shard lives is the
        # sequence-sharded decode attention of collectives.py); "cpu" = the
        # weak host over PCIe.  Task constants: 197 TF bf16, 819 GB/s HBM,
        # ~50 GB/s/link ICI; host assumed 16 GB/s per 8-chip host.
        "v5e": Hardware(
            levels=(Level("gpu", 197e12, 819 * G, 16 * G),
                    Level("ici", 197e12, 819 * G, 255 * 16 * G),
                    Level("cpu", 0.4e12, 50 * G, 256 * G)),
            links={("cpu", "gpu"): 2 * G, ("ici", "gpu"): 50 * G},
            name="v5e"),
    }
    return presets[name]


def measured_link_bw(path: str = "BENCH_transfer.json"):
    """Measured host→device bandwidth (bytes/s) from a
    benchmarks/bench_transfer.py artifact: the pinned-path figure when the
    backend had a pinned_host space, else the pageable figure.  Returns
    None when the artifact is absent/malformed or the run was
    interpret/CPU (bench_transfer records null bandwidths there — a CPU
    'transfer' is a memcpy and would poison the roofline)."""
    import json
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    bw = data.get("h2d_pinned_bytes_per_s") or data.get(
        "h2d_pageable_bytes_per_s")
    return float(bw) if bw else None


def with_measured_links(hw: Hardware, path: str = "BENCH_transfer.json"
                        ) -> Hardware:
    """The roofline's cpu→gpu link term replaced by the *measured* H2D
    bandwidth when a bench_transfer artifact is on disk — the paper's
    HRM uses spec-sheet constants, but achieved PCIe/DMA rates routinely
    sit 20–40% under spec and the T_pre/T_dec bounds inherit the error.
    No artifact → the preset is returned unchanged."""
    bw = measured_link_bw(path)
    if bw is None:
        return hw
    links = dict(hw.links)
    links[("cpu", "gpu")] = bw
    return Hardware(hw.levels, links, name=f"{hw.name}+measured")


# ---------------------------------------------------------------------------
# Roofline math
# ---------------------------------------------------------------------------

def attainable_local(hw: Hardware, level: str, intensity: float) -> float:
    """Eq. (8): performance of a computation resident at `level`."""
    l = hw.level(level)
    return min(l.p_peak, l.b_peak * intensity)


def attainable_cross(hw: Hardware, exec_level: str, data_level: str,
                     i_exec: float, i_data: float) -> float:
    """Eq. (7): executed at exec_level, data fetched from data_level."""
    l = hw.level(exec_level)
    bw = hw.link_bw(data_level, exec_level)
    return min(l.p_peak, l.b_peak * i_exec, bw * i_data)


def turning_point_p1(hw: Hardware, exec_level: str, data_level: str,
                     i_data_local: float) -> float:
    """Eq. (9): critical I below which it is better to compute at
    data_level than to move the data up to exec_level."""
    bw = hw.link_bw(data_level, exec_level)
    return attainable_local(hw, data_level, i_data_local) / bw


def turning_point_p2(hw: Hardware, exec_level: str, data_level: str,
                     i_exec_local: float) -> float:
    """Eq. (10): critical I below which the cross-level link binds."""
    bw = hw.link_bw(data_level, exec_level)
    return attainable_local(hw, exec_level, i_exec_local) / bw


def balance_point_intensity(hw: Hardware, exec_level: str, data_level: str,
                            i_exec: float) -> float:
    """Eq. (11): the I_x^j at which local and cross-level bandwidth bind
    simultaneously: B_i I_i = B_{j,i} I_j  ->  I_j = B_i I_i / B_{j,i}."""
    bw = hw.link_bw(data_level, exec_level)
    return hw.level(exec_level).b_peak * i_exec / bw


def should_compute_at_data(hw: Hardware, exec_level: str, data_level: str,
                           i_data: float) -> bool:
    """The paper's CPU-attention criterion: if the task's intensity w.r.t.
    the data level is below P1's critical intensity, don't move the data."""
    return i_data < turning_point_p1(hw, exec_level, data_level, i_data)


# ---------------------------------------------------------------------------
# LLM decode-layer workload model (paper §4.2, Table 1 notation)
# ---------------------------------------------------------------------------

@dataclass
class LayerWorkload:
    """Theoretical per-layer flops/bytes for one decode step of a batch.

    All quantities are for ONE transformer layer processing N tokens
    (batch) with average context length `ctx`.

    For MoE layers the weight bytes are split: ``bytes_w_shared``
    (attention projections + shared experts — touched every step) vs
    ``bytes_w_expert`` (the *activated* routed-expert bytes, whose H2D
    traffic the expert-granular residency cache can absorb at the
    measured/assumed ``popularity`` hit rate).  ``bytes_w`` stays their
    sum for the intensity definitions."""
    flops_attn: float        # attention score+value flops (excl. qkvo proj)
    bytes_kv: float          # KV cache bytes touched
    flops_ffn: float         # FFN (MoE) flops incl. router+shared
    bytes_w: float           # layer weight bytes (experts + attn proj)
    bytes_hidden: float      # D1/D2-class transfers: activations per ub hop
    flops_proj: float        # qkvo projection flops
    bytes_w_shared: float = 0.0   # non-routed weight bytes (= bytes_w if dense)
    bytes_w_expert: float = 0.0   # expected activated routed-expert bytes
    num_experts: int = 0          # routed expert count (0 = dense layer)
    popularity: Optional[object] = None  # (E,) or (L, E) routing frequency
    kv_hit: Optional[float] = None  # measured device-hit fraction of KV
    # block touches (core.blockpool counters); None -> the r_c-linear
    # placement assumption (resident fraction == hit fraction)
    predictor_accuracy: float = 0.0  # measured GatePredictor.acc (engine's
    # weight_traffic()['predictor_accuracy']); feeds the intra-pass
    # prefetch term of expert_hit_rate when the policy predicts

    @classmethod
    def decode(cls, cfg, batch: int, ctx: float, dtype_bytes: int = 2,
               experts_hit: Optional[float] = None, popularity=None,
               kv_hit: Optional[float] = None,
               block_tokens: Optional[int] = None,
               predictor_accuracy: float = 0.0):
        """``block_tokens``: set for the block-granular paged pool — the
        page-table-native decode kernels gather whole blocks, so the KV
        bytes touched per step round ``ctx`` up to the mapped-block
        footprint (what Engine.kv_traffic()'s gathered-bytes counters
        measure), not the raw token count."""
        h1 = cfg.d_model
        hd = cfg.head_dim or 1
        nq = max(cfg.num_heads, 1)
        nkv = max(cfg.num_kv_heads, 1)
        if cfg.kv_lora_rank:               # MLA: latent cache
            kv_row = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            flops_attn = 2 * batch * ctx * nq * (cfg.kv_lora_rank
                                                 + cfg.qk_rope_head_dim) * 2
        else:
            kv_row = 2 * nkv * hd
            flops_attn = 2 * batch * ctx * nq * hd * 2
        kv_ctx = ctx
        if block_tokens:
            kv_ctx = block_tokens * math.ceil(ctx / block_tokens)
        bytes_kv = batch * kv_ctx * kv_row * dtype_bytes

        w_expert = 0.0
        num_experts = 0
        if cfg.is_moe:
            k = cfg.top_k + cfg.num_shared_experts
            f_flops = 2 * 3 * h1 * cfg.d_ff * k * batch
            n_hit = experts_hit if experts_hit is not None else min(
                cfg.num_experts, batch * cfg.top_k)
            w_ffn = (n_hit + cfg.num_shared_experts) * 3 * h1 * cfg.d_ff
            w_expert = n_hit * 3 * h1 * cfg.d_ff
            num_experts = cfg.num_experts
        else:
            f_flops = 2 * 3 * h1 * (cfg.d_ff or cfg.ssm_expand * h1) * batch
            w_ffn = 3 * h1 * (cfg.d_ff or cfg.ssm_expand * h1)
        w_attn = (2 * h1 * nq * hd + 2 * h1 * nkv * hd) if nq else 0
        flops_proj = 2 * w_attn * batch
        bytes_w = (w_ffn + w_attn) * dtype_bytes
        return cls(flops_attn=flops_attn, bytes_kv=bytes_kv, flops_ffn=f_flops,
                   bytes_w=bytes_w,
                   bytes_hidden=2 * batch * h1 * dtype_bytes,
                   flops_proj=flops_proj,
                   bytes_w_shared=bytes_w - w_expert * dtype_bytes,
                   bytes_w_expert=w_expert * dtype_bytes,
                   num_experts=num_experts, popularity=popularity,
                   kv_hit=kv_hit, predictor_accuracy=predictor_accuracy)

    # Operational intensities (paper Definition 3.1)
    def intensity_attn_vs_kv(self) -> float:
        return self.flops_attn / max(self.bytes_kv, 1.0)

    def intensity_ffn_vs_weights(self) -> float:
        return self.flops_ffn / max(self.bytes_w, 1.0)


def kv_block_hit_rate(kv_gpu_ratio: float, num_ubs: int = 1) -> float:
    """Expected device-hit fraction of a decode step's KV block touches
    under the block-granular paged cache with CGOPipe rotation.

    The arena holds ``r_c`` of the total KV blocks, but only the decoding
    group's blocks — ``1/num_ubs`` of the total — are touched per step,
    so the fraction of the active working set still resident when its
    turn comes back around is ``min(1, r_c · num_ubs)`` under fair
    (oldest-first) spilling.  ``num_ubs = 1`` degenerates to the dense
    placement assumption hit = r_c; rotation is exactly what makes a
    small arena disproportionately effective — the same shape as
    ``expert_hit_rate`` for skewed routing.  KV traffic per layer is then
    ``miss_rate × touched block bytes`` (each transfer moves whole
    blocks, which is what the engine's BlockPool counters measure).
    Since the page-table-native decode kernels gather exactly the mapped
    blocks (``Engine.kv_traffic()``'s gathered-bytes/step), this modeled
    term now matches what the device executes — pass
    ``LayerWorkload.decode(..., block_tokens=…)`` so the touched bytes
    round to whole blocks too."""
    r = min(max(kv_gpu_ratio, 0.0), 1.0)
    return float(min(1.0, r * max(1, num_ubs)))


def _top_mass(p, slots: float, num_experts: int) -> float:
    """Retained routing mass when the hottest ``slots`` experts (fractional
    slots prorated) of a normalized (rows, E) popularity matrix are
    resident; averaged over rows."""
    import numpy as np
    k = int(slots)
    frac = slots - k
    srt = np.sort(p, axis=1)[:, ::-1]
    hit = srt[:, :k].sum(axis=1)
    if k < num_experts:
        hit = hit + frac * srt[:, k]
    return float(np.clip(hit.mean(), 0.0, 1.0))


def expert_hit_rate(w_gpu_ratio: float, num_experts: int,
                    popularity=None, predictor_accuracy: float = 0.0,
                    predict_lookahead: int = 0,
                    replicate_frac: Optional[float] = None) -> float:
    """Expected P(activated expert span is on-device when its layer
    dispatches) under the residency cache (core.residency) with a pool
    sized by the policy's ``r_w``.

    Uniform routing → exactly ``r_w`` (the whole-layer model's implicit
    assumption).  A measured popularity vector — (E,) or per-layer
    (L, E), e.g. the residency EWMA table — → the retained top mass,
    which is ≥ r_w: skewed routing makes a small cache disproportionately
    effective, and this is precisely what lets the policy search trade
    ``r_w`` against hit rate instead of against raw resident bytes.

    ``replicate_frac`` (None = no replication, legacy model): a fraction
    of the ``r_w·E`` slots is pinned persistently to the popularity-top
    experts (hysteresis keeps them through window turnover), whose mass
    always hits; the remaining non-pinned slots are modeled
    conservatively as a uniform share of the residual mass — pinning
    guarantees the head of the distribution at the cost of popularity
    targeting in the tail, which is the trade ``policy.search`` sweeps.

    ``predictor_accuracy`` (GatePredictor.acc) with
    ``predict_lookahead ≥ 1``: intra-pass predicted prefetch converts a
    would-be miss into a hit when the predictor called the expert and the
    span landed in time — modeled as acc discounted by ℓ/(ℓ+1) (a
    1-layer lookahead hides only spans whose transfer fits one layer's
    compute; deeper lookahead approaches full overlap)."""
    import numpy as np
    r = min(max(w_gpu_ratio, 0.0), 1.0)
    if num_experts <= 0:
        return r
    if popularity is None:
        p = np.full((1, num_experts), 1.0 / num_experts)
    else:
        p = np.atleast_2d(np.asarray(popularity, float))
        sums = p.sum(axis=1, keepdims=True)
        uniform = np.full_like(p, 1.0 / num_experts)
        p = np.where(sums > 0, p / np.maximum(sums, 1e-30), uniform)
    slots = r * num_experts
    if replicate_frac is None:
        hit = r if popularity is None else _top_mass(p, slots, num_experts)
    else:
        rf = min(max(float(replicate_frac), 0.0), 1.0)
        rep_slots = rf * slots
        m_rep = _top_mass(p, rep_slots, num_experts)
        rest_experts = max(num_experts - rep_slots, 1e-9)
        hit_rest = (1.0 - m_rep) * min(1.0, (slots - rep_slots)
                                       / rest_experts)
        hit = min(1.0, m_rep + hit_rest)
    acc = min(max(float(predictor_accuracy), 0.0), 1.0)
    la = max(int(predict_lookahead), 0)
    if acc > 0.0 and la > 0:
        hit = hit + (1.0 - hit) * acc * (la / (la + 1.0))
    return float(np.clip(hit, 0.0, 1.0))


# ---------------------------------------------------------------------------
# Paper-style per-layer latency estimate (Eqs. 12–14)
# ---------------------------------------------------------------------------

def time_comp(flops: float, p_peak: float) -> float:
    return flops / p_peak if p_peak > 0 else float("inf")


def time_comm(bytes_: float, bw: float) -> float:
    return bytes_ / bw if bw > 0 else float("inf")


def layer_latency(hw: Hardware, wl: LayerWorkload, policy) -> Dict[str, float]:
    """T = max(comm_cpu_to_gpu, T_cpu, T_gpu) — Eq. (12) with Eq. (13)/(14).

    `policy` needs fields: attn_on_gpu (A_g), ffn_on_gpu (F_g),
    w_gpu_ratio (r_w), kv_gpu_ratio (r_c).
    """
    gpu, cpu = hw.level("gpu"), hw.level("cpu")
    b_cg = hw.link_bw("cpu", "gpu")

    comm_ctg = 0.0           # CPU->GPU transferred bytes per layer
    t_gpu = t_cpu = 0.0

    # ---- attention ----
    if policy.attn_on_gpu:
        # KV traffic term: miss rate × touched KV bytes.  The default
        # (kv_hit = r_c) is the dense placement assumption — a fixed r_c
        # fraction resident; a measured/modelled block hit rate (paged
        # pool, kv_block_hit_rate) lets the search trade r_c against r_w
        # on the same link budget.
        kv_hit = wl.kv_hit if wl.kv_hit is not None else policy.kv_gpu_ratio
        kv_from_cpu = wl.bytes_kv * (1 - kv_hit)
        comm_ctg += kv_from_cpu
        t_attn = max(time_comp(wl.flops_attn, gpu.p_peak),
                     time_comm(wl.bytes_kv * kv_hit, gpu.b_peak)
                     + time_comm(kv_from_cpu, b_cg))
        t_gpu += t_attn
    else:
        t_attn = max(time_comp(wl.flops_attn, cpu.p_peak),
                     time_comm(wl.bytes_kv, cpu.b_peak))
        t_cpu += t_attn
        comm_ctg += wl.bytes_hidden      # D2: hidden states back to GPU

    # ---- FFN ----
    if policy.ffn_on_gpu:
        # module-based batching (policy.module_groups = G > 1): each
        # streamed weight span serves G rotation groups' staged tokens
        # per accumulation window, so per-layer-pass weight traffic
        # amortizes by 1/G.  The staging-buffer memory this buys is
        # charged in policy.memory_usage, so the search trades the two
        # on one budget.
        mg = max(1, int(getattr(policy, "module_groups", 1) or 1))
        if wl.num_experts and wl.bytes_w_expert:
            # expert-granular paging: the shared span streams at (1-r_w)
            # as before, but the routed-expert traffic is *expected
            # activated bytes × miss rate* — the residency cache absorbs
            # the hits, so r_w buys hit rate, not just resident bytes
            hit = expert_hit_rate(
                policy.w_gpu_ratio, wl.num_experts, wl.popularity,
                predictor_accuracy=wl.predictor_accuracy,
                predict_lookahead=getattr(policy, "predict_lookahead", 0),
                replicate_frac=getattr(policy, "replicate_frac", None))
            w_from_cpu = (wl.bytes_w_shared * (1 - policy.w_gpu_ratio)
                          + wl.bytes_w_expert * (1 - hit)) / mg
        else:
            w_from_cpu = wl.bytes_w * (1 - policy.w_gpu_ratio) / mg
        comm_ctg += w_from_cpu
        t_ffn = max(time_comp(wl.flops_ffn + wl.flops_proj, gpu.p_peak),
                    time_comm(wl.bytes_w, gpu.b_peak))
        t_gpu += t_ffn
    else:
        t_ffn = max(time_comp(wl.flops_ffn + wl.flops_proj, cpu.p_peak),
                    time_comm(wl.bytes_w, cpu.b_peak))
        t_cpu += t_ffn

    t_io = time_comm(comm_ctg, b_cg)
    return {"t_layer": max(t_io, t_cpu, t_gpu), "t_io": t_io,
            "t_cpu": t_cpu, "t_gpu": t_gpu, "comm_bytes": comm_ctg}

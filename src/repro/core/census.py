"""Exact per-step op census: FLOPs, HBM bytes and collective bytes for
one (architecture × shape × sharding plan) cell.

Why this exists: XLA's ``cost_analysis()`` counts a ``lax.scan`` body ONCE
(not × trip count), so a scanned-layer model under-reports FLOPs/bytes by
~num_layers×.  The roofline terms therefore come from this census — the
same methodology as the paper's performance model ("theoretically
calculated computation flops and bytes with profiled peak performance and
memory bandwidth", §4.2) — while the compiled HLO remains the source of
truth for (a) memory_analysis (fits-per-chip) and (b) the collective
*schedule* (which ops XLA actually inserted), cross-checked against the
trip-scaled HLO parse done by launch.dryrun.

Conventions:
  * FLOPs: 2·M·N·K per matmul (XLA's convention).
  * HBM bytes (per chip): every weight shard read once per step (3× for
    training: fwd, bwd-wrt-act, bwd-wrt-weight each re-read), KV bytes
    read once per decode step, activations charged ACT_RT round-trips of
    (B,S,D) per layer.
  * Collective bytes (per chip): ring all-reduce of N bytes ≈ 2N wire
    bytes; all-gather/reduce-scatter ≈ N; all-to-all ≈ N.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ATTN_MLA, ATTN_NONE, ATTN_WINDOW, ModelConfig, \
    ShapeConfig

ACT_RT = 6          # activation (B,S,D)-equivalents touched per layer
TRAIN_FLOP_MULT = 3   # bwd = 2x fwd
TRAIN_BYTE_MULT = 3


@dataclass
class Census:
    flops: float = 0.0            # total, whole step, all chips
    hbm_bytes: float = 0.0        # per chip
    coll_bytes: Dict[str, float] = field(default_factory=dict)  # per chip

    def add_coll(self, kind: str, nbytes: float):
        self.coll_bytes[kind] = self.coll_bytes.get(kind, 0.0) + nbytes

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def _axsize(mesh_shape: Dict[str, int], axes) -> int:
    if not axes:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return n


def census(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: Dict[str, int],
           plan=None, dtype_bytes: int = 2) -> Census:
    """plan: distributed.sharding.Plan (for dp/kv/expert axes); falls back
    to sensible defaults when None."""
    c = Census()
    chips = math.prod(mesh_shape.values())
    dp_axes = (plan.dp_axes if plan is not None else
               tuple(a for a in ("pod", "data") if a in mesh_shape))
    dp = _axsize(mesh_shape, dp_axes)
    tp = mesh_shape.get("model", 1)

    B, S = shape.global_batch, shape.seq_len
    train = shape.mode == "train"
    decode = shape.mode == "decode"
    tokens = B * (1 if decode else S)
    B_loc = B / dp
    tok_loc = tokens / dp

    E, Dh = cfg.d_model, cfg.head_dim or 0
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    fmult = TRAIN_FLOP_MULT if train else 1
    bmult = TRAIN_BYTE_MULT if train else 1
    cmult = 2 if train else 1        # collectives: fwd + bwd mirror
    # stationary 2D-sharded weights at inference: the embed-dim shard also
    # divides per-chip weight traffic (training re-gathers, so full/tp)
    wshard = tp
    if not train and plan is not None:
        wshard = tp * _axsize(mesh_shape, plan.rules.get("embed"))

    # ---------------- embedding + loss head ----------------
    c.flops += 2.0 * tokens * E * cfg.vocab_size * fmult   # unembed (+loss)
    if train:
        c.flops += 0  # embed gather is bytes, not flops
    # embedding table + head weights read once (sharded over vocab/model)
    c.hbm_bytes += (cfg.vocab_size * E * dtype_bytes / tp) * bmult * \
        (1 if cfg.tie_embeddings else 2)
    if tp > 1:
        # vocab-sharded logits: psum/all-gather of (tok, V/tp) partials is
        # avoided by sharded loss; we charge the label psum only (small).
        c.add_coll("all-reduce", 2 * tok_loc * 4)

    # ---------------- per-layer census ----------------
    specs = list(cfg.prologue) + [s for _ in range(cfg.num_periods)
                                  for s in cfg.period]
    expert_ax = _axsize(mesh_shape, plan.expert_axes) if (
        plan and plan.expert_axes) else 1
    kv_ax = _axsize(mesh_shape, plan.kv_axes) if (plan and plan.kv_axes) else 1

    for spec in specs:
        # ---- attention / mamba mixer ----
        if spec.kind == "attn" and spec.attn != ATTN_NONE:
            if spec.attn == ATTN_MLA:
                r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
                dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
                w_attn = (E * cfg.q_lora_rank + cfg.q_lora_rank * nq * (dn + dr)
                          + E * r + E * dr + r * nq * dn + r * nq * dv
                          + nq * dv * E)
                kv_row = (r + dr)
                if decode:
                    # absorbed decode: q @ Wuk (per head) + latent attention
                    c.flops += 2.0 * B * nq * (r * dn + dv * r) \
                        + 4.0 * B * nq * S * (r + dr)
                else:
                    ctx = S
                    c.flops += (2.0 * tokens * w_attn
                                + 2.0 * tokens * nq * (dn + dr) * ctx / 2 * 2
                                ) * fmult
            else:
                w_attn = E * nq * Dh + 2 * E * nkv * Dh + nq * Dh * E
                kv_row = 2 * nkv * Dh * dtype_bytes
                if getattr(cfg, "kv_dtype", "") == "int8":
                    kv_row = 2 * nkv * (Dh + 4)      # int8 + f32 scale
                kv_row /= dtype_bytes                # normalized below
                win = cfg.window_size if spec.attn == ATTN_WINDOW else 0
                if decode:
                    ctx = min(win, S) if win else S
                    c.flops += 2.0 * B * w_attn + 4.0 * B * nq * Dh * ctx
                else:
                    ctx = min(win, S) if win else S / 2   # causal avg
                    c.flops += (2.0 * tokens * w_attn
                                + 4.0 * tokens * nq * Dh * ctx) * fmult
            c.hbm_bytes += w_attn * dtype_bytes / wshard * bmult
            if decode:
                # KV read: rows sharded over dp x kv_ax
                c.hbm_bytes += B_loc * S * kv_row * dtype_bytes / kv_ax
                # seq-sharded attention: broadcast q + lse psum of o
                if kv_ax > 1:
                    qo = B_loc * nq * (Dh if spec.attn != ATTN_MLA
                                       else cfg.kv_lora_rank) * 4
                    c.add_coll("all-reduce", 2 * 2 * qo)
            else:
                c.hbm_bytes += tok_loc * kv_row * dtype_bytes * bmult
        elif spec.kind == "mamba":
            d_in = cfg.ssm_expand * E
            nh = d_in // cfg.ssm_head_dim
            N = cfg.ssm_state
            w_m = 2 * E * d_in + 2 * E * N + E * nh + d_in * E
            if decode:
                c.flops += 2.0 * B * w_m + 2.0 * B * d_in * N * 2
            else:
                # SSD chunked: intra-chunk (L) + inter-chunk state
                L = cfg.ssm_chunk
                c.flops += (2.0 * tokens * w_m
                            + 2.0 * tokens * L / 2 * (nh + N)     # CB/decay
                            + 4.0 * tokens * N * d_in) * fmult
            c.hbm_bytes += w_m * dtype_bytes / wshard * bmult
            c.hbm_bytes += (B_loc * nh * cfg.ssm_head_dim * N * 4 / tp
                            if decode else 0)

        # ---- FFN ----
        if spec.ffn:
            if spec.moe:
                F = cfg.d_ff
                k_eff = cfg.top_k + cfg.num_shared_experts
                cf = cfg.capacity_factor if train else 1.0
                c.flops += 2.0 * 3 * tokens * E * F * (cfg.top_k * cf
                                                       + cfg.num_shared_experts) * fmult
                c.flops += 2.0 * tokens * E * cfg.num_experts * fmult  # router
                # expert weights per chip (int8 experts halve the traffic)
                ebytes = 1 if getattr(cfg, "expert_dtype", "") == "int8" \
                    else dtype_bytes
                w_exp = cfg.num_experts * 3 * E * F * ebytes / expert_ax
                ffn_shard = _axsize(mesh_shape,
                                    plan.rules.get("effn") if plan else None)
                c.hbm_bytes += w_exp / ffn_shard * bmult
                if cfg.num_shared_experts:
                    c.hbm_bytes += 3 * E * F * cfg.num_shared_experts * \
                        dtype_bytes / wshard * bmult
                # dispatch collectives
                if plan and plan.moe_variant == "ep_a2a":
                    # tokens are sharded over dp ∪ expert_axes for the a2a
                    shard_axes = set(dp_axes) | set(plan.expert_axes)
                    tok_a2a = tokens / _axsize(mesh_shape, tuple(shard_axes))
                    c.add_coll("all-to-all",
                               2 * tok_a2a * E * dtype_bytes
                               * cfg.top_k * cf * cmult)
                elif plan and plan.moe_variant == "ep_psum":
                    c.add_coll("all-reduce",
                               2 * tok_loc * E * dtype_bytes * cmult)
                elif expert_ax > 1:   # grouped_pjit: partitioner moves acts
                    shard_axes = set(dp_axes) | set(plan.expert_axes
                                                    if plan else ())
                    tok_a2a = tokens / _axsize(mesh_shape, tuple(shard_axes))
                    c.add_coll("all-to-all",
                               2 * tok_a2a * E * dtype_bytes
                               * cfg.top_k * cf * cmult)
                elif plan and plan.rules.get("effn") == "model" and tp > 1:
                    # ffn-dim-sharded experts (mixtral on a 16-wide axis):
                    # TP-style activation all-reduce per layer
                    c.add_coll("all-reduce",
                               2 * 2 * tok_loc * E * dtype_bytes * cmult)
            else:
                F = cfg.dense_d_ff or cfg.d_ff
                c.flops += 2.0 * 3 * tokens * E * F * fmult
                c.hbm_bytes += 3 * E * F * dtype_bytes / wshard * bmult
                if tp > 1:
                    # TP FFN+attn output psums (2 per layer, ring 2N)
                    c.add_coll("all-reduce",
                               2 * 2 * tok_loc * E * dtype_bytes * cmult)
        # activations
        c.hbm_bytes += ACT_RT * tok_loc * E * dtype_bytes * bmult

    # ---------------- FSDP weight all-gathers (training) ----------------
    # Only NON-expert params are FSDP-gathered: expert weights are consumed
    # inside shard_map with their native ('data','model')/EP sharding and
    # are never materialized unsharded.
    from repro.models.params import count_params
    n_expert = 0
    if cfg.is_moe:
        n_moe_layers = sum(1 for s in specs if s.moe)
        n_expert = (cfg.num_experts * 3 * E * cfg.d_ff * n_moe_layers)
    n_dense = count_params(cfg) - n_expert
    if plan and plan.rules.get("embed") == "data" and train:
        shard = n_dense * dtype_bytes / chips
        # all-gather fwd + bwd, reduce-scatter grads (per-chip wire bytes)
        c.add_coll("all-gather", 2 * shard * (dp - 1))
        c.add_coll("reduce-scatter", shard * (dp - 1))
    if train and mesh_shape.get("pod", 1) > 1:
        # cross-pod gradient all-reduce over DCN (per-chip f32 grads);
        # int8 error-feedback compression (distributed.compression) cuts
        # this 4x when enabled
        grad_bytes = count_params(cfg) * 4 / (chips / mesh_shape["pod"])
        c.add_coll("all-reduce(pod)", 2 * grad_bytes)

    # optimizer traffic (training): read p, mu, nu; write p, mu, nu
    if train:
        from repro.models.params import count_params
        per_chip_params = count_params(cfg) / chips
        c.hbm_bytes += per_chip_params * (2 + 4 + 4) * 2

    return c

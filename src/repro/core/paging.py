"""Paged weights (paper Appendix A.1, Fig. 11).

Layer weights are chunked into fixed-size *pages*; a page table maps
(layer, leaf) → page span.  The serving engine keeps a 2×W_L double
buffer: while layer i computes out of buffer (i % 2), the pages of layer
i+1 stream into buffer ((i+1) % 2), interleaved with hidden-state
transfers per CGOPipe.  On TPU the backing store lives in host memory
(``memory_kind='pinned_host'``) and pages move with device_put; on the
CPU-only validation platform the same code paths run with plain arrays.

The page pool layout is (num_pages, page_elems) so a layer fetch is a
single contiguous gather — the TPU analogue of the paper's paged
cudaMemcpyAsync batches, and the unit the Pallas MoE-FFN kernel's page
table indexes into.

Two manifest granularities:

  * whole-layer (``pack_layer_stack`` / ``pack_block_groups``): one flat
    span per layer; every page streams every layer — the paper's baseline
    layout, kept as the reference path;
  * split (``pack_layer_stack_split`` / ``pack_block_groups_split``): each
    layer's manifest is divided into a *shared* span (attention / norm /
    router / shared-expert leaves, streamed every layer as before) and
    per-(layer, expert) spans for the routed expert weights, with a
    ``(layer, expert) → page ids`` table.  Top-k routing touches only a
    fraction of the experts, so the serving engine can gather just the
    activated experts' spans (core.residency keeps the popular ones
    device-resident) instead of the full E-expert block.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LeafEntry:
    path: Tuple[str, ...]
    shape: Tuple[int, ...]       # per-layer shape (stack dim removed)
    dtype: str
    offset: int                  # element offset within the layer's flat span


@dataclass
class PageManifest:
    page_elems: int
    layer_elems: int             # padded flat elements per layer
    pages_per_layer: int
    num_layers: int
    leaves: List[LeafEntry]
    dtype: str

    def layer_pages(self, layer: int) -> np.ndarray:
        start = layer * self.pages_per_layer
        return np.arange(start, start + self.pages_per_layer)


def _flatten_with_paths(tree, prefix=()):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _flatten_with_paths(tree[k], prefix + (k,))
        return out
    return [(prefix, tree)]


def pack_layer_stack(stacked: Dict, page_elems: int = 1 << 20
                     ) -> Tuple[jax.Array, PageManifest]:
    """stacked: pytree whose every leaf has a leading `layers` dim L.
    Returns (pages (P, page_elems), manifest)."""
    leaves = _flatten_with_paths(stacked)
    L = leaves[0][1].shape[0]
    dtype = leaves[0][1].dtype
    entries: List[LeafEntry] = []
    offset = 0
    for path, leaf in leaves:
        assert leaf.shape[0] == L, f"stack dim mismatch at {path}"
        per_layer = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        entries.append(LeafEntry(path, tuple(leaf.shape[1:]), str(leaf.dtype),
                                 offset))
        offset += per_layer
    pages_per_layer = math.ceil(offset / page_elems)
    layer_elems = pages_per_layer * page_elems

    flat = jnp.concatenate(
        [leaf.reshape(L, -1).astype(dtype) for _, leaf in leaves], axis=1)
    pad = layer_elems - flat.shape[1]
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    pages = flat.reshape(L * pages_per_layer, page_elems)
    manifest = PageManifest(page_elems, layer_elems, pages_per_layer, L,
                            entries, str(dtype))
    return pages, manifest


def fetch_layer(pages: jax.Array, manifest: PageManifest, layer) -> Dict:
    """Gather one layer's pages and rebuild its parameter pytree.
    `layer` may be a traced index (used inside lax.scan/fori loops)."""
    start = layer * manifest.pages_per_layer
    span = jax.lax.dynamic_slice_in_dim(pages, start,
                                        manifest.pages_per_layer, axis=0)
    flat = span.reshape(-1)
    out: Dict = {}
    for e in manifest.leaves:
        n = int(np.prod(e.shape)) if e.shape else 1
        leaf = jax.lax.dynamic_slice_in_dim(flat, e.offset, n, axis=0)
        leaf = leaf.reshape(e.shape) if e.shape else leaf[0]
        node = out
        for p in e.path[:-1]:
            node = node.setdefault(p, {})
        node[e.path[-1]] = leaf
    return out


def fetch_pages(pages: jax.Array, page_ids) -> jax.Array:
    return pages[jnp.asarray(page_ids)]


# ---------------------------------------------------------------------------
# Split manifests: shared span + per-(layer, expert) spans
# ---------------------------------------------------------------------------

# Routed-expert leaves inside a "moe" subtree (shared experts stay in the
# shared span — they run for every token, so streaming them per layer is
# already optimal).  The int8 dequant scales (wi_scale/wo_scale) also stay
# in the shared span: they are 4 bytes per expert — page-padding them into
# expert spans would waste a page each, and the expert pool is packed at
# the expert-weight dtype, which would truncate float32 scales.  moe_paged
# gathers them per activated expert from the shared params instead.
EXPERT_LEAF_NAMES = ("wi", "wo")


def _is_expert_leaf(path: Tuple[str, ...]) -> bool:
    return ("moe" in path and "shared" not in path
            and path[-1] in EXPERT_LEAF_NAMES)


def _tree_from_leaves(leaves):
    out: Dict = {}
    for path, leaf in leaves:
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = leaf
    return out


@dataclass
class ExpertManifest:
    """Per-(layer, expert) page spans for one stacked layer group.  The
    span unit is ONE expert's weights in ONE layer — the granularity the
    residency cache pins/evicts and the router-gated gather fetches."""
    page_elems: int
    expert_elems: int            # padded flat elements per (layer, expert)
    pages_per_expert: int
    num_layers: int
    num_experts: int
    leaves: List[LeafEntry]      # paths relative to the moe subtree
    dtype: str

    def expert_pages(self, layer: int, expert: int) -> np.ndarray:
        """The (layer, expert) → page ids table (flat pool numbering)."""
        start = ((layer * self.num_experts + expert)
                 * self.pages_per_expert)
        return np.arange(start, start + self.pages_per_expert)

    @property
    def span_bytes(self) -> int:
        """H2D bytes one expert span moves (padded, what a transfer costs)."""
        return (self.pages_per_expert * self.page_elems
                * np.dtype(self.dtype).itemsize)


@dataclass
class SplitManifest:
    shared: PageManifest
    experts: Optional[ExpertManifest]


def pack_expert_stack(expert_leaves, page_elems: int = 1 << 20
                      ) -> Tuple[jax.Array, ExpertManifest]:
    """expert_leaves: [(path, arr (L, E, ...))].  Returns
    (pages (L, E, pages_per_expert, page_elems), manifest).  Leaf paths in
    the manifest are stored relative to the ``moe`` subtree so a gathered
    span unflattens straight into the MoE param dict."""
    L, NE = expert_leaves[0][1].shape[:2]
    dtype = expert_leaves[0][1].dtype
    entries: List[LeafEntry] = []
    offset = 0
    for path, leaf in expert_leaves:
        assert leaf.shape[:2] == (L, NE), f"expert stack mismatch at {path}"
        rel = path[path.index("moe") + 1:]
        per = int(np.prod(leaf.shape[2:])) if leaf.ndim > 2 else 1
        entries.append(LeafEntry(rel, tuple(leaf.shape[2:]), str(leaf.dtype),
                                 offset))
        offset += per
    pages_per_expert = math.ceil(offset / page_elems)
    expert_elems = pages_per_expert * page_elems

    flat = jnp.concatenate(
        [leaf.reshape(L, NE, -1).astype(dtype) for _, leaf in expert_leaves],
        axis=2)
    pad = expert_elems - flat.shape[2]
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, 0), (0, pad)))
    pages = flat.reshape(L, NE, pages_per_expert, page_elems)
    manifest = ExpertManifest(page_elems, expert_elems, pages_per_expert,
                              L, NE, entries, str(dtype))
    return pages, manifest


def unflatten_expert_span(span: jax.Array, em: ExpertManifest) -> Dict:
    """Rebuild expert params from page spans with arbitrary leading batch
    dims: span (..., pages_per_expert, page_elems) -> pytree whose leaves
    have shape (..., *leaf_shape) — the compacted (A, ...) expert subset
    the two-phase MoE step computes on."""
    lead = span.shape[:-2]
    flat = span.reshape(lead + (-1,))
    out: Dict = {}
    for e in em.leaves:
        n = int(np.prod(e.shape)) if e.shape else 1
        leaf = flat[..., e.offset:e.offset + n].reshape(lead + e.shape)
        node = out
        for p in e.path[:-1]:
            node = node.setdefault(p, {})
        node[e.path[-1]] = leaf
    return out


def pack_layer_stack_split(stacked: Dict, page_elems: int = 1 << 20
                           ) -> Tuple[jax.Array, Optional[jax.Array],
                                      SplitManifest]:
    """Split one stacked layer group into a shared span (everything that
    streams every layer: attention, norms, router, shared experts) and
    per-(layer, expert) spans for the routed expert weights.

    Returns (shared_pages (L*ppl, page_elems),
             expert_pages (L, E, pages_per_expert, page_elems) or None,
             SplitManifest)."""
    leaves = _flatten_with_paths(stacked)
    expert_leaves = [(p, l) for p, l in leaves if _is_expert_leaf(p)]
    shared_leaves = [(p, l) for p, l in leaves if not _is_expert_leaf(p)]
    shared_pages, shared_manifest = pack_layer_stack(
        _tree_from_leaves(shared_leaves), page_elems)
    if not expert_leaves:
        return shared_pages, None, SplitManifest(shared_manifest, None)
    expert_pages, em = pack_expert_stack(expert_leaves, page_elems)
    return shared_pages, expert_pages, SplitManifest(shared_manifest, em)


@dataclass
class PagedWeights:
    """Engine-facing bundle for split (expert-granular) paging: per-group
    shared spans shaped for the layer scan, plus the per-(layer, expert)
    page pools and manifests for every MoE group.  Groups without routed
    experts appear only in ``pages``/``manifests`` (identical to the
    whole-layer path)."""
    pages: Dict[str, jax.Array]              # key -> (L, ppl, page_elems)
    manifests: Dict[str, PageManifest]
    expert_pages: Dict[str, jax.Array]       # key -> (L, E, ppe, page_elems)
    expert_manifests: Dict[str, ExpertManifest]

    def shared_layer_bytes(self, key: str) -> int:
        m = self.manifests[key]
        return (m.pages_per_layer * m.page_elems
                * np.dtype(m.dtype).itemsize)


def pack_block_groups_split(blocks: Dict, page_elems: int = 1 << 20
                            ) -> PagedWeights:
    """Split-pack every period-position group of a model's stacked block
    params (the expert-granular analogue of ``pack_block_groups``).

    The packed pools are the engine's *host-side* weight store: they are
    placed in pinned host memory when the backend exposes the space
    (core.offload), so the transfer_plan/window_plan slices the serving
    scan consumes — and the router-gated expert-span gathers — lower to
    async pinned-DMA copies instead of pageable-rate transfers."""
    from repro.core import offload
    pages, manifests, epages, emanifests = {}, {}, {}, {}
    for key, group in blocks.items():
        shared, experts, sm = pack_layer_stack_split(group, page_elems)
        L = sm.shared.num_layers
        pages[key] = offload.pinned_put(
            shared.reshape(L, sm.shared.pages_per_layer,
                           sm.shared.page_elems))
        manifests[key] = sm.shared
        if experts is not None:
            epages[key] = offload.pinned_put(experts)
            emanifests[key] = sm.experts
    return PagedWeights(pages, manifests, epages, emanifests)


def unflatten_span(span: jax.Array, manifest: PageManifest) -> Dict:
    """Rebuild one layer's parameter pytree from its page span
    (pages_per_layer, page_elems) — static offsets, reshape-only (used
    inside lax.scan where the span arrives as a scan slice)."""
    flat = span.reshape(-1)
    out: Dict = {}
    for e in manifest.leaves:
        n = int(np.prod(e.shape)) if e.shape else 1
        leaf = flat[e.offset:e.offset + n]
        leaf = leaf.reshape(e.shape) if e.shape else leaf[0]
        node = out
        for p in e.path[:-1]:
            node = node.setdefault(p, {})
        node[e.path[-1]] = leaf
    return out


def pack_block_groups(blocks: Dict, page_elems: int = 1 << 20):
    """Pack every period-position group ('p0', 'p1', ...) of a model's
    stacked block params into page pools.  Returns (pages_dict, manifests):
    pages_dict[key] has shape (L, pages_per_layer, page_elems) — sliceable
    by the layer scan — and manifests[key] rebuilds the layer pytree."""
    from repro.core import offload
    pages_dict, manifests = {}, {}
    for key, group in blocks.items():
        pages, manifest = pack_layer_stack(group, page_elems)
        L = manifest.num_layers
        # host-side page store: pinned placement when available, so the
        # in-scan page consumption streams at pinned-DMA rate
        pages_dict[key] = offload.pinned_put(
            pages.reshape(L, manifest.pages_per_layer, manifest.page_elems))
        manifests[key] = manifest
    return pages_dict, manifests


# ---------------------------------------------------------------------------
# Transfer scheduling (which page moves during which micro-batch)
# ---------------------------------------------------------------------------

def transfer_plan(pages_per_layer: int, n_ubs: int) -> List[List[int]]:
    """Split a layer's pages into n_ubs groups; group j is transferred
    while micro-batch j computes (CGOPipe interleaving: the small, urgent
    hidden-state transfer for ub j+1 slots between groups)."""
    groups: List[List[int]] = [[] for _ in range(n_ubs)]
    for p in range(pages_per_layer):
        groups[p * n_ubs // pages_per_layer].append(p)
    return groups


def window_plan(n_items: int, n_ubs: int,
                positions: Sequence[int]) -> List[int]:
    """Module-batched drain schedule: the union of the transfer_plan
    groups for every rotation position in one accumulation window —
    prefetch admitted during a window may drain through all of the
    window's interleave slots, not just one group's.  `positions` are
    rotation indices (taken mod n_ubs); returns sorted item ids."""
    plan = transfer_plan(n_items, n_ubs)
    return sorted({i for p in positions for i in plan[p % n_ubs]})


def predicted_drain_order(pairs: Sequence[Tuple[int, int]],
                          scores: Sequence[float]) -> List[int]:
    """Earliest-deadline-first enqueue order for gate-predicted expert
    spans: a span predicted for layer l is only useful if it lands
    before the scan's layer-l step consumes it, so shallow layers
    enqueue first (ties broken toward higher predicted probability).
    The engine feeds the ordered entries into the same pending queue the
    router-ahead prefetch drains through ``transfer_plan`` slices — the
    slices interleave the H2D work between the rotation's compute steps,
    and deadline order maximizes the spans that complete before their
    consuming layer.  Returns indices into ``pairs``."""
    return sorted(range(len(pairs)),
                  key=lambda i: (pairs[i][0], -scores[i], pairs[i][1]))


@dataclass
class DoubleBuffer:
    """The 2×W_L weight buffer of Appendix A.1 (logical model; the JAX
    engine realizes it as two donated page buffers)."""
    n_slots: int = 2
    resident: List[int] = field(default_factory=lambda: [-1, -1])

    def slot_for(self, layer: int) -> int:
        return layer % self.n_slots

    def load(self, layer: int) -> int:
        s = self.slot_for(layer)
        self.resident[s] = layer
        return s

    def is_resident(self, layer: int) -> bool:
        return self.resident[self.slot_for(layer)] == layer

"""Paged weights (paper Appendix A.1, Fig. 11).

Layer weights are chunked into fixed-size *pages*; a page table maps
(layer, leaf) → page span.  The serving engine keeps a 2×W_L double
buffer: while layer i computes out of buffer (i % 2), the pages of layer
i+1 stream into buffer ((i+1) % 2), interleaved with hidden-state
transfers per CGOPipe.  On TPU the backing store lives in host memory
(``memory_kind='pinned_host'``) and pages move with device_put; on the
CPU-only validation platform the same code paths run with plain arrays.

The page pool layout is (num_pages, page_elems) so a layer fetch is a
single contiguous gather — the TPU analogue of the paper's paged
cudaMemcpyAsync batches, and the unit the Pallas MoE-FFN kernel's page
table indexes into.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LeafEntry:
    path: Tuple[str, ...]
    shape: Tuple[int, ...]       # per-layer shape (stack dim removed)
    dtype: str
    offset: int                  # element offset within the layer's flat span


@dataclass
class PageManifest:
    page_elems: int
    layer_elems: int             # padded flat elements per layer
    pages_per_layer: int
    num_layers: int
    leaves: List[LeafEntry]
    dtype: str

    def layer_pages(self, layer: int) -> np.ndarray:
        start = layer * self.pages_per_layer
        return np.arange(start, start + self.pages_per_layer)


def _flatten_with_paths(tree, prefix=()):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _flatten_with_paths(tree[k], prefix + (k,))
        return out
    return [(prefix, tree)]


def pack_layer_stack(stacked: Dict, page_elems: int = 1 << 20
                     ) -> Tuple[jax.Array, PageManifest]:
    """stacked: pytree whose every leaf has a leading `layers` dim L.
    Returns (pages (P, page_elems), manifest)."""
    leaves = _flatten_with_paths(stacked)
    L = leaves[0][1].shape[0]
    dtype = leaves[0][1].dtype
    entries: List[LeafEntry] = []
    offset = 0
    for path, leaf in leaves:
        assert leaf.shape[0] == L, f"stack dim mismatch at {path}"
        per_layer = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
        entries.append(LeafEntry(path, tuple(leaf.shape[1:]), str(leaf.dtype),
                                 offset))
        offset += per_layer
    pages_per_layer = math.ceil(offset / page_elems)
    layer_elems = pages_per_layer * page_elems

    flat = jnp.concatenate(
        [leaf.reshape(L, -1).astype(dtype) for _, leaf in leaves], axis=1)
    pad = layer_elems - flat.shape[1]
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    pages = flat.reshape(L * pages_per_layer, page_elems)
    manifest = PageManifest(page_elems, layer_elems, pages_per_layer, L,
                            entries, str(dtype))
    return pages, manifest


def fetch_layer(pages: jax.Array, manifest: PageManifest, layer) -> Dict:
    """Gather one layer's pages and rebuild its parameter pytree.
    `layer` may be a traced index (used inside lax.scan/fori loops)."""
    start = layer * manifest.pages_per_layer
    span = jax.lax.dynamic_slice_in_dim(pages, start,
                                        manifest.pages_per_layer, axis=0)
    flat = span.reshape(-1)
    out: Dict = {}
    for e in manifest.leaves:
        n = int(np.prod(e.shape)) if e.shape else 1
        leaf = jax.lax.dynamic_slice_in_dim(flat, e.offset, n, axis=0)
        leaf = leaf.reshape(e.shape) if e.shape else leaf[0]
        node = out
        for p in e.path[:-1]:
            node = node.setdefault(p, {})
        node[e.path[-1]] = leaf
    return out


def fetch_pages(pages: jax.Array, page_ids) -> jax.Array:
    return pages[jnp.asarray(page_ids)]


def unflatten_span(span: jax.Array, manifest: PageManifest) -> Dict:
    """Rebuild one layer's parameter pytree from its page span
    (pages_per_layer, page_elems) — static offsets, reshape-only (used
    inside lax.scan where the span arrives as a scan slice)."""
    flat = span.reshape(-1)
    out: Dict = {}
    for e in manifest.leaves:
        n = int(np.prod(e.shape)) if e.shape else 1
        leaf = flat[e.offset:e.offset + n]
        leaf = leaf.reshape(e.shape) if e.shape else leaf[0]
        node = out
        for p in e.path[:-1]:
            node = node.setdefault(p, {})
        node[e.path[-1]] = leaf
    return out


def pack_block_groups(blocks: Dict, page_elems: int = 1 << 20):
    """Pack every period-position group ('p0', 'p1', ...) of a model's
    stacked block params into page pools.  Returns (pages_dict, manifests):
    pages_dict[key] has shape (L, pages_per_layer, page_elems) — sliceable
    by the layer scan — and manifests[key] rebuilds the layer pytree."""
    pages_dict, manifests = {}, {}
    for key, group in blocks.items():
        pages, manifest = pack_layer_stack(group, page_elems)
        L = manifest.num_layers
        pages_dict[key] = pages.reshape(L, manifest.pages_per_layer,
                                        manifest.page_elems)
        manifests[key] = manifest
    return pages_dict, manifests


# ---------------------------------------------------------------------------
# Transfer scheduling (which page moves during which micro-batch)
# ---------------------------------------------------------------------------

def transfer_plan(pages_per_layer: int, n_ubs: int) -> List[List[int]]:
    """Split a layer's pages into n_ubs groups; group j is transferred
    while micro-batch j computes (CGOPipe interleaving: the small, urgent
    hidden-state transfer for ub j+1 slots between groups)."""
    groups: List[List[int]] = [[] for _ in range(n_ubs)]
    for p in range(pages_per_layer):
        groups[p * n_ubs // pages_per_layer].append(p)
    return groups


@dataclass
class DoubleBuffer:
    """The 2×W_L weight buffer of Appendix A.1 (logical model; the JAX
    engine realizes it as two donated page buffers)."""
    n_slots: int = 2
    resident: List[int] = field(default_factory=lambda: [-1, -1])

    def slot_for(self, layer: int) -> int:
        return layer % self.n_slots

    def load(self, layer: int) -> int:
        s = self.slot_for(layer)
        self.resident[s] = layer
        return s

    def is_resident(self, layer: int) -> bool:
        return self.resident[self.slot_for(layer)] == layer

"""Request batching (paper Algorithm 2, Appendix A.2).

Balanced token distribution: requests sorted by input length descending,
each placed into the micro-batch with the fewest tokens, subject to a KV
cache budget; full micro-batches are sealed.  Returns the sealed
micro-batches plus the requests deferred to the next round.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple


class GenLenEWMA:
    """Running EWMA of observed generation lengths.

    Feeds the scheduler's EOS-aware reservations: instead of reserving
    each live request's worst-case remaining quota, reserve the *expected*
    remaining length — requests that hit EOS early stop inflating the
    KV budget for everyone behind them.  Until the first observation the
    estimate is None and callers must fall back to the worst case."""

    def __init__(self, alpha: float = 0.25):
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self.value: Optional[float] = None
        self.count = 0

    def observe(self, gen_len: int) -> None:
        self.count += 1
        if self.value is None:
            self.value = float(gen_len)
        else:
            self.value += self.alpha * (gen_len - self.value)

    def expected(self, max_new_tokens: int) -> int:
        """Expected total generation length for a request with the given
        quota (never optimistic below 1, never beyond the quota)."""
        if self.value is None:
            return max_new_tokens
        return max(1, min(max_new_tokens, math.ceil(self.value)))


def blocks_for_tokens(tokens: int, block_tokens: int) -> int:
    """Fixed-size KV blocks covering `tokens` ring positions (ceil; 0 for
    an empty footprint).  The unit of the block-granular paged KV cache's
    admission accounting: a request occupies whole blocks of the shared
    arena, so budget charges round up to the block boundary."""
    if tokens <= 0:
        return 0
    return -(-tokens // block_tokens)


def round_to_blocks(tokens: int, block_tokens: Optional[int]) -> int:
    """Token charge of a footprint under block-granular accounting
    (identity when block_tokens is None — the dense max_seq-wide pool)."""
    if not block_tokens:
        return tokens
    return blocks_for_tokens(tokens, block_tokens) * block_tokens


@dataclass(frozen=True)
class Request:
    rid: int
    input_len: int
    gen_len: int = 0


@dataclass
class MicroBatch:
    requests: List[Request] = field(default_factory=list)

    @property
    def tokens(self) -> int:
        return sum(r.input_len for r in self.requests)

    def __len__(self):
        return len(self.requests)


def batch_requests(req_queue: List[Request], n_ub: int, ubs: int,
                   gen_len: int, cache_size: int
                   ) -> Tuple[List[MicroBatch], List[Request]]:
    """Algorithm 2 verbatim.

    req_queue: queue of requests; n_ub: number of micro-batches;
    ubs: max requests per micro-batch; gen_len: generation length;
    cache_size: max cache tokens per micro-batch.
    Returns (micro_batches, aborted_requests)."""
    partitions: List[MicroBatch] = [MicroBatch() for _ in range(n_ub)]
    partition_sums: List[int] = [0] * n_ub
    micro_batches: List[MicroBatch] = []
    aborted: List[Request] = []

    for req in sorted(req_queue, key=lambda r: r.input_len, reverse=True):
        idx = place_request(req.input_len, partition_sums,
                            [len(p) for p in partitions],
                            gen_len=gen_len, cache_size=cache_size)
        if idx is None:
            aborted.append(req)
            continue
        partitions[idx].requests.append(req)
        partition_sums[idx] += req.input_len
        if len(partitions[idx]) == ubs:
            micro_batches.append(partitions.pop(idx))
            partition_sums.pop(idx)
    # remaining (non-empty, unsealed) partitions are emitted too — they are
    # simply smaller; the engine pads them to the policy's μ
    for p in partitions:
        if len(p):
            micro_batches.append(p)
    return micro_batches, aborted


def place_request(input_len: int, partition_sums: Sequence[int],
                  partition_counts: Sequence[int], *, gen_len: int,
                  cache_size: int,
                  open_mask: Optional[Sequence[bool]] = None,
                  reserve: Optional[int] = None) -> Optional[int]:
    """Incremental single-request placement: Algorithm 2's balance criterion
    applied to ONE request against live partitions (continuous batching).

    partition_sums/partition_counts: current token load and live request
    count per partition; each co-resident reserves `gen_len` generation
    tokens (pass gen_len=0 when partition_sums already include their
    reservations) and the candidate reserves `reserve` (default gen_len —
    the batch-mode uniform bound).  open_mask: which partitions can still
    take a request (e.g. have a free slot).  Returns the index of the
    least-loaded open partition if the projected cache use fits the
    budget, else None (caller defers or aborts the request)."""
    cands = [i for i in range(len(partition_sums))
             if open_mask is None or open_mask[i]]
    if not cands:
        return None
    idx = min(cands, key=lambda i: partition_sums[i])
    projected = (partition_sums[idx] + input_len
                 + (gen_len if reserve is None else reserve)
                 + partition_counts[idx] * gen_len)
    if projected > cache_size:
        return None
    return idx

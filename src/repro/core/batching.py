"""Request batching (paper Algorithm 2, Appendix A.2).

Balanced token distribution: requests sorted by input length descending,
each placed into the micro-batch with the fewest tokens, subject to a KV
cache budget; full micro-batches are sealed.  Returns the sealed
micro-batches plus the requests deferred to the next round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class Request:
    rid: int
    input_len: int
    gen_len: int = 0


@dataclass
class MicroBatch:
    requests: List[Request] = field(default_factory=list)

    @property
    def tokens(self) -> int:
        return sum(r.input_len for r in self.requests)

    def __len__(self):
        return len(self.requests)


def batch_requests(req_queue: List[Request], n_ub: int, ubs: int,
                   gen_len: int, cache_size: int
                   ) -> Tuple[List[MicroBatch], List[Request]]:
    """Algorithm 2 verbatim.

    req_queue: queue of requests; n_ub: number of micro-batches;
    ubs: max requests per micro-batch; gen_len: generation length;
    cache_size: max cache tokens per micro-batch.
    Returns (micro_batches, aborted_requests)."""
    partitions: List[MicroBatch] = [MicroBatch() for _ in range(n_ub)]
    partition_sums: List[int] = [0] * n_ub
    micro_batches: List[MicroBatch] = []
    aborted: List[Request] = []

    for req in sorted(req_queue, key=lambda r: r.input_len, reverse=True):
        if not partitions:
            aborted.append(req)
            continue
        idx = min(range(len(partitions)), key=lambda i: partition_sums[i])
        projected = (partition_sums[idx] + req.input_len
                     + (1 + len(partitions[idx])) * gen_len)
        if projected > cache_size:
            aborted.append(req)
            continue
        partitions[idx].requests.append(req)
        partition_sums[idx] += req.input_len
        if len(partitions[idx]) == ubs:
            micro_batches.append(partitions.pop(idx))
            partition_sums.pop(idx)
    # remaining (non-empty, unsealed) partitions are emitted too — they are
    # simply smaller; the engine pads them to the policy's μ
    for p in partitions:
        if len(p):
            micro_batches.append(p)
    return micro_batches, aborted

"""Block-granular KV page-table control plane (host side).

The paper's policy tuple places a fraction ``r_c`` of the KV cache on
GPU (Table 1) and keeps the remainder CPU-resident, but the serving
stack used to allocate one dense ``max_seq``-wide KV ring per slot,
entirely on device — ``r_c`` existed only inside ``core.policy``'s
arithmetic.  This module is the KV analogue of ``core.residency``: the
control plane for a **shared arena** of fixed-size token blocks
(``block_tokens`` ring slots each) plus a
``(slot, logical_block) → physical_block`` page table, so a request's
device KV footprint is proportional to its actual length instead of
``max_seq``, and cold blocks can be demoted to a host-RAM block store
sized by the rest of the budget.

Split of responsibilities (mirrors ``core.residency``):

  * data plane — functional JAX (``models.kvcache``): the arena arrays
    and the device page table are *arguments* to the jitted serving
    steps; attention gathers a dense ring view of each slot's mapped
    blocks under the existing ``slot_pos`` masking, so greedy
    transcripts are bit-identical in every tier regime;
  * control plane — this module, host-side numpy: which physical block
    holds which (slot, logical_block), which blocks live in the host
    tier, victim selection, hit/miss/spill counters.  Methods *plan*
    data movement (ordered op lists) and the engine executes the copies,
    so the map can never disagree with what actually moved.

Placement states per (slot, logical_block):

  * **unmapped** — no KV written there yet (device and host entry -1);
  * **device**   — resident in the physical arena (device entry = id);
  * **host**     — spilled to the host-RAM block store; streams back
    through ``paging.transfer_plan`` rotation slices (prefetch) or on
    demand at dispatch preparation (a **miss**, H2D ``block_bytes``).

Accounting model (consistent with DESIGN.md §2 — on the CPU validation
container traffic is accounted, not physically transferred):

  * every block a decode chunk's attention will read is a **fetch
    event** at dispatch preparation: device-resident → **hit** (0
    bytes), host-resident → **miss** (streams back inline, H2D);
  * a **prefetch** promotes a host block ahead of its group's turn
    (free arena blocks only) and pays H2D up front; the later touch is
    then a hit;
  * a **spill** demotes a victim block to the host tier (D2H) to make
    room; protected slots (the group being dispatched / the staged
    prefill target) are never victims — the paged-attention analogue of
    residency's pinned spans.

Invariants (enforced by tests/test_kv_paging.py):

  * free-list conservation: every device/host block id is either free or
    owned by exactly one (slot, logical_block), exactly once;
  * no double mapping: a logical block is device- xor host-resident;
  * a slot's mapped logical blocks form a contiguous prefix (KV is
    append-only: prompt blocks, then decode growth);
  * ``counters.fetches == hits + misses`` counts every planned block
    read exactly once.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batching import blocks_for_tokens

# Ordered data-movement instructions for the engine to execute:
#   ("spill", slot, lb, pb, hb)  copy arena block pb -> host block hb
#   ("fetch", slot, lb, hb, pb)  copy host block hb -> arena block pb
#   ("alloc", slot, lb, pb)      fresh block: clear arena slot_pos[pb]
Op = Tuple


@dataclass
class BlockCounters:
    hits: int = 0            # touched & device-resident (0 bytes)
    misses: int = 0          # touched & streamed back inline (block_bytes)
    prefetches: int = 0      # promoted ahead of use (block_bytes)
    spills: int = 0          # demoted to the host tier (block_bytes D2H)
    allocs: int = 0          # fresh blocks mapped
    frees: int = 0           # blocks released (slot drained / preempted)
    h2d_bytes: int = 0
    d2h_bytes: int = 0

    @property
    def fetches(self) -> int:
        """Total planned block-read events (hits + misses)."""
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.fetches if self.fetches else 0.0


class BlockPool:
    """Page-table manager for one shared KV block arena.

    ``n_slots`` rows (the engine's ``num_ubs × ubatch`` slot pool, plus
    static-mode micro-batches mapped onto the same indices) of
    ``blocks_per_slot`` logical blocks each, backed by ``device_blocks``
    physical arena blocks and an (always sufficient) host block store.
    ``block_bytes`` is what one block transfer moves across every paged
    layer group — the unit of the H2D/D2H counters.
    """

    def __init__(self, n_slots: int, blocks_per_slot: int,
                 device_blocks: int, block_bytes: int, faults=None):
        assert device_blocks >= 1 and blocks_per_slot >= 1
        self.n_slots = n_slots
        self.blocks_per_slot = blocks_per_slot
        self.device_blocks = device_blocks
        self.block_bytes = block_bytes
        # optional runtime.faults.FaultInjector: the "kv_pool" site models
        # arena exhaustion — ensure_range refuses at entry as if no block
        # could be acquired, and flags the refusal so the engine can retry
        # (injected exhaustion is transient) instead of preempting
        self.faults = faults
        self.last_refusal_injected = False
        host_blocks = n_slots * blocks_per_slot   # worst case: all spilled
        self.dev = np.full((n_slots, blocks_per_slot), -1, np.int32)
        self.host = np.full((n_slots, blocks_per_slot), -1, np.int32)
        self.free_dev: List[int] = list(range(device_blocks))
        self.free_host: List[int] = list(range(host_blocks))
        self.dev_owner = np.full((device_blocks,), -1, np.int64)
        self.host_owner = np.full((host_blocks,), -1, np.int64)
        self.last_touch = np.zeros((n_slots,), np.int64)
        self._tick = 0
        self.peak_in_use = 0
        self.counters = BlockCounters()

    # ------------------------------------------------------------- ids
    def _pid(self, slot: int, lb: int) -> int:
        return int(slot) * self.blocks_per_slot + int(lb)

    def _pair(self, pid: int) -> Tuple[int, int]:
        return divmod(int(pid), self.blocks_per_slot)

    # ---------------------------------------------------------- queries
    def n_mapped(self, slot: int) -> int:
        """Length of the slot's mapped logical-block prefix."""
        mapped = (self.dev[slot] >= 0) | (self.host[slot] >= 0)
        return int(mapped.sum())

    def slot_in_use(self, slot: int) -> bool:
        return self.n_mapped(slot) > 0

    def in_use_device(self) -> int:
        return self.device_blocks - len(self.free_dev)

    def device_table(self, rows: Sequence[int]) -> np.ndarray:
        """The (B, blocks_per_slot) device page table the jitted step
        reads: physical block id, or -1 (unmapped OR host-resident —
        either way the gather masks that span)."""
        return self.dev[np.asarray(rows, np.int64)].astype(np.int32)

    def host_resident_blocks(self, slot: int) -> List[int]:
        return np.flatnonzero(self.host[slot] >= 0).tolist()

    # -------------------------------------------------- device acquire
    def _spill_one(self, protect: frozenset) -> Optional[Op]:
        """Demote one victim block: slots outside ``protect``, least
        recently touched first; within a slot, oldest (lowest logical)
        block first.  Window-layer rings never enter the arena, so they
        are exempt by construction."""
        cands = [s for s in range(self.n_slots)
                 if s not in protect and (self.dev[s] >= 0).any()]
        if not cands:
            return None
        s = min(cands, key=lambda x: (self.last_touch[x], x))
        lb = int(np.flatnonzero(self.dev[s] >= 0)[0])     # oldest first
        pb = int(self.dev[s, lb])
        if not self.free_host:
            return None                                    # store exhausted
        hb = self.free_host.pop()
        self.dev[s, lb] = -1
        self.dev_owner[pb] = -1
        self.free_dev.append(pb)
        self.host[s, lb] = hb
        self.host_owner[hb] = self._pid(s, lb)
        self.counters.spills += 1
        self.counters.d2h_bytes += self.block_bytes
        return ("spill", s, lb, pb, hb)

    def _acquire_device(self, protect: frozenset,
                        ops: List[Op]) -> Optional[int]:
        """A free physical block, spilling unprotected victims if needed
        (spill ops are appended so the engine copies the victim out
        before its block is reused)."""
        while not self.free_dev:
            op = self._spill_one(protect)
            if op is None:
                return None
            ops.append(op)
        pb = self.free_dev.pop()
        self.peak_in_use = max(self.peak_in_use, self.in_use_device())
        return pb

    # --------------------------------------------------------- ensure
    def ensure_range(self, slot: int, lb_lo: int, lb_hi: int,
                     protect: Iterable[int] = ()
                     ) -> Tuple[List[Op], bool, int]:
        """Make logical blocks [lb_lo, lb_hi) of ``slot`` mapped and
        device-resident: resident blocks book a hit, host blocks a miss
        (+ fetch op), unmapped blocks a fresh alloc.  Returns (ops, ok,
        next_lb): the ordered data-movement ops, False when the arena
        cannot hold the demand even after spilling every unprotected
        block, and the first logical block NOT yet satisfied — the ops
        planned so far are still valid and must be executed; the caller
        preempts a request and *resumes* from next_lb, so each needed
        block is booked exactly once per preparation regardless of
        retries."""
        self.last_refusal_injected = False
        if self.faults is not None:
            ev = self.faults.fire("kv_pool")
            if ev is not None and ev.kind in ("exhaust", "fail"):
                self.last_refusal_injected = True
                return [], False, lb_lo
        protect = frozenset(protect) | {slot}
        self._tick += 1
        self.last_touch[slot] = self._tick
        ops: List[Op] = []
        lb_hi = min(lb_hi, self.blocks_per_slot)
        for lb in range(lb_lo, lb_hi):
            if self.dev[slot, lb] >= 0:
                self.counters.hits += 1
                continue
            if self.host[slot, lb] >= 0:
                pb = self._acquire_device(protect, ops)
                if pb is None:
                    return ops, False, lb
                hb = int(self.host[slot, lb])
                self.host[slot, lb] = -1
                self.host_owner[hb] = -1
                self.free_host.append(hb)
                self.dev[slot, lb] = pb
                self.dev_owner[pb] = self._pid(slot, lb)
                self.counters.misses += 1
                self.counters.h2d_bytes += self.block_bytes
                ops.append(("fetch", slot, lb, hb, pb))
                continue
            # fresh mapping: KV is append-only, so the prefix must hold
            assert lb == 0 or self.dev[slot, lb - 1] >= 0 \
                or self.host[slot, lb - 1] >= 0, \
                f"non-contiguous block map at slot {slot} lb {lb}"
            pb = self._acquire_device(protect, ops)
            if pb is None:
                return ops, False, lb
            self.dev[slot, lb] = pb
            self.dev_owner[pb] = self._pid(slot, lb)
            self.counters.allocs += 1
            ops.append(("alloc", slot, lb, pb))
        return ops, True, lb_hi

    def blocks_needed(self, n_tokens: int, block_tokens: int) -> int:
        return blocks_for_tokens(min(n_tokens,
                                     self.blocks_per_slot * block_tokens),
                                 block_tokens)

    def ensure_tokens(self, slot: int, n_tokens: int, block_tokens: int,
                      protect: Iterable[int] = ()
                      ) -> Tuple[List[Op], bool, int]:
        """Blocks covering ring positions [0, n_tokens) — what a decode
        chunk's attention reads plus the positions it will write."""
        return self.ensure_range(
            slot, 0, self.blocks_needed(n_tokens, block_tokens), protect)

    # -------------------------------------------------------- prefetch
    def prefetch(self, slot: int, lb: int) -> Optional[Op]:
        """Promote a host-resident block ahead of its group's turn, free
        arena blocks only (demotion to make room is the demand path's
        call, mirroring residency's miss-fills-free-slots rule)."""
        if self.host[slot, lb] < 0 or not self.free_dev:
            return None
        pb = self.free_dev.pop()
        self.peak_in_use = max(self.peak_in_use, self.in_use_device())
        hb = int(self.host[slot, lb])
        self.host[slot, lb] = -1
        self.host_owner[hb] = -1
        self.free_host.append(hb)
        self.dev[slot, lb] = pb
        self.dev_owner[pb] = self._pid(slot, lb)
        self.counters.prefetches += 1
        self.counters.h2d_bytes += self.block_bytes
        return ("fetch", slot, lb, hb, pb)

    # ------------------------------------------------------------ free
    def free_slot(self, slot: int) -> List[int]:
        """Release every block of a drained/preempted slot.  Returns the
        freed physical ids (their slot_pos planes are cleared lazily, at
        the next allocation)."""
        freed: List[int] = []
        for lb in range(self.blocks_per_slot):
            pb = int(self.dev[slot, lb])
            if pb >= 0:
                self.dev[slot, lb] = -1
                self.dev_owner[pb] = -1
                self.free_dev.append(pb)
                freed.append(pb)
                self.counters.frees += 1
            hb = int(self.host[slot, lb])
            if hb >= 0:
                self.host[slot, lb] = -1
                self.host_owner[hb] = -1
                self.free_host.append(hb)
                self.counters.frees += 1
        return freed

    # ------------------------------------------------------ invariants
    def check_invariants(self) -> None:
        """Free-list conservation + ownership bijection + device/host
        exclusivity + prefix-contiguity (test hook)."""
        dev_owned = np.flatnonzero(self.dev_owner >= 0).tolist()
        assert sorted(self.free_dev + dev_owned) == \
            list(range(self.device_blocks))
        host_owned = np.flatnonzero(self.host_owner >= 0).tolist()
        assert sorted(self.free_host + host_owned) == \
            list(range(len(self.host_owner)))
        for pb in dev_owned:
            s, lb = self._pair(int(self.dev_owner[pb]))
            assert self.dev[s, lb] == pb
        for hb in host_owned:
            s, lb = self._pair(int(self.host_owner[hb]))
            assert self.host[s, lb] == hb
        both = (self.dev >= 0) & (self.host >= 0)
        assert not both.any(), "block device- AND host-resident"
        mapped = (self.dev >= 0) | (self.host >= 0)
        for s in range(self.n_slots):
            n = int(mapped[s].sum())
            assert mapped[s, :n].all(), f"non-prefix map at slot {s}"
        assert len(set(self.dev[self.dev >= 0].tolist())) == \
            int((self.dev >= 0).sum()), "double-mapped physical block"

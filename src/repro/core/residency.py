"""Device-resident expert cache (control plane for expert-granular paging).

The paper's policy tuple sizes a weight budget with ``r_w`` but the seed
paging layer streamed every layer's full page span regardless — all E
experts — even though top-k routing touches a fraction of them.  This
module turns ``r_w`` into an actual placement decision: a fixed device
page pool holds ``capacity`` expert spans (``slots_from_ratio`` converts
the policy ratio into a span count), an activation-popularity EWMA (the
``core.batching.GenLenEWMA`` pattern lifted to a (layer, expert) table)
decides which spans deserve the slots, and hit/miss + H2D-byte counters
make the traffic observable (``benchmarks/bench_paging.py`` reports them).

Split of responsibilities:

  * data plane — functional JAX: the pool array and the
    ``(layer, expert) → slot`` resident map are *arguments* to the jitted
    serving steps; the in-scan gather reads resident spans from the pool
    and streams misses from the host store (models.moe.moe_paged);
  * control plane — this module, host-side numpy: which span occupies
    which slot, popularity, pins, counters.  The engine snapshots
    ``slot_of`` into the step call, so evicting *after* a chunk is
    dispatched can never corrupt it (the chunk holds its snapshot);
    pins additionally protect the spans an in-flight chunk may read so
    the router-ahead prefetch for the *next* group cannot recycle them.

Accounting model (consistent with DESIGN.md §2 — on the CPU validation
container traffic is accounted, not physically transferred):

  * an activated expert whose span is resident is a **hit** (0 bytes);
  * an activated non-resident expert is a **miss** and streams its span
    inline (``span_bytes`` H2D).  Demand-admitting it into the pool in
    the same step reuses that stream (no second charge);
  * a **prefetch** admits a span before use and pays ``span_bytes`` up
    front; its later activation is then a hit.  Prefetch admissions
    carry a *cause* — ``router`` (group-j+1 router-ahead), ``predicted``
    (the cross-layer GatePredictor) or ``replica`` (hot-expert
    replication fill) — and hits are attributed back to the cause that
    staged the span, so the counters split demand / router / predicted /
    replicated hits and ``prefetch_accuracy`` (predicted-and-used /
    predicted) is measurable;
  * a miss whose span *landed during the dispatch it was consumed by*
    (the engine passes ``hidden_mask``) still pays its bytes but books
    as a **hidden miss**: its H2D stream overlapped the chunk's compute,
    so it contributes no stall — ``miss_stall_bytes`` accumulates the
    per-layer bytes of the *unhidden* misses only, which is exactly the
    per-layer miss-stall estimate the roofline report converts to time.

Replication: ``replicate_frac`` reserves a budget of the pool for
persistently-pinned replicas of the popularity-EWMA top spans.  Replicas
enter when they rank inside the budget (popularity ≥ the rank-budget
entry, θ_hi) and exit only when they decay below ``replica_exit · θ_hi``
(hysteresis), so they survive window turnover instead of churning with
it.  A replica is never an eviction victim and survives ``unpin_all``.

Prediction: ``GatePredictor`` — per-layer-transition logistic heads fit
online (plain numpy SGD, host control plane, no jit retrace) on the
(chunk, L, E) activation counts the decode scan already emits, mapping
layer-i routed-token distributions to layer-i+1 activation
probabilities; chained once more for the i+2 lookahead.  Predicted
admissions are protected from demand-quota eviction for ``protect_ttl``
accounting rounds (or until first use), realizing "pinned in-flight so
demand misses never evict them".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Pair = Tuple[int, int]                       # (layer, expert)


def slots_from_ratio(w_gpu_ratio: float, num_layers: int,
                     num_experts: int) -> int:
    """Pool capacity (in expert spans) implied by the policy's ``r_w``:
    the fraction of all (layer, expert) spans that fits device-resident."""
    total = num_layers * num_experts
    return int(np.clip(round(w_gpu_ratio * total), 0, total))


@dataclass
class ResidencyCounters:
    hits: int = 0            # activated & resident (0 bytes)
    misses: int = 0          # activated & streamed inline (span_bytes)
    prefetches: int = 0      # admitted ahead of use (span_bytes)
    demand_admits: int = 0   # miss stream landed in a pool slot (no charge)
    evictions: int = 0
    refusals: int = 0        # admission declined (pinned/hotter cache)
    h2d_bytes: int = 0       # expert-span H2D traffic booked
    # what G separate per-group bookings would have charged: observe()
    # adds its own misses (lockstep IS per-group), observe_window() adds
    # the per-group miss count before the union dedup — the ratio
    # lockstep_misses / misses is the measured module-batching
    # amortization factor (weight_traffic()["module_groups_effective"])
    lockstep_misses: int = 0
    # hit attribution by the cause that staged the span (sums to hits):
    # demand-admitted / router-ahead prefetched / gate-predictor
    # prefetched / replicated.  A replica hit wins over the span's
    # original admission cause — the replication pin is what kept it
    # resident through window turnover.
    demand_hits: int = 0
    router_hits: int = 0
    predicted_hits: int = 0
    replicated_hits: int = 0
    # prefetch sub-causes (both also count in ``prefetches`` so the
    # h2d_bytes == span_bytes * (misses + prefetches) invariant holds)
    predicted_prefetches: int = 0   # gate-predictor admissions
    replications: int = 0          # replica fills copied into the pool
    predicted_used: int = 0        # predicted spans hit at least once
    # misses whose span landed during the very dispatch that consumed
    # them: bytes are charged but the H2D stream overlapped the chunk's
    # compute, so they contribute no stall (per-layer stall bytes live
    # on ExpertResidency.miss_stall_bytes)
    hidden_misses: int = 0

    @property
    def fetches(self) -> int:
        """Total activated-expert fetch events (hits + misses)."""
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.fetches if self.fetches else 0.0

    @property
    def stall_misses(self) -> int:
        """Misses whose stream could NOT hide behind the consuming
        dispatch's compute (the stall component of the expert phase)."""
        return self.misses - self.hidden_misses

    @property
    def prefetch_accuracy(self) -> float:
        """predicted-and-used / predicted — the gate predictor's realized
        precision (a wasted predicted span paid bytes for no hit)."""
        if self.predicted_prefetches == 0:
            return 0.0
        return self.predicted_used / self.predicted_prefetches


class ExpertResidency:
    """Fixed-capacity residency manager for one stacked layer group.

    Invariants (enforced by tests/test_residency.py):
      * occupancy ≤ capacity, and ``slot_of``/``owner`` stay a bijection
        between resident pairs and occupied slots;
      * a pinned span (in use by an in-flight chunk) is never evicted;
      * ``counters.fetches == hits + misses`` counts every activated
        expert fetch exactly once.
    """

    def __init__(self, num_layers: int, num_experts: int, *, capacity: int,
                 span_bytes: int, alpha: float = 0.25,
                 victim_quota: int = 0, replicate_frac: float = 0.0,
                 replica_exit: float = 0.5, replica_warmup: int = 8,
                 protect_ttl: int = 2):
        assert 0.0 < alpha <= 1.0
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.capacity = int(max(0, min(capacity, num_layers * num_experts)))
        self.span_bytes = span_bytes
        self.alpha = alpha
        # demand-path eviction allowance: misses normally fill free slots
        # only, but up to `victim_quota` demand admits per chunk may evict
        # a (strictly colder, unpinned) victim — so a cold cache under a
        # hot steady-state converges instead of refusing until the
        # prefetch path happens to agree (``begin_chunk`` refreshes it)
        self.victim_quota = int(max(0, victim_quota))
        self._victims_left = self.victim_quota
        # hot-expert replication: a replicate_frac share of the pool may
        # be pinned persistently to the popularity-EWMA top spans, with
        # enter/exit hysteresis (exit at replica_exit × the enter bar)
        self.replicate_frac = float(np.clip(replicate_frac, 0.0, 1.0))
        self.replica_exit = float(np.clip(replica_exit, 0.0, 1.0))
        self.replica_warmup = int(max(0, replica_warmup))
        self.protect_ttl = int(max(1, protect_ttl))
        self._chunks = 0              # accounting rounds seen (warmup gate)
        # degraded-mode occupancy cap (None = full capacity): set by the
        # engine's degradation ladder to shrink the pool reversibly —
        # admissions above the limit behave as if no slot were free, and
        # ``shrink_to_limit`` evicts cold spans down to it
        self.limit: Optional[int] = None
        self.slot_of = np.full((num_layers, num_experts), -1, np.int32)
        self.owner = np.full((self.capacity,), -1, np.int64)  # flat pair id
        self.free: List[int] = list(range(self.capacity))
        self.pinned: set = set()                              # flat pair ids
        self.replicas: set = set()            # flat pair ids, survive unpin
        # gate-predicted spans awaiting first use: pid → remaining
        # accounting rounds of eviction protection ("pinned in flight")
        self.protected: Dict[int, int] = {}
        self._pred_unused: set = set()        # predicted, not yet hit
        self.cause: Dict[int, str] = {}       # pid → admission cause
        self.popularity = np.zeros((num_layers, num_experts), np.float64)
        # per-layer unhidden-miss bytes — the roofline report's
        # miss-stall estimate (bytes / link bandwidth = stall time)
        self.miss_stall_bytes = np.zeros((num_layers,), np.int64)
        self.counters = ResidencyCounters()

    @property
    def replica_budget(self) -> int:
        return int(min(self.capacity,
                       round(self.replicate_frac * self.capacity)))

    # ------------------------------------------------------------- ids
    def _pid(self, layer: int, expert: int) -> int:
        return int(layer) * self.num_experts + int(expert)

    def _pair(self, pid: int) -> Pair:
        return divmod(int(pid), self.num_experts)

    # ---------------------------------------------------------- queries
    def is_resident(self, layer: int, expert: int) -> bool:
        return self.slot_of[layer, expert] >= 0

    def occupancy(self) -> int:
        return int((self.slot_of >= 0).sum())

    def resident_pairs(self) -> List[Pair]:
        return [self._pair(o) for o in self.owner if o >= 0]

    # ------------------------------------------------------------- pins
    def pin(self, pairs: Sequence[Pair]) -> None:
        """Protect spans an in-flight chunk may read in place: they cannot
        be evicted until ``unpin_all`` (called once the chunk's results
        are back on the host)."""
        self.pinned.update(self._pid(l, e) for l, e in pairs)

    def pin_resident(self) -> None:
        """Pin every currently-resident span: a dispatched chunk may read
        any of them in place, so none may be evicted until it lands."""
        self.pinned.update(int(o) for o in self.owner if o >= 0)

    def unpin_all(self) -> None:
        self.pinned.clear()

    def begin_chunk(self) -> None:
        """Refresh the per-chunk demand-eviction allowance (see
        ``victim_quota``) and age the predicted-span protection TTLs;
        the engine calls this once per accounting round."""
        self._victims_left = self.victim_quota
        self._chunks += 1
        for pid in [p for p, ttl in self.protected.items() if ttl <= 1]:
            del self.protected[pid]
        for pid in self.protected:
            self.protected[pid] -= 1

    # --------------------------------------------------- hit/miss booking
    def _book_hit(self, layer: int, expert: int) -> None:
        pid = self._pid(layer, expert)
        c = self.counters
        c.hits += 1
        if pid in self.replicas:
            c.replicated_hits += 1
        else:
            cause = self.cause.get(pid, "demand")
            if cause == "predicted":
                c.predicted_hits += 1
            elif cause == "router":
                c.router_hits += 1
            else:
                c.demand_hits += 1
        if pid in self._pred_unused:
            self._pred_unused.discard(pid)
            c.predicted_used += 1
        # first use releases the in-flight protection early
        self.protected.pop(pid, None)

    def _book_miss(self, layer: int, expert: int, hidden: bool) -> None:
        c = self.counters
        c.misses += 1
        c.h2d_bytes += self.span_bytes
        if hidden:
            c.hidden_misses += 1
        else:
            self.miss_stall_bytes[layer] += self.span_bytes

    # ----------------------------------------------- observe (accounting)
    def observe(self, activated: np.ndarray,
                token_counts: Optional[np.ndarray] = None,
                resident_mask: Optional[np.ndarray] = None,
                hidden_mask: Optional[np.ndarray] = None) -> List[Pair]:
        """Record one forward step's router decisions.

        activated: (L, E) bool — experts gated this step; token_counts
        optionally weights the popularity update by tokens routed.
        Updates the popularity EWMA, books hits / misses (+ inline H2D
        bytes for misses), and returns the missed pairs hottest-first —
        the admission candidates for the engine's prefetch queue.

        resident_mask: (L, E) bool snapshot of residency *at dispatch* of
        the step being booked — hits/misses must be judged against the
        map the step actually read, not the live one (prefetch/demand
        admissions may have landed since).

        hidden_mask: (L, E) bool — spans that became resident *between
        dispatch and landing* of this step (their stream overlapped its
        compute): such misses pay bytes but no per-layer stall."""
        activated = np.asarray(activated, bool)
        w = (np.asarray(token_counts, np.float64) if token_counts is not None
             else activated.astype(np.float64))
        denom = np.maximum(w.sum(axis=1, keepdims=True), 1.0)
        self.popularity += self.alpha * (w / denom - self.popularity)

        res = (np.asarray(resident_mask, bool) if resident_mask is not None
               else self.slot_of >= 0)
        hid = (np.asarray(hidden_mask, bool) if hidden_mask is not None
               else np.zeros_like(res))
        missed: List[Pair] = []
        for l, e in zip(*np.nonzero(activated)):
            if res[l, e]:
                self._book_hit(l, e)
            else:
                self._book_miss(l, e, bool(hid[l, e]))
                missed.append((int(l), int(e)))
        self.counters.lockstep_misses += len(missed)
        missed.sort(key=lambda p: -self.popularity[p])
        return missed

    def observe_window(self, activated: np.ndarray,
                       token_counts: Optional[np.ndarray] = None,
                       resident_mask: Optional[np.ndarray] = None,
                       hidden_mask: Optional[np.ndarray] = None
                       ) -> List[Pair]:
        """Book one module-batched accumulation window: `activated` is
        (G, L, E) — the G rotation groups that shared this forward step.
        An expert span streams at most ONCE per window regardless of how
        many groups routed to it, so hits/misses (and inline H2D bytes)
        are charged on the per-window UNION; ``lockstep_misses`` records
        what G separate ``observe`` calls would have charged, making the
        amortization measurable.  The popularity EWMA takes one update
        from the summed token weights (the window is one scheduling
        event, not G), and the returned admission candidates are the
        union misses hottest-first."""
        activated = np.asarray(activated, bool)
        assert activated.ndim == 3, "observe_window wants (G, L, E)"
        w = (np.asarray(token_counts, np.float64).sum(axis=0)
             if token_counts is not None
             else activated.astype(np.float64).sum(axis=0))
        denom = np.maximum(w.sum(axis=1, keepdims=True), 1.0)
        self.popularity += self.alpha * (w / denom - self.popularity)

        res = (np.asarray(resident_mask, bool) if resident_mask is not None
               else self.slot_of >= 0)
        hid = (np.asarray(hidden_mask, bool) if hidden_mask is not None
               else np.zeros_like(res))
        self.counters.lockstep_misses += int((activated & ~res[None]).sum())
        union = activated.any(axis=0)
        missed: List[Pair] = []
        for l, e in zip(*np.nonzero(union)):
            if res[l, e]:
                self._book_hit(l, e)
            else:
                self._book_miss(l, e, bool(hid[l, e]))
                missed.append((int(l), int(e)))
        missed.sort(key=lambda p: -self.popularity[p])
        return missed

    # ------------------------------------------------------- admit/evict
    def admit(self, layer: int, expert: int, *, demand: bool = False,
              allow_evict: bool = True, cause: Optional[str] = None,
              priority: Optional[float] = None) -> Optional[int]:
        """Grant (layer, expert) a pool slot; the caller must then copy
        the span into it.  Uses a free slot if any, else (when
        ``allow_evict``) evicts the coldest unpinned resident — only if
        it is strictly colder than the candidate (no thrash when the
        cache is already hotter), and never a pinned (in-flight) span, a
        replica, or a still-protected predicted span.
        Returns the slot id, or None when already resident / refused /
        capacity is zero.

        demand=True marks a miss stream landing directly in the pool (the
        bytes were already booked by ``observe``); otherwise this is a
        prefetch and pays ``span_bytes`` now.  ``cause`` labels the
        admission for hit attribution: "demand" (default when demand),
        "router" (default otherwise — the router-ahead group-j+1 path),
        "predicted" (gate-predictor lookahead; also grants
        ``protect_ttl`` rounds of eviction protection until first use)
        or "replica" (hot-expert replication fill).  The engine's demand
        path passes allow_evict=False — misses only fill free slots, and
        popularity-driven *replacement* is the prefetch path's job — so
        the two admission flows stay observable in the counters.
        Exception: up to ``victim_quota`` demand admits per chunk may
        evict anyway (same strictly-colder/unpinned rules), so a cold
        cache under a hot steady state converges faster.

        ``priority`` overrides the candidate's own popularity in the
        strictly-colder victim test: the popularity EWMA is a *long-run*
        frequency, but a gate-predicted span carries a *short-horizon*
        next-chunk activation probability — the engine passes
        score × predictor-accuracy so an imminent span can displace a
        stale tail resident the EWMA still ranks above it.  Replicas
        (the pinned long-run core) and protected spans are never
        victims, so the two signals occupy complementary slots."""
        if cause is None:
            cause = "demand" if demand else "router"
        if self.capacity == 0 or self.is_resident(layer, expert):
            return None
        # degraded-mode cap: at the limit a free slot is off-budget, so
        # admission must displace a victim (occupancy never grows)
        at_limit = (self.limit is not None
                    and self.occupancy() >= self.limit)
        use_quota = (not allow_evict and demand
                     and (not self.free or at_limit)
                     and self._victims_left > 0)
        if self.free and not at_limit:
            slot = self.free.pop()
        elif not allow_evict and not use_quota:
            self.counters.refusals += 1
            return None
        else:
            # o >= 0: with the degraded-mode cap the eviction branch can
            # run while free slots exist (they are off-budget, not victims)
            cands = [(self.popularity[self._pair(o)], s)
                     for s, o in enumerate(self.owner)
                     if o >= 0 and int(o) not in self.pinned
                     and int(o) not in self.replicas
                     and int(o) not in self.protected]
            if not cands:
                self.counters.refusals += 1
                return None
            vpop, slot = min(cands)
            cand_pri = (float(priority) if priority is not None
                        else self.popularity[layer, expert])
            if vpop >= cand_pri:
                self.counters.refusals += 1
                return None
            self.evict(slot)
            self.free.remove(slot)
            if use_quota:
                self._victims_left -= 1
        pid = self._pid(layer, expert)
        self.owner[slot] = pid
        self.slot_of[layer, expert] = slot
        self.cause[pid] = cause
        if demand:
            self.counters.demand_admits += 1
        else:
            self.counters.prefetches += 1
            self.counters.h2d_bytes += self.span_bytes
            if cause == "predicted":
                self.counters.predicted_prefetches += 1
                self._pred_unused.add(pid)
                self.protected[pid] = self.protect_ttl
            elif cause == "replica":
                self.counters.replications += 1
        return slot

    def evict(self, slot: int) -> None:
        pid = int(self.owner[slot])
        assert pid >= 0, f"evicting empty slot {slot}"
        assert pid not in self.pinned, \
            f"evicting pinned span {self._pair(pid)} (in-flight)"
        assert pid not in self.replicas, \
            f"evicting replicated span {self._pair(pid)}"
        self.slot_of[self._pair(pid)] = -1
        self.owner[slot] = -1
        self.free.append(slot)
        self.cause.pop(pid, None)
        self.protected.pop(pid, None)
        self._pred_unused.discard(pid)
        self.counters.evictions += 1

    # ----------------------------------------------- degraded-mode shrink
    def drop_replicas(self) -> int:
        """Release every persistent replica pin (the spans stay resident
        — they just become ordinary eviction candidates).  First step of
        the ladder's residency_shrunk rung."""
        n = len(self.replicas)
        self.replicas.clear()
        return n

    def set_limit(self, limit: Optional[int]) -> int:
        """Cap (or, with None, restore) the pool's usable occupancy.
        Returns the number of spans evicted to honor the new cap.
        Reversible by construction: residency only decides where bytes
        stream from, so shrinking never changes tokens."""
        self.limit = None if limit is None else int(max(1, limit))
        return self.shrink_to_limit()

    def shrink_to_limit(self) -> int:
        """Evict coldest-first down to ``limit``, skipping pinned
        (in-flight), replicated and still-protected spans — best effort:
        if pins block the full shrink, admission's at-limit rule keeps
        occupancy from growing and a later call finishes the job."""
        if self.limit is None:
            return 0
        evicted = 0
        while self.occupancy() > self.limit:
            cands = [(self.popularity[self._pair(o)], s)
                     for s, o in enumerate(self.owner)
                     if o >= 0 and int(o) not in self.pinned
                     and int(o) not in self.replicas
                     and int(o) not in self.protected]
            if not cands:
                break
            _, slot = min(cands)
            self.evict(slot)
            evicted += 1
        return evicted

    # ------------------------------------------------------- replication
    def update_replicas(self) -> List[Tuple[int, int, int]]:
        """Reconcile the replica set with the popularity EWMA, with
        hysteresis: a span enters when it ranks inside the
        ``replica_budget`` (popularity ≥ θ_hi, the rank-budget entry's
        popularity) and exits only when it decays below
        ``replica_exit · θ_hi`` — so replicas survive window turnover
        instead of churning with it.  Demoted replicas stay resident
        (they just lose the persistent pin); promoted spans that are not
        yet resident are admitted with cause="replica" (the caller must
        copy those spans — they are returned as (layer, expert, slot)).

        No-op for the first ``replica_warmup`` accounting rounds: the
        EWMA is still cold-start noise, and pinning the wrong spans
        early slows demand convergence more than replication helps."""
        if self.limit is not None:
            # degraded (residency_shrunk): replica pins stay dropped so
            # the shrunken pool keeps every slot evictable
            return []
        budget = self.replica_budget
        if budget <= 0:
            self.replicas.clear()
            return []
        if self._chunks < self.replica_warmup:
            return []
        pop = self.popularity.reshape(-1)
        order = np.argsort(-pop, kind="stable")
        top = [int(i) for i in order[:budget] if pop[i] > 0.0]
        if not top:
            return []
        theta_hi = float(pop[top[-1]])
        theta_lo = self.replica_exit * theta_hi
        for pid in [p for p in self.replicas if pop[p] < theta_lo]:
            self.replicas.discard(pid)
        copies: List[Tuple[int, int, int]] = []
        for pid in top:
            if len(self.replicas) >= budget:
                break
            if pid in self.replicas:
                continue
            l, e = self._pair(pid)
            if self.is_resident(l, e):
                self.replicas.add(pid)
                continue
            slot = self.admit(l, e, cause="replica")
            if slot is not None:
                self.replicas.add(pid)
                copies.append((l, e, slot))
        return copies


# ---------------------------------------------------------------------------
# Cross-layer gate prediction
# ---------------------------------------------------------------------------

def _sigmoid(z: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-np.clip(z, -30.0, 30.0)))


class GatePredictor:
    """Per-layer-transition logistic heads predicting layer-i+1 expert
    activations from layer-i routed-token counts.

    One head per transition: ``W[i]`` maps the normalized layer-i
    token-count vector (plus a bias feature) to per-expert activation
    logits for layer i+1.  Fit online with plain numpy SGD on the host
    control plane — one gradient step per forward pass per transition,
    on the (chunk, L, E) activation counts the decode scan already
    emits — so prediction costs no jit retrace and no device work.

    The transition structure is cyclic in *time order*: heads
    0..L-2 map layer i to layer i+1 of the same forward pass, and the
    wrap head L-1 maps layer L-1 of pass t to layer 0 of pass t+1 — the
    temporal successor during decode (the scan finishes the stack, then
    the next pass re-enters layer 0).  The wrap head is what lets the
    predictor cover EVERY layer's next-pass activations, not just
    layers ≥ 1.

    ``acc`` is an EWMA of the *pre-update* top-k overlap between each
    head's prediction and the realized next-layer gating (k = realized
    activation breadth): the honest online accuracy estimate
    ``hrm.expert_hit_rate``'s predictor term consumes.
    """

    def __init__(self, num_layers: int, num_experts: int, *,
                 lr: float = 0.5, acc_alpha: float = 0.25,
                 wrap: bool = True):
        self.num_layers = int(num_layers)
        self.num_experts = int(num_experts)
        self.lr = float(lr)
        self.acc_alpha = float(acc_alpha)
        self.wrap = bool(wrap) and self.num_layers >= 1
        n_trans = max(0, self.num_layers - 1) + (1 if self.wrap else 0)
        # (transition, feature, expert); feature = E counts + 1 bias
        self.W = np.zeros((n_trans, self.num_experts + 1, self.num_experts),
                          np.float64)
        self.acc = 0.0
        self._n_fits = 0
        self._prev_top: Optional[np.ndarray] = None  # last pass's layer L-1

    def _feat(self, counts: np.ndarray) -> np.ndarray:
        x = np.asarray(counts, np.float64).reshape(-1)
        s = x.sum()
        if s > 0:
            x = x / s
        return np.concatenate([x, [1.0]])

    def fit_step(self, counts: np.ndarray) -> float:
        """One SGD step per layer transition on a single forward pass's
        (L, E) routed-token counts.  Scores each head's top-k prediction
        against the realized next layer BEFORE updating (honest online
        accuracy), folds the score into the EWMA, and returns it.

        The wrap head is fit on *consecutive calls*: the previous call's
        layer L-1 counts predict this call's layer 0.  Passes are fed in
        decode order per chunk, so within a chunk the pairing is exact;
        across chunk boundaries the stream may interleave rotation
        groups, which adds label noise the EWMA absorbs.
        """
        counts = np.asarray(counts, np.float64)
        if self.W.shape[0] == 0 or counts.sum() <= 0:
            return self.acc
        correct = 0
        total = 0
        for i in range(self.num_layers - 1):
            x = self._feat(counts[i])
            y = (counts[i + 1] > 0).astype(np.float64)
            k = int(y.sum())
            p = _sigmoid(x @ self.W[i])
            if k:
                top = np.argsort(-p, kind="stable")[:k]
                correct += int(y[top].sum())
                total += k
            self.W[i] += self.lr * np.outer(x, y - p)
        if self.wrap:
            prev = self._prev_top
            if prev is not None and prev.sum() > 0:
                wi = self.num_layers - 1
                x = self._feat(prev)
                y = (counts[0] > 0).astype(np.float64)
                k = int(y.sum())
                p = _sigmoid(x @ self.W[wi])
                if k:
                    top = np.argsort(-p, kind="stable")[:k]
                    correct += int(y[top].sum())
                    total += k
                self.W[wi] += self.lr * np.outer(x, y - p)
            self._prev_top = counts[self.num_layers - 1].copy()
        if total:
            score = correct / total
            self._n_fits += 1
            a = 1.0 if self._n_fits == 1 else self.acc_alpha
            self.acc += a * (score - self.acc)
        return self.acc

    def predict(self, counts: np.ndarray, *, lookahead: int = 2,
                topk: Optional[int] = None
                ) -> List[Tuple[int, int, float]]:
        """Score the experts the NEXT chunk will activate, per layer,
        from the last observed (L, E) counts: shift 1 maps layer i
        through head i to layer i+1; shift 2 chains the shift-1
        probabilities (as pseudo-counts) through the next head — the
        "stream layer i+2 while layer i computes" lookahead.  Per target
        layer, the top-k scores survive (k defaults to the source
        layer's realized activation breadth).  Returns
        [(layer, expert, score)] with each pair's best score over
        shifts."""
        counts = np.asarray(counts, np.float64)
        if self.W.shape[0] == 0 or counts.sum() <= 0 or lookahead <= 0:
            return []
        score = np.zeros((self.num_layers, self.num_experts), np.float64)
        cur = counts.astype(np.float64)
        n_src = self.num_layers if self.wrap else self.num_layers - 1
        for _shift in range(1, int(lookahead) + 1):
            nxt = np.zeros_like(cur)
            for i in range(n_src):
                src = cur[i]
                if src.sum() <= 0:
                    continue
                j = (i + 1) % self.num_layers
                p = _sigmoid(self._feat(src) @ self.W[i])
                k = (int(topk) if topk is not None
                     else int(min(self.num_experts,
                                  max(1, int((counts[i] > 0).sum())))))
                top = np.argsort(-p, kind="stable")[:k]
                sel = np.zeros(self.num_experts, np.float64)
                sel[top] = p[top]
                nxt[j] = np.maximum(nxt[j], sel)
                score[j] = np.maximum(score[j], sel)
            cur = nxt
            if cur.sum() <= 0:
                break
        return [(int(l), int(e), float(score[l, e]))
                for l, e in zip(*np.nonzero(score > 0.0))]

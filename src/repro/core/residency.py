"""Device-resident expert cache (control plane for expert-granular paging).

The paper's policy tuple sizes a weight budget with ``r_w`` but the seed
paging layer streamed every layer's full page span regardless — all E
experts — even though top-k routing touches a fraction of them.  This
module turns ``r_w`` into an actual placement decision: a fixed device
page pool holds ``capacity`` expert spans (``slots_from_ratio`` converts
the policy ratio into a span count), an activation-popularity EWMA (the
``core.batching.GenLenEWMA`` pattern lifted to a (layer, expert) table)
decides which spans deserve the slots, and hit/miss + H2D-byte counters
make the traffic observable (``benchmarks/bench_paging.py`` reports them).

Split of responsibilities:

  * data plane — functional JAX: the pool array and the
    ``(layer, expert) → slot`` resident map are *arguments* to the jitted
    serving steps; the in-scan gather reads resident spans from the pool
    and streams misses from the host store (models.moe.moe_paged);
  * control plane — this module, host-side numpy: which span occupies
    which slot, popularity, pins, counters.  The engine snapshots
    ``slot_of`` into the step call, so evicting *after* a chunk is
    dispatched can never corrupt it (the chunk holds its snapshot);
    pins additionally protect the spans an in-flight chunk may read so
    the router-ahead prefetch for the *next* group cannot recycle them.

Accounting model (consistent with DESIGN.md §2 — on the CPU validation
container traffic is accounted, not physically transferred):

  * an activated expert whose span is resident is a **hit** (0 bytes);
  * an activated non-resident expert is a **miss** and streams its span
    inline (``span_bytes`` H2D).  Demand-admitting it into the pool in
    the same step reuses that stream (no second charge);
  * a router-ahead **prefetch** admits a predicted span before use and
    pays ``span_bytes`` up front; its later activation is then a hit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

Pair = Tuple[int, int]                       # (layer, expert)


def slots_from_ratio(w_gpu_ratio: float, num_layers: int,
                     num_experts: int) -> int:
    """Pool capacity (in expert spans) implied by the policy's ``r_w``:
    the fraction of all (layer, expert) spans that fits device-resident."""
    total = num_layers * num_experts
    return int(np.clip(round(w_gpu_ratio * total), 0, total))


@dataclass
class ResidencyCounters:
    hits: int = 0            # activated & resident (0 bytes)
    misses: int = 0          # activated & streamed inline (span_bytes)
    prefetches: int = 0      # admitted ahead of use (span_bytes)
    demand_admits: int = 0   # miss stream landed in a pool slot (no charge)
    evictions: int = 0
    refusals: int = 0        # admission declined (pinned/hotter cache)
    h2d_bytes: int = 0       # expert-span H2D traffic booked
    # what G separate per-group bookings would have charged: observe()
    # adds its own misses (lockstep IS per-group), observe_window() adds
    # the per-group miss count before the union dedup — the ratio
    # lockstep_misses / misses is the measured module-batching
    # amortization factor (weight_traffic()["module_groups_effective"])
    lockstep_misses: int = 0

    @property
    def fetches(self) -> int:
        """Total activated-expert fetch events (hits + misses)."""
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.fetches if self.fetches else 0.0


class ExpertResidency:
    """Fixed-capacity residency manager for one stacked layer group.

    Invariants (enforced by tests/test_residency.py):
      * occupancy ≤ capacity, and ``slot_of``/``owner`` stay a bijection
        between resident pairs and occupied slots;
      * a pinned span (in use by an in-flight chunk) is never evicted;
      * ``counters.fetches == hits + misses`` counts every activated
        expert fetch exactly once.
    """

    def __init__(self, num_layers: int, num_experts: int, *, capacity: int,
                 span_bytes: int, alpha: float = 0.25,
                 victim_quota: int = 0):
        assert 0.0 < alpha <= 1.0
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.capacity = int(max(0, min(capacity, num_layers * num_experts)))
        self.span_bytes = span_bytes
        self.alpha = alpha
        # demand-path eviction allowance: misses normally fill free slots
        # only, but up to `victim_quota` demand admits per chunk may evict
        # a (strictly colder, unpinned) victim — so a cold cache under a
        # hot steady-state converges instead of refusing until the
        # prefetch path happens to agree (``begin_chunk`` refreshes it)
        self.victim_quota = int(max(0, victim_quota))
        self._victims_left = self.victim_quota
        self.slot_of = np.full((num_layers, num_experts), -1, np.int32)
        self.owner = np.full((self.capacity,), -1, np.int64)  # flat pair id
        self.free: List[int] = list(range(self.capacity))
        self.pinned: set = set()                              # flat pair ids
        self.popularity = np.zeros((num_layers, num_experts), np.float64)
        self.counters = ResidencyCounters()

    # ------------------------------------------------------------- ids
    def _pid(self, layer: int, expert: int) -> int:
        return int(layer) * self.num_experts + int(expert)

    def _pair(self, pid: int) -> Pair:
        return divmod(int(pid), self.num_experts)

    # ---------------------------------------------------------- queries
    def is_resident(self, layer: int, expert: int) -> bool:
        return self.slot_of[layer, expert] >= 0

    def occupancy(self) -> int:
        return int((self.slot_of >= 0).sum())

    def resident_pairs(self) -> List[Pair]:
        return [self._pair(o) for o in self.owner if o >= 0]

    # ------------------------------------------------------------- pins
    def pin(self, pairs: Sequence[Pair]) -> None:
        """Protect spans an in-flight chunk may read in place: they cannot
        be evicted until ``unpin_all`` (called once the chunk's results
        are back on the host)."""
        self.pinned.update(self._pid(l, e) for l, e in pairs)

    def pin_resident(self) -> None:
        """Pin every currently-resident span: a dispatched chunk may read
        any of them in place, so none may be evicted until it lands."""
        self.pinned.update(int(o) for o in self.owner if o >= 0)

    def unpin_all(self) -> None:
        self.pinned.clear()

    def begin_chunk(self) -> None:
        """Refresh the per-chunk demand-eviction allowance (see
        ``victim_quota``); the engine calls this once per accounting
        round."""
        self._victims_left = self.victim_quota

    # ----------------------------------------------- observe (accounting)
    def observe(self, activated: np.ndarray,
                token_counts: Optional[np.ndarray] = None,
                resident_mask: Optional[np.ndarray] = None) -> List[Pair]:
        """Record one forward step's router decisions.

        activated: (L, E) bool — experts gated this step; token_counts
        optionally weights the popularity update by tokens routed.
        Updates the popularity EWMA, books hits / misses (+ inline H2D
        bytes for misses), and returns the missed pairs hottest-first —
        the admission candidates for the engine's prefetch queue.

        resident_mask: (L, E) bool snapshot of residency *at dispatch* of
        the step being booked — hits/misses must be judged against the
        map the step actually read, not the live one (prefetch/demand
        admissions may have landed since)."""
        activated = np.asarray(activated, bool)
        w = (np.asarray(token_counts, np.float64) if token_counts is not None
             else activated.astype(np.float64))
        denom = np.maximum(w.sum(axis=1, keepdims=True), 1.0)
        self.popularity += self.alpha * (w / denom - self.popularity)

        res = (np.asarray(resident_mask, bool) if resident_mask is not None
               else self.slot_of >= 0)
        missed: List[Pair] = []
        for l, e in zip(*np.nonzero(activated)):
            if res[l, e]:
                self.counters.hits += 1
            else:
                self.counters.misses += 1
                self.counters.h2d_bytes += self.span_bytes
                missed.append((int(l), int(e)))
        self.counters.lockstep_misses += len(missed)
        missed.sort(key=lambda p: -self.popularity[p])
        return missed

    def observe_window(self, activated: np.ndarray,
                       token_counts: Optional[np.ndarray] = None,
                       resident_mask: Optional[np.ndarray] = None
                       ) -> List[Pair]:
        """Book one module-batched accumulation window: `activated` is
        (G, L, E) — the G rotation groups that shared this forward step.
        An expert span streams at most ONCE per window regardless of how
        many groups routed to it, so hits/misses (and inline H2D bytes)
        are charged on the per-window UNION; ``lockstep_misses`` records
        what G separate ``observe`` calls would have charged, making the
        amortization measurable.  The popularity EWMA takes one update
        from the summed token weights (the window is one scheduling
        event, not G), and the returned admission candidates are the
        union misses hottest-first."""
        activated = np.asarray(activated, bool)
        assert activated.ndim == 3, "observe_window wants (G, L, E)"
        w = (np.asarray(token_counts, np.float64).sum(axis=0)
             if token_counts is not None
             else activated.astype(np.float64).sum(axis=0))
        denom = np.maximum(w.sum(axis=1, keepdims=True), 1.0)
        self.popularity += self.alpha * (w / denom - self.popularity)

        res = (np.asarray(resident_mask, bool) if resident_mask is not None
               else self.slot_of >= 0)
        self.counters.lockstep_misses += int((activated & ~res[None]).sum())
        union = activated.any(axis=0)
        missed: List[Pair] = []
        for l, e in zip(*np.nonzero(union)):
            if res[l, e]:
                self.counters.hits += 1
            else:
                self.counters.misses += 1
                self.counters.h2d_bytes += self.span_bytes
                missed.append((int(l), int(e)))
        missed.sort(key=lambda p: -self.popularity[p])
        return missed

    # ------------------------------------------------------- admit/evict
    def admit(self, layer: int, expert: int, *, demand: bool = False,
              allow_evict: bool = True) -> Optional[int]:
        """Grant (layer, expert) a pool slot; the caller must then copy
        the span into it.  Uses a free slot if any, else (when
        ``allow_evict``) evicts the coldest unpinned resident — only if
        it is strictly colder than the candidate (no thrash when the
        cache is already hotter), and never a pinned (in-flight) span.
        Returns the slot id, or None when already resident / refused /
        capacity is zero.

        demand=True marks a miss stream landing directly in the pool (the
        bytes were already booked by ``observe``); otherwise this is a
        router-ahead prefetch and pays ``span_bytes`` now.  The engine's
        demand path passes allow_evict=False — misses only fill free
        slots, and popularity-driven *replacement* is the prefetch
        path's job — so the two admission flows stay observable in the
        counters.  Exception: up to ``victim_quota`` demand admits per
        chunk may evict anyway (same strictly-colder/unpinned rules), so
        a cold cache under a hot steady state converges faster."""
        if self.capacity == 0 or self.is_resident(layer, expert):
            return None
        use_quota = (not allow_evict and demand and not self.free
                     and self._victims_left > 0)
        if self.free:
            slot = self.free.pop()
        elif not allow_evict and not use_quota:
            self.counters.refusals += 1
            return None
        else:
            cands = [(self.popularity[self._pair(o)], s)
                     for s, o in enumerate(self.owner)
                     if o not in self.pinned]
            if not cands:
                self.counters.refusals += 1
                return None
            vpop, slot = min(cands)
            if vpop >= self.popularity[layer, expert]:
                self.counters.refusals += 1
                return None
            self.evict(slot)
            self.free.remove(slot)
            if use_quota:
                self._victims_left -= 1
        self.owner[slot] = self._pid(layer, expert)
        self.slot_of[layer, expert] = slot
        if demand:
            self.counters.demand_admits += 1
        else:
            self.counters.prefetches += 1
            self.counters.h2d_bytes += self.span_bytes
        return slot

    def evict(self, slot: int) -> None:
        pid = int(self.owner[slot])
        assert pid >= 0, f"evicting empty slot {slot}"
        assert pid not in self.pinned, \
            f"evicting pinned span {self._pair(pid)} (in-flight)"
        self.slot_of[self._pair(pid)] = -1
        self.owner[slot] = -1
        self.free.append(slot)
        self.counters.evictions += 1

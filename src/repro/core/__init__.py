from repro.core import batching, cgopipe, hrm, offload, paging, policy  # noqa: F401

"""Roofline analysis of compiled XLA artifacts (deliverable §Roofline).

Derives the three roofline terms for a lowered+compiled step:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_chip / HBM_bandwidth
    collective = collective_bytes_per_chip / ICI_link_bandwidth

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition under
SPMD).  Collective bytes are NOT in cost_analysis — we parse the compiled
HLO text and sum operand sizes of all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute ops.

Hardware constants (task-assigned, TPU v5e): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "bf16[8,128,2048]{2,1,0}" or "f32[128]"
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum the *output* shape bytes of every collective op instance.

    For all-reduce/all-to-all the output size equals the input; for
    all-gather it is the gathered (larger) size and for reduce-scatter the
    pre-reduce input is larger — we use the max of output and operand
    shapes on the line as the per-chip wire-bytes proxy.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # instruction lines look like:  %x = bf16[..] all-gather(bf16[..] %y), ...
        m = re.search(r"=\s*[\w\[\],{}\s()]*?\b(" + "|".join(_COLLECTIVES) +
                      r")(-start|-done)?\(", s)
        if not m:
            continue
        kind, phase = m.group(1), m.group(2)
        if phase == "-done":      # avoid double counting start/done pairs
            continue
        shapes = _SHAPE_RE.findall(s)
        if not shapes:
            continue
        nbytes = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes: float
    collectives: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float           # 6*N*D (active params x tokens)
    chips: int
    memory_per_chip: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — catches remat/redundancy waste."""
        tot = self.flops_per_chip * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roof actually used at the bound:
        (model_flops/chips/t_bound) / peak."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.chips / self.t_bound) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_per_chip": self.memory_per_chip,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, hlo_text: Optional[str] = None,
            census=None) -> RooflineReport:
    """When a `core.census.Census` is supplied its exact analytic
    flops/bytes/collective-bytes become the roofline terms (XLA's
    cost_analysis counts scan bodies once — see census.py); the HLO-parsed
    quantities are retained in the report for cross-checking."""
    cost = compiled.cost_analysis()
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    mem = compiled.memory_analysis()
    mem_d = {
        "argument": float(mem.argument_size_in_bytes),
        "output": float(mem.output_size_in_bytes),
        "temp": float(mem.temp_size_in_bytes),
        "peak_estimate": float(mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes),
        "hlo_flops_per_chip": hlo_flops,
        "hlo_bytes_per_chip": hlo_bytes,
        "hlo_collective_bytes": coll.total_bytes,
    }
    if census is not None:
        flops = census.flops / chips
        bytes_ = census.hbm_bytes
        coll_bytes = census.coll_total
        coll_kinds = dict(census.coll_bytes)
    else:
        flops, bytes_ = hlo_flops, hlo_bytes
        coll_bytes, coll_kinds = coll.total_bytes, dict(coll.bytes_by_kind)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_chip=flops, bytes_per_chip=bytes_,
        collective_bytes=coll_bytes,
        collectives=coll_kinds,
        t_compute=flops / PEAK_FLOPS,
        t_memory=bytes_ / HBM_BW,
        t_collective=coll_bytes / ICI_BW,
        model_flops=model_flops, chips=chips, memory_per_chip=mem_d)


def model_flops_for(cfg, shape_cfg) -> float:
    """6*N_active*D for training, 2*N_active*D for inference steps."""
    from repro.models.params import count_params
    n_active = count_params(cfg, active_only=True, include_embed=False)
    tokens = shape_cfg.global_batch * (1 if shape_cfg.mode == "decode"
                                       else shape_cfg.seq_len)
    mult = 6 if shape_cfg.mode == "train" else 2
    return float(mult * n_active * tokens)

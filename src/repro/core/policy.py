"""Policy search (paper §4.2).

Searches the 6-tuple P = (N, μ, A_g, F_g, r_w, r_c) minimizing estimated
per-layer decode latency T(M, H, W, P) = max(comm_cpu→gpu, T_cpu, T_gpu)
subject to GPU and CPU memory capacities — i.e. drives the system to the
HRM balance point (Eq. 11).  The paper solves a MILP; the space is small
enough for exact enumeration (no solver dependency offline), finishing in
well under the paper's "less than a minute".
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.core import hrm as H


@dataclass(frozen=True)
class Policy:
    """The paper's Table-1 policy tuple (+ derived batching plan)."""
    batch: int               # N — tokens per model pass
    ubatch: int              # μ — tokens per kernel execution
    attn_on_gpu: bool        # A_g
    ffn_on_gpu: bool         # F_g
    w_gpu_ratio: float       # r_w — weights resident on GPU
    kv_gpu_ratio: float      # r_c — KV cache resident on GPU
    # module-based batching: rotation groups accumulated per expert-phase
    # window (1 = lockstep attention/FFN, the classic CGOPipe schedule).
    # Each streamed weight span then serves G groups' staged tokens, so
    # the HRM weight-traffic term amortizes by 1/G at the cost of a
    # G-deep routed-token staging buffer (memory_usage charges it).
    module_groups: int = 1
    # intra-pass predictive prefetch: layers of gate-predictor lookahead
    # (0 = off).  ℓ ≥ 1 lets predicted spans stream while earlier layers
    # compute (expert_hit_rate's predictor term), at the cost of an
    # ℓ-deep in-flight span staging charge (memory_usage).
    predict_lookahead: int = 0
    # hot-expert replication: fraction of the r_w·E residency slots
    # pinned persistently to the popularity-top experts (None = no
    # replication — the legacy pure-LRU/EWMA model).
    replicate_frac: Optional[float] = None

    @property
    def num_ubs(self) -> int:
        return max(1, self.batch // self.ubatch)


@dataclass(frozen=True)
class Workload:
    """Paper Table 1: s (avg prompt len), n (generation length)."""
    prompt_len: int
    gen_len: int

    @property
    def avg_ctx(self) -> float:
        return self.prompt_len + self.gen_len / 2


# ---------------------------------------------------------------------------
# Memory accounting
# ---------------------------------------------------------------------------

def model_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    from repro.models.params import count_params
    return count_params(cfg) * dtype_bytes


def kv_bytes_per_token_layer(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    if cfg.kv_lora_rank:
        return (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * dtype_bytes
    if cfg.num_kv_heads == 0:      # SSM: O(1) state, charge nothing per token
        return 0.0
    return 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes


def memory_usage(cfg: ModelConfig, wl: Workload, pol: Policy,
                 dtype_bytes: int = 2) -> Dict[str, float]:
    W_total = model_bytes(cfg, dtype_bytes)
    W_layer = W_total / max(cfg.num_layers, 1)
    kv_total = (kv_bytes_per_token_layer(cfg, dtype_bytes) * cfg.num_layers
                * pol.batch * (wl.prompt_len + wl.gen_len))
    act = pol.ubatch * cfg.d_model * dtype_bytes
    gpu = (pol.w_gpu_ratio * W_total
           + pol.kv_gpu_ratio * kv_total
           + 2 * (1 - pol.w_gpu_ratio) * W_layer       # 2x page buffer (A.1)
           + 8 * act)                                  # in-flight activations
    mg = max(1, int(getattr(pol, "module_groups", 1) or 1))
    if mg > 1:
        # module-based batching: the routed-token staging buffer holds
        # every group's top-k expanded activations for the layer being
        # executed (gather input + scatter output, hence the 2×)
        gpu += 2 * mg * pol.ubatch * max(cfg.top_k, 1) * cfg.d_model \
            * dtype_bytes
    la = max(0, int(getattr(pol, "predict_lookahead", 0) or 0))
    if la > 0 and cfg.is_moe:
        # predicted spans stream ahead of their layer: up to ℓ layers ×
        # top-k expert spans are in flight (pinned, not yet chargeable to
        # the resident pool) at any point of the pass
        gpu += la * max(cfg.top_k, 1) * 3 * cfg.d_model * (cfg.d_ff or 0) \
            * dtype_bytes
    if pol.attn_on_gpu:
        gpu += (1 - pol.kv_gpu_ratio) * kv_total / max(cfg.num_layers, 1) * 2
    cpu = ((1 - pol.w_gpu_ratio) * W_total
           + (1 - pol.kv_gpu_ratio) * kv_total
           + 4 * (1 - pol.w_gpu_ratio) * W_layer       # pinned staging
           + 8 * act)
    return {"gpu": gpu, "cpu": cpu, "kv_total": kv_total, "w_total": W_total}


# ---------------------------------------------------------------------------
# Throughput estimate
# ---------------------------------------------------------------------------

def estimate(cfg: ModelConfig, hw: H.Hardware, wl: Workload, pol: Policy,
             dtype_bytes: int = 2, expert_popularity=None,
             kv_hit_rate: Optional[float] = None,
             kv_paged: bool = False,
             block_tokens: Optional[int] = None,
             predictor_accuracy: float = 0.0) -> Dict[str, float]:
    """Per-layer decode latency (Eq. 12) and end-to-end generation
    throughput (tokens/s) including prefill amortization.

    expert_popularity: optional measured routing-frequency table ((E,) or
    (L, E), e.g. core.residency's EWMA) — MoE weight traffic then uses
    expected activated-expert bytes × miss rate of the r_w-sized resident
    cache (H.expert_hit_rate) instead of the uniform (1 - r_w) stream.

    kv_hit_rate: optional measured device-hit fraction of KV block
    touches (core.blockpool counters) — the attention traffic term then
    becomes miss rate × touched block bytes instead of the r_c-linear
    stream.  kv_paged=True models the block-granular pool instead:
    H.kv_block_hit_rate(r_c, num_ubs) — rotation makes a small arena
    disproportionately effective, so the search can trade r_c down and
    spend the memory on r_w.

    block_tokens: block size of the paged pool — the page-table-native
    decode kernels gather whole blocks, so the touched-KV term rounds
    the context up to the mapped-block footprint (matching the engine's
    gathered-bytes counters).

    predictor_accuracy: measured GatePredictor accuracy (the engine's
    weight_traffic()['predictor_accuracy']) — with
    pol.predict_lookahead ≥ 1 the expert-traffic term credits intra-pass
    predicted prefetch (H.expert_hit_rate's predictor term)."""
    kv_hit = kv_hit_rate
    if kv_hit is None and kv_paged:
        kv_hit = H.kv_block_hit_rate(pol.kv_gpu_ratio, pol.num_ubs)
    lw = H.LayerWorkload.decode(cfg, pol.batch, wl.avg_ctx, dtype_bytes,
                                popularity=expert_popularity,
                                kv_hit=kv_hit, block_tokens=block_tokens,
                                predictor_accuracy=predictor_accuracy)
    lat = H.layer_latency(hw, lw, pol)
    t_layer = lat["t_layer"]
    # prefill: compute-bound on the accelerator, overlapped with weight
    # streaming (paper §4: zig-zag order, no extra optimization)
    gpu = hw.level("gpu")
    from repro.models.params import count_params
    n_active = count_params(cfg, active_only=True)
    pf_flops = 2 * n_active * pol.batch * wl.prompt_len
    w_stream = (1 - pol.w_gpu_ratio) * model_bytes(cfg, dtype_bytes)
    t_prefill = max(pf_flops / gpu.p_peak,
                    w_stream / hw.link_bw("cpu", "gpu"))
    t_decode = wl.gen_len * cfg.num_layers * t_layer
    thr = pol.batch * wl.gen_len / (t_prefill + t_decode)
    return {"throughput": thr, "t_layer": t_layer, "t_prefill": t_prefill,
            **{k: v for k, v in lat.items() if k != "t_layer"}}


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

def search(cfg: ModelConfig, hw: H.Hardware, wl: Workload,
           dtype_bytes: int = 2,
           ub_grid=(4, 8, 16, 32, 36, 64, 100, 128, 256),
           mult_grid=(1, 2, 4, 8, 15, 16, 26, 32, 61, 64, 92, 128, 256),
           ratio_grid=(0.0, 0.1, 0.2, 0.25, 0.5, 0.75, 0.9, 1.0),
           expert_popularity=None, kv_paged: bool = False,
           block_tokens: Optional[int] = None,
           module_groups_grid=(1,),
           predict_grid=(0,), replicate_grid=(None,),
           predictor_accuracy: float = 0.0,
           bench_path: Optional[str] = None) -> Dict:
    """Exact enumeration over the 6-tuple.  Returns the best feasible
    policy and its estimate; also the best with attention forced to each
    device (for the §6.3-style case study).

    With ``expert_popularity`` (a measured routing-frequency table), the
    MoE weight-traffic term becomes expected activated-expert bytes ×
    residency miss rate, so the search genuinely trades r_w against hit
    rate — skewed routing shifts the optimum toward smaller r_w.

    With ``kv_paged`` the KV traffic term models the block-granular
    paged pool (H.kv_block_hit_rate): rotation over num_ubs groups means
    an arena of r_c × total blocks serves ~min(1, r_c·num_ubs) of each
    step's touches from device, so smaller r_c stays feasible at the
    same latency and the freed memory can buy r_w — the search trades
    the two on one budget.

    ``module_groups_grid`` widens the search over module-based batching
    (decoupled attention/expert phases, MoE-Gen direction): G > 1
    amortizes the weight-traffic term by 1/G at the cost of a staging
    buffer (memory_usage).  The default grid (1,) keeps the classic
    lockstep search — opt in with e.g. ``module_groups_grid=(1, 2, 4)``;
    G is capped at num_ubs (there must be G groups to accumulate).

    ``predict_grid`` / ``replicate_grid`` widen the search over the
    intra-pass prediction + replication layer: lookahead ℓ credits the
    expert-traffic term with predicted-prefetch hits (discounted by the
    measured ``predictor_accuracy``) but charges an ℓ-deep in-flight
    span staging buffer; replicate_frac pins top-mass persistently at
    the cost of popularity targeting in the tail — the search trades
    both against r_w/r_c on the same memory budget.  Defaults keep the
    legacy search; opt in with e.g. ``predict_grid=(0, 1, 2),
    replicate_grid=(None, 0.25, 0.5)``."""
    if bench_path is not None:
        # swap the spec-sheet cpu↔gpu link for the measured H2D bandwidth
        # (benchmarks/bench_transfer.py artifact) before enumerating — the
        # whole search then optimizes against achieved, not nominal, DMA
        hw = H.with_measured_links(hw, bench_path)
    gpu_cap = hw.level("gpu").capacity
    cpu_cap = hw.level("cpu").capacity
    best: Optional[Dict] = None
    best_by_ag = {0: None, 1: None}

    for ub, mult, ag, fg in itertools.product(
            ub_grid, mult_grid, (False, True), (True, False)):
        N = ub * mult
        for rw in (ratio_grid if fg else (0.0,)):
            for rc in (ratio_grid if ag else (0.0,)):
                for mg, la, rf in itertools.product(
                        module_groups_grid if fg else (1,),
                        predict_grid if fg else (0,),
                        replicate_grid if fg else (None,)):
                    if mg > max(1, N // ub):
                        continue
                    pol = Policy(N, ub, ag, fg, rw, rc, module_groups=mg,
                                 predict_lookahead=la, replicate_frac=rf)
                    mem = memory_usage(cfg, wl, pol, dtype_bytes)
                    if mem["gpu"] > gpu_cap or mem["cpu"] > cpu_cap:
                        continue
                    est = estimate(cfg, hw, wl, pol, dtype_bytes,
                                   expert_popularity=expert_popularity,
                                   kv_paged=kv_paged,
                                   block_tokens=block_tokens,
                                   predictor_accuracy=predictor_accuracy)
                    cand = {"policy": pol, **est, "mem_gpu": mem["gpu"],
                            "mem_cpu": mem["cpu"]}
                    if best is None or cand["throughput"] > best["throughput"]:
                        best = cand
                    key = int(ag)
                    if (best_by_ag[key] is None
                            or cand["throughput"]
                            > best_by_ag[key]["throughput"]):
                        best_by_ag[key] = cand
    if best is None:
        raise RuntimeError("no feasible policy (model too large for CPU+GPU)")
    return {"best": best, "best_gpu_attn": best_by_ag[1],
            "best_cpu_attn": best_by_ag[0]}

"""Placement plans: which tensors live where (paper policy → execution).

A `PlacementPlan` realizes the policy tuple's r_w/r_c fractions as a
per-leaf assignment of weights (and the KV cache) to memory levels, and —
on backends that support it — produces shardings with an explicit
``memory_kind`` so XLA keeps offloaded tensors in host DRAM and streams
them on use.  The CPU validation backend has a single memory space; there
the plan is exercised logically (page store + engine double buffer) and
its timing modeled by core.cgopipe / core.hrm — see DESIGN.md §2.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import Policy, kv_bytes_per_token_layer, model_bytes


def backend_memory_kinds() -> List[str]:
    try:
        dev = jax.devices()[0]
        return [m.kind for m in dev.addressable_memories()]
    except Exception:
        return []


def supports_host_offload() -> bool:
    return "pinned_host" in backend_memory_kinds()


class HostOffloadFallbackWarning(UserWarning):
    """The backend has no addressable pinned_host memory space: host-tier
    stores fall back to default placement (pageable numpy / device)."""


_warned_no_pinned = False


def reset_host_probe() -> None:
    """Clear the process-wide fall-back warning latch.  The degradation
    ladder's re-promotion path calls this before re-probing, so the
    pinned→pageable fall-back is observable each time it recurs instead
    of once per process (and a recovered backend probes clean)."""
    global _warned_no_pinned
    _warned_no_pinned = False


def _make_pinned_sharding() -> jax.sharding.Sharding:
    """Single-device sharding in the pinned_host memory space (split out
    so tests can monkeypatch it with a plain CPU sharding and drive the
    pinned code paths on backends without the memory space)."""
    return jax.sharding.SingleDeviceSharding(jax.devices()[0],
                                             memory_kind="pinned_host")


def pinned_host_sharding(*, warn: bool = True, faults=None
                         ) -> Optional[jax.sharding.Sharding]:
    """Sharding for host-tier staging buffers, or None when the backend
    has no pinned_host space (one structured warning per process, until
    ``reset_host_probe``).  ``faults`` is an optional
    runtime.faults.FaultInjector: the "host_alloc" site models a failed
    pinned-host allocation (raises HostMemoryError) — the caller falls
    back to the pageable tier and may re-probe on ladder promotion."""
    global _warned_no_pinned
    if faults is not None:
        faults.raise_for("host_alloc")
    if supports_host_offload():
        return _make_pinned_sharding()
    if warn and not _warned_no_pinned:
        _warned_no_pinned = True
        warnings.warn(
            "backend %r exposes no pinned_host memory space "
            "(kinds: %s) — host-tier KV blocks and weight pages use "
            "default placement; H2D transfers will be pageable-rate"
            % (jax.default_backend(), backend_memory_kinds()),
            HostOffloadFallbackWarning, stacklevel=2)
    return None


def pinned_put(x):
    """Place an array in pinned host memory when available; otherwise
    return it unchanged (default placement, post-warning)."""
    s = pinned_host_sharding()
    if s is None:
        return x
    return jax.device_put(x, s)


def to_device(x):
    """Stage a (possibly pinned-host) array into device memory."""
    return jax.device_put(x, jax.devices()[0])


@dataclass
class PlacementPlan:
    """Per-leaf device residency for the offloaded-serving engine."""
    device_leaves: List[Tuple[str, ...]]
    host_leaves: List[Tuple[str, ...]]
    kv_on_device: bool
    w_device_bytes: float
    w_host_bytes: float

    @property
    def host_fraction(self) -> float:
        tot = self.w_device_bytes + self.w_host_bytes
        return self.w_host_bytes / tot if tot else 0.0


def _leaf_sizes(tree, prefix=()):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _leaf_sizes(tree[k], prefix + (k,))
        return out
    size = int(np.prod(tree.shape)) * np.dtype(tree.dtype).itemsize
    return [(prefix, size)]


def plan_from_policy(cfg: ModelConfig, abstract_params, pol: Policy
                     ) -> PlacementPlan:
    """Greedy knapsack: keep the hottest (non-expert first, then experts)
    leaves on device until the r_w budget is spent.  Expert weights are the
    paper's primary offload target (largest, least intensity per byte)."""
    sizes = _leaf_sizes(abstract_params)
    total = sum(s for _, s in sizes)
    budget = pol.w_gpu_ratio * total

    def priority(path):                       # lower = keep on device first
        if "moe" in path and path[-1] in ("wi", "wo"):
            return 2                           # experts offload first
        if path[0] in ("embed", "lm_head"):
            return 1
        return 0

    ordered = sorted(sizes, key=lambda e: (priority(e[0]), -e[1]))
    device, host, spent = [], [], 0.0
    for path, size in ordered:
        if spent + size <= budget:
            device.append(path)
            spent += size
        else:
            host.append(path)
    return PlacementPlan(device, host, kv_on_device=pol.kv_gpu_ratio >= 1.0,
                         w_device_bytes=spent, w_host_bytes=total - spent)


def host_sharding(mesh, spec) -> Optional[jax.sharding.NamedSharding]:
    """NamedSharding pinned to host memory when the backend supports it."""
    s = jax.sharding.NamedSharding(mesh, spec)
    if supports_host_offload():
        return s.with_memory_kind("pinned_host")
    return s

"""CGOPipe and the baseline schedules (paper §4.1, Fig. 6, Algorithm 1),
validated by an event-driven pipeline simulator.

The simulator models the four contended resources of the paper's node —
GPU, CPU, H2D link, D2H link (opposite PCIe directions are independent;
same-direction transfers serialize), plus the CPU→pinned staging copier —
and executes a task DAG with resource exclusivity.  Task durations come
from the HRM performance model, so the simulator's steady-state per-layer
latency is directly comparable with `policy.estimate`.

Schedules implemented (Fig. 6):
  cgopipe    — Algorithm 1: CPU attention, paged weights interleaved with
               hidden-state transfers, j+2 lookahead.
  s2         — FastDecode-style: CPU attention overlapped, but weights
               transferred as one block (no paging).
  s3         — FlexGen(c): CPU attention, serialized per micro-batch.
  s4         — FlexGen: GPU attention with KV-cache prefetch.
  deepspeed  — whole-weight streaming, GPU attention, single micro-batch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

RESOURCES = ("gpu", "cpu", "h2d", "d2h", "c2p")


@dataclass
class Task:
    tid: str
    resource: str
    duration: float
    deps: Tuple[str, ...] = ()


@dataclass
class ScheduleResult:
    makespan: float
    busy: Dict[str, float]
    starts: Dict[str, float]
    ends: Dict[str, float]

    def utilization(self, resource: str) -> float:
        return self.busy.get(resource, 0.0) / self.makespan if self.makespan else 0.0

    def bubble_fraction(self, resource: str) -> float:
        return 1.0 - self.utilization(resource)


def simulate(tasks: List[Task]) -> ScheduleResult:
    """List scheduler: tasks are issued in list order per resource (the
    launch order of Algorithm 1); a task starts when its resource is free
    AND its deps have finished."""
    res_free = {r: 0.0 for r in RESOURCES}
    busy = {r: 0.0 for r in RESOURCES}
    ends: Dict[str, float] = {}
    starts: Dict[str, float] = {}
    pending = list(tasks)
    # iterate until all scheduled; list order = priority within a resource
    progressed = True
    while pending and progressed:
        progressed = False
        for t in list(pending):
            if all(d in ends for d in t.deps):
                start = max(res_free[t.resource],
                            max((ends[d] for d in t.deps), default=0.0))
                ends[t.tid] = start + t.duration
                starts[t.tid] = start
                res_free[t.resource] = ends[t.tid]
                busy[t.resource] += t.duration
                pending.remove(t)
                progressed = True
    if pending:
        raise ValueError(f"cyclic or dangling deps: {[t.tid for t in pending]}")
    makespan = max(ends.values(), default=0.0)
    return ScheduleResult(makespan, busy, starts, ends)


# ---------------------------------------------------------------------------
# Task-time model for one decode step
# ---------------------------------------------------------------------------

@dataclass
class StepTimes:
    """Durations (seconds) of the primitive tasks for ONE micro-batch at
    ONE layer.  Built by `times_from_policy`."""
    preattn: float           # GPU: LN + QKV projection (μ tokens)
    postattn: float          # GPU: O proj + (MoE) FFN (μ tokens)
    cpuattn: float           # CPU: softmax(QK)V against CPU-resident KV
    gpuattn: float           # GPU: attention if A_g=1 (compute only)
    offqkv: float            # D2H: QKV for one micro-batch
    loadh: float             # H2D: hidden states for one micro-batch
    kvload: float            # H2D: KV cache for one micro-batch (S4)
    wpage: float             # H2D: ONE page of the next layer's weights
    wfull: float             # H2D: next layer's full streamed weights
    wstage: float            # CPU→pinned staging copy of one page
    n_ubs: int = 4


def times_from_policy(cfg, hw, wl, pol, dtype_bytes: int = 2) -> StepTimes:
    from repro.core import hrm as H
    gpu, cpu = hw.level("gpu"), hw.level("cpu")
    b_cg = hw.link_bw("cpu", "gpu")
    lw = H.LayerWorkload.decode(cfg, pol.ubatch, wl.avg_ctx, dtype_bytes)
    lw_full = H.LayerWorkload.decode(cfg, pol.batch, wl.avg_ctx, dtype_bytes)
    n_ubs = pol.num_ubs
    w_stream = (1 - pol.w_gpu_ratio) * lw_full.bytes_w
    hidden = pol.ubatch * cfg.d_model * dtype_bytes
    qkv = hidden * 3
    return StepTimes(
        preattn=(lw.flops_proj * 0.75) / gpu.p_peak,
        postattn=max((lw.flops_ffn + lw.flops_proj * 0.25) / gpu.p_peak,
                     lw_full.bytes_w / n_ubs / gpu.b_peak),
        cpuattn=max(lw.flops_attn / cpu.p_peak, lw.bytes_kv / cpu.b_peak),
        gpuattn=lw.flops_attn / gpu.p_peak,
        offqkv=qkv / b_cg,
        loadh=hidden / b_cg,
        kvload=lw.bytes_kv * (1 - pol.kv_gpu_ratio) / b_cg,
        wpage=w_stream / n_ubs / b_cg,
        wfull=w_stream / b_cg,
        wstage=w_stream / n_ubs / cpu.b_peak,
        n_ubs=n_ubs,
    )


# ---------------------------------------------------------------------------
# Schedule builders.  All build `n_layers` of steady-state decode.
# ---------------------------------------------------------------------------

def build_cgopipe(t: StepTimes, n_layers: int) -> List[Task]:
    """Algorithm 1 with the j+2 lookahead and paged weights."""
    tasks: List[Task] = []
    J = t.n_ubs

    def seq(i, j):           # global micro-batch sequence index
        return i * J + j

    # prologue (layer 0, first two micro-batches through the CPU side)
    for j in range(min(2, J)):
        tasks += [
            Task(f"pre_{0}_{j}", "gpu", t.preattn),
            Task(f"off_{0}_{j}", "d2h", t.offqkv, (f"pre_{0}_{j}",)),
            Task(f"cpu_{0}_{j}", "cpu", t.cpuattn, (f"off_{0}_{j}",)),
            Task(f"stage_{1}_{j}", "c2p", t.wstage),
        ]
    for i in range(n_layers):
        for j in range(J):
            deps_post = [f"load_{i}_{j}"]
            if i > 0:        # all pages of layer i must have arrived
                deps_post += [f"wpg_{i}_{k}" for k in range(J)]
            tasks += [
                Task(f"load_{i}_{j}", "h2d", t.loadh, (f"cpu_{i}_{j}",)),
                Task(f"wpg_{i + 1}_{j}", "h2d", t.wpage,
                     (f"stage_{i + 1}_{j}",)),
                Task(f"post_{i}_{j}", "gpu", t.postattn, tuple(deps_post)),
            ]
            # two micro-batches ahead (wraps into the next layer)
            a = seq(i, j) + 2
            ai, aj = a // J, a % J
            if ai < n_layers:
                dep_pre = (f"post_{ai - 1}_{aj}",) if ai > 0 else ()
                tasks += [
                    Task(f"pre_{ai}_{aj}", "gpu", t.preattn, dep_pre),
                    Task(f"off_{ai}_{aj}", "d2h", t.offqkv,
                         (f"pre_{ai}_{aj}",)),
                    Task(f"cpu_{ai}_{aj}", "cpu", t.cpuattn,
                         (f"off_{ai}_{aj}",)),
                ]
                if ai + 1 <= n_layers:
                    tasks.append(Task(f"stage_{ai + 1}_{aj}", "c2p", t.wstage))
    return tasks


def build_s2(t: StepTimes, n_layers: int) -> List[Task]:
    """CPU attention overlapped, but un-paged weight transfer: the whole
    next-layer block occupies H2D before hidden states can return."""
    tasks: List[Task] = []
    J = t.n_ubs
    for j in range(J):
        tasks += [Task(f"pre_{0}_{j}", "gpu", t.preattn),
                  Task(f"off_{0}_{j}", "d2h", t.offqkv, (f"pre_{0}_{j}",)),
                  Task(f"cpu_{0}_{j}", "cpu", t.cpuattn, (f"off_{0}_{j}",))]
    for i in range(n_layers):
        tasks.append(Task(f"wfull_{i + 1}", "h2d", t.wfull))
        for j in range(J):
            deps_post = [f"load_{i}_{j}"]
            if i > 0:
                deps_post.append(f"wfull_{i}")
            tasks += [
                Task(f"load_{i}_{j}", "h2d", t.loadh, (f"cpu_{i}_{j}",)),
                Task(f"post_{i}_{j}", "gpu", t.postattn, tuple(deps_post)),
            ]
            if i + 1 < n_layers:
                tasks += [
                    Task(f"pre_{i + 1}_{j}", "gpu", t.preattn,
                         (f"post_{i}_{j}",)),
                    Task(f"off_{i + 1}_{j}", "d2h", t.offqkv,
                         (f"pre_{i + 1}_{j}",)),
                    Task(f"cpu_{i + 1}_{j}", "cpu", t.cpuattn,
                         (f"off_{i + 1}_{j}",)),
                ]
    return tasks


def build_s3(t: StepTimes, n_layers: int) -> List[Task]:
    """FlexGen(c): CPU attention with NO lookahead — pre/off/cpu/load/post
    serialize per micro-batch (the paper's least-optimized schedule)."""
    tasks: List[Task] = []
    J = t.n_ubs
    prev = None
    for i in range(n_layers):
        tasks.append(Task(f"wfull_{i + 1}", "h2d", t.wfull))
        for j in range(J):
            deps = [prev] if prev else []
            if i > 0:
                deps.append(f"wfull_{i}")
            tasks += [
                Task(f"pre_{i}_{j}", "gpu", t.preattn, tuple(deps)),
                Task(f"off_{i}_{j}", "d2h", t.offqkv, (f"pre_{i}_{j}",)),
                Task(f"cpu_{i}_{j}", "cpu", t.cpuattn, (f"off_{i}_{j}",)),
                Task(f"load_{i}_{j}", "h2d", t.loadh, (f"cpu_{i}_{j}",)),
                Task(f"post_{i}_{j}", "gpu", t.postattn, (f"load_{i}_{j}",)),
            ]
            prev = f"post_{i}_{j}"
    return tasks


def build_s4(t: StepTimes, n_layers: int) -> List[Task]:
    """FlexGen: GPU attention, KV prefetched one micro-batch ahead,
    whole-block weight transfer."""
    tasks: List[Task] = []
    J = t.n_ubs
    tasks.append(Task("kv_0_0", "h2d", t.kvload))
    for i in range(n_layers):
        tasks.append(Task(f"wfull_{i + 1}", "h2d", t.wfull))
        for j in range(J):
            deps = [f"kv_{i}_{j}"]
            if i > 0:
                deps.append(f"wfull_{i}")
            if i or j:
                prev = (i, j - 1) if j else (i - 1, J - 1)
                deps.append(f"comp_{prev[0]}_{prev[1]}")
            tasks.append(Task(f"comp_{i}_{j}", "gpu",
                              t.preattn + t.gpuattn + t.postattn, tuple(deps)))
            nxt = (i, j + 1) if j + 1 < J else (i + 1, 0)
            if nxt[0] < n_layers:
                tasks.append(Task(f"kv_{nxt[0]}_{nxt[1]}", "h2d", t.kvload))
    return tasks


def build_deepspeed(t: StepTimes, n_layers: int) -> List[Task]:
    """ZeRO-Inference-style: stream whole weights per layer, GPU attention,
    one (big) micro-batch, no prefetch beyond the weight stream."""
    tasks: List[Task] = []
    for i in range(n_layers):
        deps = [f"w_{i}"]
        if i:
            deps.append(f"comp_{i - 1}")
        tasks.append(Task(f"w_{i}", "h2d", t.wfull * 1.0))
        tasks.append(Task(
            f"comp_{i}", "gpu",
            (t.preattn + t.gpuattn + t.postattn + t.kvload * 0) * t.n_ubs,
            tuple(deps)))
    return tasks


BUILDERS = {"cgopipe": build_cgopipe, "s2": build_s2, "s3": build_s3,
            "s4": build_s4, "deepspeed": build_deepspeed}


def run_schedule(name: str, t: StepTimes, n_layers: int = 8) -> ScheduleResult:
    return simulate(BUILDERS[name](t, n_layers))


def per_layer_latency(name: str, t: StepTimes, n_layers: int = 16) -> float:
    """Steady-state per-layer latency (subtracting pipeline fill)."""
    a = run_schedule(name, t, n_layers)
    b = run_schedule(name, t, n_layers // 2)
    return (a.makespan - b.makespan) / (n_layers - n_layers // 2)

import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture × input shape × mesh) cell:
  * build the sharding plan (distributed.sharding.make_plan),
  * jit the step function with explicit in/out shardings,
  * ``.lower().compile()`` — success proves the distribution config is
    coherent (sharding divisibility, collective legality, SPMD partitioning),
  * record ``memory_analysis()`` (fits-per-chip evidence),
    ``cost_analysis()`` FLOPs/bytes and the parsed collective bytes
    (§Roofline terms) into experiments/dryrun/<cell>.json.

The XLA_FLAGS line above MUST run before any other import so the CPU
platform materializes 512 placeholder devices.  Smoke tests and benches do
NOT import this module — they see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, SHAPES, get_config, get_shape, \
    shape_applicable
from repro.core import roofline
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import kvcache
from repro.models.inputs import input_specs
from repro.models.model import ExecPolicy
from repro.models.params import abstract_params
from repro.serving.steps import make_prefill_step, make_serve_step
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               plan_overrides=None):
    """Build + lower + compile one cell. Returns (compiled, report, plan).

    plan_overrides: kwargs for sharding.make_plan, plus the step-level
    knobs 'num_micro' (gradient-accumulation micro-batches for train) and
    'loss_chunk'."""
    overrides = dict(plan_overrides or {})
    num_micro = overrides.pop("num_micro", 1)
    cfg = get_config(arch)
    # any ModelConfig field may be overridden (expert_dtype,
    # capacity_factor, ssm_chunk, ...); the rest go to make_plan
    import dataclasses
    cfg_kw = {k: overrides.pop(k) for k in list(overrides)
              if k in cfg.__dataclass_fields__}
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    shape = get_shape(shape_name)
    plan = SH.make_plan(cfg, shape, mesh, **overrides)
    params_abs = abstract_params(cfg)
    p_shard = _named(mesh, plan.param_specs)
    specs = input_specs(cfg, shape)
    if True:
        if shape.mode == "train":
            opt = OptConfig(moment_dtype="bfloat16" if
                            cfg.family in ("moe", "hybrid") else "float32")
            if num_micro > 1:
                from repro.training.train_step import \
                    make_microbatched_train_step
                step = make_microbatched_train_step(cfg, opt, plan.policy,
                                                    num_micro)
            else:
                step = make_train_step(cfg, opt, plan.policy)
            opt_abs = jax.eval_shape(lambda p: init_opt_state(p, opt),
                                     params_abs)
            o_shard = {"mu": p_shard, "nu": p_shard,
                       "step": jax.sharding.NamedSharding(
                           mesh, jax.sharding.PartitionSpec())}
            b_spec = SH.batch_specs(specs, plan.dp_axes)
            b_shard = _named(mesh, b_spec)
            jf = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            lowered = jf.lower(params_abs, opt_abs, specs)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, plan.policy)

            def step2(params, batch):
                extras = {k: v for k, v in batch.items() if k != "tokens"}
                return step(params, batch["tokens"], **extras)

            b_shard = _named(mesh, SH.batch_specs(specs, plan.dp_axes))
            jf = jax.jit(step2, in_shardings=(p_shard, b_shard),
                         out_shardings=None)
            lowered = jf.lower(params_abs, specs)
        else:  # decode
            step = make_serve_step(cfg, plan.policy)
            cache_abs = specs["cache"]
            c_spec = SH.cache_specs(cfg, cache_abs, plan.dp_axes,
                                    plan.kv_axes, plan.rules, mesh)
            c_shard = _named(mesh, c_spec)
            dpa = plan.dp_axes if plan.dp_axes else None
            t_shard = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(dpa, None))
            jf = jax.jit(step,
                         in_shardings=(p_shard, c_shard, t_shard),
                         out_shardings=(None, None, c_shard),
                         donate_argnums=(1,))
            lowered = jf.lower(params_abs, cache_abs, specs["tokens"])
        compiled = lowered.compile()

    from repro.core.census import census as make_census
    cens = make_census(cfg, shape, dict(mesh.shape), plan)
    rep = roofline.analyze(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=mesh.size, census=cens,
        model_flops=roofline.model_flops_for(cfg, shape))
    return compiled, rep, plan


def run_cell(arch, shape_name, mesh, mesh_name, out_dir=OUT_DIR,
             plan_overrides=None, tag=""):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{cell}.json"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] SKIP {cell}: {reason}", flush=True)
        return rec
    t0 = time.time()
    try:
        compiled, rep, plan = lower_cell(arch, shape_name, mesh, mesh_name,
                                         plan_overrides)
        # trip-scaled HLO collective cross-check: compile again with the
        # layer scan partially unrolled (u=2); per-kind bytes extrapolate as
        # nonscan + P*(c2 - c1) since the scan body is counted once per
        # unrolled copy (see core/census.py docstring).
        hlo_coll_scaled = {}
        try:
            ov = dict(plan_overrides or {})
            ov["scan_unroll"] = 2
            compiled2, rep2, _ = lower_cell(arch, shape_name, mesh,
                                            mesh_name, ov)
            P = cfg.num_periods
            from repro.core.roofline import parse_collectives
            raw1 = parse_collectives(compiled.as_text()).bytes_by_kind
            raw2 = parse_collectives(compiled2.as_text()).bytes_by_kind
            for kind in set(raw1) | set(raw2):
                a, b = raw1.get(kind, 0.0), raw2.get(kind, 0.0)
                body = max(b - a, 0.0)
                hlo_coll_scaled[kind] = max(a - body, 0.0) + P * body
        except Exception as e:  # cross-check is best-effort
            hlo_coll_scaled = {"error": str(e)[:200]}
        rec = {"status": "ok", "compile_s": round(time.time() - t0, 1),
               "hlo_collectives_scaled": hlo_coll_scaled,
               "plan": {"rules": {k: str(v) for k, v in plan.rules.items()},
                        "dp_axes": plan.dp_axes, "kv_axes": plan.kv_axes,
                        "expert_axes": plan.expert_axes,
                        "moe_variant": plan.moe_variant},
               **rep.to_dict()}
        mem = rep.memory_per_chip
        print(f"[dryrun] OK   {cell}  t={rec['compile_s']}s "
              f"dom={rep.dominant} "
              f"comp={rep.t_compute*1e3:.2f}ms mem={rep.t_memory*1e3:.2f}ms "
              f"coll={rep.t_collective*1e3:.2f}ms "
              f"arg={mem['argument']/1e9:.2f}GB tmp={mem['temp']/1e9:.2f}GB",
              flush=True)
    except Exception as e:  # noqa
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        print(f"[dryrun] FAIL {cell}: {type(e).__name__}: {e}", flush=True)
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--debug", action="store_true",
                    help="small meshes on REPRO_DRYRUN_DEVICES=8 fake devices")
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = []
    if args.debug:
        from repro.launch.mesh import make_debug_mesh
        if args.mesh in ("single", "both"):
            meshes.append(("debug_2x4", make_debug_mesh(model=4, data=2)))
        if args.mesh in ("multi", "both"):
            meshes.append(("debug_2x2x2", make_debug_mesh(model=2, data=2,
                                                          pod=2)))
    else:
        if args.mesh in ("single", "both"):
            meshes.append(("single_pod_16x16", make_production_mesh()))
        if args.mesh in ("multi", "both"):
            meshes.append(("multi_pod_2x16x16",
                           make_production_mesh(multi_pod=True)))

    archs = ALL_ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    n_ok = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh, mesh_name, out_dir)
                if rec["status"] == "error":
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"[dryrun] done: {n_ok} ok/skip, {n_fail} failed", flush=True)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

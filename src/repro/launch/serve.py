"""Serving launcher: offloading-aware batch inference (the paper's
workload).  Generates HRM policy advice for the requested hardware, then
runs the engine on synthetic requests and reports generation throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
      --requests 16 --hw l4 [--paged]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--hw", default="l4",
                    help="HRM hardware preset for policy advice")
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--ubatch", type=int, default=4)
    ap.add_argument("--num-ubs", type=int, default=2)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.core import hrm, policy as pol
    from repro.models.params import init_params
    from repro.serving.engine import Engine, EngineConfig

    cfg_full = get_config(args.arch)
    # HRM policy advice is computed for the FULL model on the target hw
    hw = hrm.preset(args.hw)
    wl = pol.Workload(prompt_len=args.prompt_len, gen_len=args.gen_len)
    try:
        advice = pol.search(cfg_full, hw, wl)["best"]
        print("[serve] HRM policy advice for", args.hw, ":",
              advice["policy"], f"est {advice['throughput']:.1f} tok/s")
    except RuntimeError as e:
        print("[serve] HRM policy:", e)

    cfg = cfg_full.smoke() if args.smoke else cfg_full
    params = init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(
        ubatch=args.ubatch, num_ubs=args.num_ubs,
        max_seq=args.prompt_len + args.gen_len + 8, paged=args.paged))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        n = int(rng.integers(4, args.prompt_len + 1))
        eng.submit(rng.integers(2, cfg.vocab_size, n), args.gen_len)
    t0 = time.time()
    out = eng.run_until_idle()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(json.dumps({"requests": len(out), "tokens": total,
                      "seconds": round(dt, 2),
                      "tok_per_s": round(total / dt, 2),
                      "paged": args.paged}))


if __name__ == "__main__":
    main()

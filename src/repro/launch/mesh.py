"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  Single pod:
16×16 = 256 chips ("data", "model").  Multi-pod: 2×16×16 = 512 chips
("pod", "data", "model") — the "pod" axis is pure data parallelism over
DCN and scales to N pods without code changes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(model: int = 2, data: int = 2, pod: int = 0
                    ) -> jax.sharding.Mesh:
    """Small mesh over however many (possibly fake) devices exist — used by
    multi-device unit tests run with XLA_FLAGS host-device overrides."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))

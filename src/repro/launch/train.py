"""Training launcher.

Local (CPU) smoke:  PYTHONPATH=src python -m repro.launch.train \
    --arch qwen2.5-3b --smoke --steps 20
Production lowering check: add --dryrun (uses the production mesh via
repro.launch.dryrun instead — kept separate so THIS module never forces
the 512-device platform flag).

On a real multi-host TPU deployment this entry point is what every host
runs (jax.distributed.initialize is called when the standard TPU env vars
are present); the Trainer handles restart-from-checkpoint, so preemption
recovery is: just re-run the same command.
"""
from __future__ import annotations

import argparse
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--num-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # multi-host init when launched under a TPU scheduler
    if "TPU_WORKER_ID" in os.environ or "MEGASCALE_COORDINATOR_ADDRESS" in os.environ:
        import jax
        jax.distributed.initialize()

    from repro.configs import get_config
    from repro.training.trainer import Trainer, TrainConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainConfig(steps=args.steps, batch_size=args.batch_size,
                       seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                       num_micro=args.num_micro, seed=args.seed)
    trainer = Trainer(cfg, tcfg)
    metrics = trainer.run()
    print(json.dumps({"final": metrics, "log": trainer.metrics_log[-5:]},
                     indent=1))


if __name__ == "__main__":
    main()

from repro.models.model import ExecPolicy, forward, unembed  # noqa: F401

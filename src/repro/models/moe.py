"""Mixture-of-Experts FFN.

Execution paths (numerically equivalent up to capacity drops):

  * ``moe_dense``   — masked loop over experts; O(E) compute waste; the
    reference/oracle path for unit tests and tiny smoke configs.
  * ``moe_grouped`` — single-shard capacity-bucketed grouped matmul
    (scatter tokens to (E, C, D) buckets, einsum, gather back).  This is
    the compute the Pallas ``moe_ffn`` kernel accelerates.
  * ``moe_ep_psum_local``  — expert parallelism, tokens *replicated* over
    the expert mesh axes; each shard computes its experts' contribution
    and the outputs are combined with a psum.  Robust for decode (few
    tokens per row).  Collective bytes: T*D per psum hop.
  * ``moe_ep_a2a_local``   — expert parallelism, tokens *sharded* over the
    expert axes; routed tokens are exchanged with ``lax.all_to_all``
    (capacity-bucketed), grouped-matmul'ed on the owning shard, and
    returned.  Collective bytes: ~2*T*K/M*D — the activation analogue of
    the paper's D2/D3 transfers: weights stay resident, activations move.

Gate/up projections are stored as (D, 2, F) so that sharding the 'ffn'
axis keeps the two halves aligned on every shard.

Routing follows the config: softmax top-k (mixtral/jamba/moonshot) or
sigmoid scoring with top-k renormalization (deepseek-v3 ``router_scale``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import act_fn


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------

def route(cfg: ModelConfig, router_w, x) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, D) -> (weights (T,k) f32, idx (T,k) i32, aux_loss scalar)."""
    scores = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if cfg.router_scale:                       # deepseek: sigmoid + renorm
        probs = jax.nn.sigmoid(scores)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
    # Switch-style load-balance loss over softmax probabilities
    sm = jax.nn.softmax(scores, axis=-1)
    T = x.shape[0]
    frac = jnp.zeros((cfg.num_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * cfg.top_k))
    aux = cfg.num_experts * jnp.sum(frac * jnp.mean(sm, axis=0))
    return w, idx.astype(jnp.int32), aux


def expert_weights(p: Dict, dtype):
    """Dequantize int8 experts (weight-only quant, per-expert scale) to
    the compute dtype; pass-through otherwise.  On TPU the Pallas kernel
    dequantizes tile-wise in VMEM instead (ops.moe_ffn scales args)."""
    wi, wo = p["wi"], p["wo"]
    if "wi_scale" in p:
        wi = wi.astype(dtype) * p["wi_scale"].astype(dtype)[:, None, None, None]
        wo = wo.astype(dtype) * p["wo_scale"].astype(dtype)[:, None, None]
    return wi, wo


def gated_ffn(cfg: ModelConfig, wi, wo, x):
    """x: (..., D); wi: (D, 2, F); wo: (F, D)."""
    h = jnp.einsum("...d,dgf->...gf", x, wi.astype(x.dtype))
    y = act_fn(cfg.ffn_act)(h[..., 0, :]) * h[..., 1, :]
    return jnp.einsum("...f,fd->...d", y, wo.astype(x.dtype))


def gated_ffn_partial_in(cfg, wi, wo, x):
    """Same as gated_ffn but wi/wo hold only an F-shard; the caller must
    psum the result over the sharded axis."""
    return gated_ffn(cfg, wi, wo, x)


# ---------------------------------------------------------------------------
# Capacity bucketing
# ---------------------------------------------------------------------------

def _bucket(dest, n_buckets: int, cap: int):
    """dest: (N,) int32 in [0, n_buckets) or -1. Returns (slot (N,), keep (N,)):
    rank of each entry within its bucket; keep = slot < cap and dest >= 0."""
    onehot = (dest[:, None] == jnp.arange(n_buckets)[None, :])
    rank = jnp.cumsum(onehot, axis=0) - 1                        # (N, nb)
    slot = jnp.sum(jnp.where(onehot, rank, 0), axis=1)
    keep = (dest >= 0) & (slot < cap)
    return slot.astype(jnp.int32), keep


def stage_bucket(dest, n_buckets: int, cap: int, groups: int = 1):
    """Cross-group routed-token staging map (module-based batching).

    dest: (N,) int32 bucket ids in [0, n_buckets) or -1, laid out
    group-major: rotation group g owns the flat positions
    [g·N/groups, (g+1)·N/groups).  Ranking runs per *(group, bucket)*
    composite bucket with per-group capacity ``cap``, so each group's
    keep/drop decisions are exactly what ``_bucket(dest_g, n_buckets,
    cap)`` would produce on that group's slice alone — the lockstep
    path's drops, reproduced inside one combined dispatch.  The staged
    slot is ``g·cap + rank``: groups occupy disjoint spans of the
    (n_buckets, groups·cap) staging buffer, so tokens of different
    groups can never mix in one bucket row (conservation is checked by
    ``stage_conservation_ok`` / the property suite).

    groups=1 degenerates to ``_bucket`` exactly."""
    N = dest.shape[0]
    assert N % groups == 0, "flat entries must split evenly over groups"
    per_g = N // groups
    g = (jnp.arange(N) // per_g).astype(jnp.int32)
    gb = jnp.where(dest >= 0, g * n_buckets + dest, -1)
    rank, keep = _bucket(gb, groups * n_buckets, cap)
    return (g * cap + rank).astype(jnp.int32), keep


def stage_conservation_ok(dest, slot, keep, n_buckets: int, cap: int,
                          groups: int = 1) -> bool:
    """Host-side invariant check for a staging index map: every kept
    entry occupies a unique staged slot inside its own group's span, and
    the kept count per (group, bucket) is exactly min(bucket size, cap)
    — i.e. tokens are conserved up to the per-group capacity drops and
    never cross group boundaries."""
    import numpy as np
    dest = np.asarray(dest)
    slot = np.asarray(slot)
    keep = np.asarray(keep, bool)
    N = dest.shape[0]
    if N % groups:
        return False
    per_g = N // groups
    g = np.arange(N) // per_g
    if keep[dest < 0].any():
        return False
    # kept slots live in their own group's span and are unique per bucket
    if not ((slot[keep] >= g[keep] * cap)
            & (slot[keep] < (g[keep] + 1) * cap)).all():
        return False
    pairs = set(zip(dest[keep].tolist(), slot[keep].tolist()))
    if len(pairs) != int(keep.sum()):
        return False
    # conservation: per (group, bucket), kept == min(routed, cap)
    for gg in range(groups):
        sl = slice(gg * per_g, (gg + 1) * per_g)
        for b in range(n_buckets):
            routed = int((dest[sl] == b).sum())
            kept = int(((dest[sl] == b) & keep[sl]).sum())
            if kept != min(routed, cap):
                return False
    return True


def grouped_ffn(cfg: ModelConfig, wi, wo, xbuf, use_kernel: bool = False,
                wi_scale=None, wo_scale=None):
    """xbuf: (E, C, D); wi: (E, D, 2, F); wo: (E, F, D) -> (E, C, D).
    int8 wi/wo + per-expert scales: the kernel path fuses the dequant into
    its tile loop; the jnp path dequantizes inline."""
    if use_kernel:
        from repro.kernels import ops
        return ops.moe_ffn(xbuf, wi, wo, wi_scale, wo_scale, act=cfg.ffn_act)
    if wi_scale is not None:
        wi = wi.astype(xbuf.dtype) * wi_scale[:, None, None, None].astype(xbuf.dtype)
        wo = wo.astype(xbuf.dtype) * wo_scale[:, None, None].astype(xbuf.dtype)
    h = jnp.einsum("ecd,edgf->ecgf", xbuf, wi.astype(xbuf.dtype))
    y = act_fn(cfg.ffn_act)(h[..., 0, :]) * h[..., 1, :]
    return jnp.einsum("ecf,efd->ecd", y, wo.astype(xbuf.dtype))


# ---------------------------------------------------------------------------
# Dense (oracle) path
# ---------------------------------------------------------------------------

def moe_dense(cfg: ModelConfig, p: Dict, x) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D). Returns (out (T,D), aux_loss)."""
    w, idx, aux = route(cfg, p["router"], x)
    wi_all, wo_all = expert_weights(p, x.dtype)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(cfg.num_experts):
        y = gated_ffn(cfg, wi_all[e], wo_all[e], x)
        we = jnp.sum(jnp.where(idx == e, w, 0.0), axis=-1)      # (T,)
        out = out + y.astype(jnp.float32) * we[:, None]
    out = out.astype(x.dtype)
    if cfg.num_shared_experts:
        out = out + gated_ffn(cfg, p["shared"]["wi"], p["shared"]["wo"], x)
    return out, aux


# ---------------------------------------------------------------------------
# Single-shard grouped path
# ---------------------------------------------------------------------------

def moe_grouped(cfg: ModelConfig, p: Dict, x, *, capacity_factor=None,
                use_kernel: bool = False,
                token_groups: Optional[int] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """token_groups: module-based batching — x concatenates that many
    rotation groups' tokens (group-major).  Capacity and keep/drop
    decisions are then computed per group (``stage_bucket``), so every
    group's output is bit-identical to running it alone, while the
    expert GEMM executes once over the whole staged buffer."""
    T, D = x.shape
    NE, K = cfg.num_experts, cfg.top_k
    G = token_groups or 1
    cf = capacity_factor or cfg.capacity_factor
    cap = max(1, int((T // G) * K * cf / NE + 0.999))

    w, idx, aux = route(cfg, p["router"], x)
    flat_e = idx.reshape(-1)                                     # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = w.reshape(-1)
    slot, keep = stage_bucket(flat_e, NE, cap, G)
    e_safe = jnp.where(keep, flat_e, 0)
    s_safe = jnp.where(keep, slot, G * cap - 1)

    xbuf = jnp.zeros((NE, G * cap, D), x.dtype)
    xbuf = xbuf.at[e_safe, s_safe].add(
        jnp.where(keep[:, None], x[flat_t], 0).astype(x.dtype))
    ybuf = grouped_ffn(cfg, p["wi"], p["wo"], xbuf, use_kernel,
                       p.get("wi_scale"), p.get("wo_scale"))
    y = ybuf[e_safe, s_safe]                                     # (T*K, D)
    y = jnp.where(keep[:, None], y, 0) * flat_w[:, None].astype(x.dtype)
    out = jnp.zeros_like(x).at[flat_t].add(y)
    if cfg.num_shared_experts:
        out = out + gated_ffn(cfg, p["shared"]["wi"], p["shared"]["wo"], x)
    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel bodies (to be wrapped in shard_map by distributed.sharding)
# ---------------------------------------------------------------------------

def _axis_size(name) -> int:
    """Static mesh-axis size inside shard_map: jax.lax.axis_size on new
    jax; jax.core.axis_frame(name) (which returns the size) on 0.4.x."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    from jax import core as _core
    return _core.axis_frame(name)


def _combined_axis_index(axis_names):
    idx = jnp.int32(0)
    for a in axis_names:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def _combined_axis_size(axis_names):
    m = 1
    for a in axis_names:
        m *= _axis_size(a)
    return m


def moe_ep_psum_local(cfg: ModelConfig, p_local: Dict, x, *, expert_axes,
                      capacity_factor=None, use_kernel: bool = False,
                      shared_sharded: bool = False, ffn_axes=()):
    """Tokens replicated over expert_axes (+ffn_axes); p_local holds the
    local expert slice wi (E_loc, D, 2, F_loc), wo (E_loc, F_loc, D);
    router replicated.  With ffn_axes set, each expert's FFN dim is also
    sharded (2D stationary weights) and the output psum covers both axis
    groups — decode then moves only (T, D)-sized activations while every
    weight stays resident on its shard.  x: (T, D)."""
    T, D = x.shape
    NE, K = cfg.num_experts, cfg.top_k
    M = _combined_axis_size(expert_axes)
    E_loc = NE // M
    my = _combined_axis_index(expert_axes)
    cf = capacity_factor or cfg.capacity_factor
    cap_e = max(1, int(T * K * cf / NE + 0.999))

    w, idx, aux = route(cfg, p_local["router"], x)
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = w.reshape(-1)
    local_e = flat_e - my * E_loc
    mine = (local_e >= 0) & (local_e < E_loc)
    dest = jnp.where(mine, local_e, -1)
    slot, keep = _bucket(dest, E_loc, cap_e)
    e_safe = jnp.where(keep, dest, 0)
    s_safe = jnp.where(keep, slot, cap_e - 1)

    xbuf = jnp.zeros((E_loc, cap_e, D), x.dtype).at[e_safe, s_safe].add(
        jnp.where(keep[:, None], x[flat_t], 0).astype(x.dtype))
    ybuf = grouped_ffn(cfg, p_local["wi"], p_local["wo"], xbuf, use_kernel,
                       p_local.get("wi_scale"), p_local.get("wo_scale"))
    y = jnp.where(keep[:, None], ybuf[e_safe, s_safe], 0)
    y = y * flat_w[:, None].astype(x.dtype)
    out = jnp.zeros_like(x).at[flat_t].add(y)
    reduce_axes = tuple(expert_axes) + tuple(ffn_axes)
    Mr = _combined_axis_size(reduce_axes)
    if cfg.num_shared_experts:
        sh = gated_ffn(cfg, p_local["shared"]["wi"], p_local["shared"]["wo"], x)
        if shared_sharded or ffn_axes:
            # partial-F contribution folds into the psum, but it is
            # replicated across expert_axes — pre-divide by that factor
            out = out + sh / (M if ffn_axes else 1)
        else:
            out = out + sh / Mr                   # fully replicated
    out = jax.lax.psum(out, reduce_axes)
    return out, aux


def moe_ep_a2a_local(cfg: ModelConfig, p_local: Dict, x, *, expert_axes,
                     capacity_factor=None, use_kernel: bool = False,
                     shared_sharded: bool = False):
    """Tokens *sharded* over expert_axes (x is the local token slice).
    Exchanges routed tokens via all_to_all.  x: (T_loc, D)."""
    T, D = x.shape
    NE, K = cfg.num_experts, cfg.top_k
    M = _combined_axis_size(expert_axes)
    E_loc = NE // M
    cf = capacity_factor or cfg.capacity_factor
    cap = max(1, int(T * K * cf / M + 0.999))            # per src->dst lane
    cap_e = max(1, int(M * cap * cf / E_loc + 0.999))    # per local expert

    w, idx, aux = route(cfg, p_local["router"], x)
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = w.reshape(-1)
    dest = flat_e // E_loc
    slot, keep = _bucket(dest, M, cap)
    d_safe = jnp.where(keep, dest, 0)
    s_safe = jnp.where(keep, slot, cap - 1)

    send_x = jnp.zeros((M, cap, D), x.dtype).at[d_safe, s_safe].add(
        jnp.where(keep[:, None], x[flat_t], 0).astype(x.dtype))
    send_le = jnp.full((M, cap), -1, jnp.int32).at[d_safe, s_safe].max(
        jnp.where(keep, (flat_e % E_loc).astype(jnp.int32), -1))

    recv_x = jax.lax.all_to_all(send_x, expert_axes, 0, 0, tiled=True)
    recv_le = jax.lax.all_to_all(send_le, expert_axes, 0, 0, tiled=True)

    rx = recv_x.reshape(M * cap, D)
    rle = recv_le.reshape(M * cap)
    slot2, keep2 = _bucket(rle, E_loc, cap_e)
    e2 = jnp.where(keep2, rle, 0)
    s2 = jnp.where(keep2, slot2, cap_e - 1)
    xbuf = jnp.zeros((E_loc, cap_e, D), x.dtype).at[e2, s2].add(
        jnp.where(keep2[:, None], rx, 0))
    ybuf = grouped_ffn(cfg, p_local["wi"], p_local["wo"], xbuf, use_kernel,
                       p_local.get("wi_scale"), p_local.get("wo_scale"))
    ry = jnp.zeros((M * cap, D), x.dtype).at[jnp.arange(M * cap)].set(
        jnp.where(keep2[:, None], ybuf[e2, s2], 0)).reshape(M, cap, D)

    back = jax.lax.all_to_all(ry, expert_axes, 0, 0, tiled=True)
    y = back[d_safe, s_safe]
    y = jnp.where(keep[:, None], y, 0) * flat_w[:, None].astype(x.dtype)
    out = jnp.zeros_like(x).at[flat_t].add(y)
    if cfg.num_shared_experts:
        sh = gated_ffn(cfg, p_local["shared"]["wi"], p_local["shared"]["wo"], x)
        if shared_sharded:
            sh = jax.lax.psum(sh, expert_axes)
        out = out + sh
    aux = jax.lax.pmean(aux, expert_axes)
    return out, aux


# ---------------------------------------------------------------------------
# Expert-granular paged path (two-phase layer step)
# ---------------------------------------------------------------------------

def activated_experts(idx, num_experts: int, max_active: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compact the routed expert set: idx (T, K) -> (sel, index_map, n_act).

    sel (max_active,): activated expert ids in ascending order, padded with
    0 beyond n_act (padding slots never receive tokens — the index map only
    targets real compact slots, and subset compute masks them to a weight
    of exactly zero).  index_map (E,): expert id → compact slot, -1 if not
    activated.  ``max_active`` must be ≥ min(E, T*K) for exactness; the
    callers derive it from static shapes so this always holds."""
    hit = jnp.zeros((num_experts,), bool).at[idx.reshape(-1)].set(True)
    index_map = jnp.where(hit, jnp.cumsum(hit) - 1, -1).astype(jnp.int32)
    sel = jnp.nonzero(hit, size=max_active, fill_value=0)[0].astype(jnp.int32)
    return sel, index_map, jnp.sum(hit).astype(jnp.int32)


def _dense_subset(cfg: ModelConfig, ep: Dict, x, w, idx, sel, n_act):
    """Dense-oracle compute on a compacted expert subset.  Accumulates in
    ascending activated-expert order, so the result matches ``moe_dense``
    bit-for-bit up to ±0 (the experts it skips contribute exactly zero
    there)."""
    A = ep["wi"].shape[0]
    wi_all, wo_all = expert_weights(ep, x.dtype)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for a in range(A):
        y = gated_ffn(cfg, wi_all[a], wo_all[a], x)
        we = jnp.sum(jnp.where(idx == sel[a], w, 0.0), axis=-1)     # (T,)
        we = jnp.where(a < n_act, we, 0.0)     # mask pad slots (sel[a] == 0)
        out = out + y.astype(jnp.float32) * we[:, None]
    return out.astype(x.dtype)


def _grouped_subset(cfg: ModelConfig, ep: Dict, x, w, idx, index_map,
                    capacity_factor=None, use_kernel: bool = False,
                    token_groups: Optional[int] = None):
    """Capacity-bucketed grouped compute on a compacted subset.  Capacity
    and keep/drop decisions use the FULL expert count (cfg.num_experts),
    so drops are identical to ``moe_grouped`` on the full set.

    token_groups: module-based batching — x concatenates that many
    rotation groups' tokens (group-major) and the staging buffer holds a
    disjoint ``cap``-wide span per (group, expert) (``stage_bucket``):
    per-group capacity, per-group drops, one grouped GEMM per activated
    expert over the whole accumulation window."""
    T, D = x.shape
    NE, K = cfg.num_experts, cfg.top_k
    A = ep["wi"].shape[0]
    G = token_groups or 1
    cf = capacity_factor or cfg.capacity_factor
    cap = max(1, int((T // G) * K * cf / NE + 0.999))

    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = w.reshape(-1)
    dest = index_map[flat_e]                   # compact slot, always >= 0
    slot, keep = stage_bucket(dest, A, cap, G)
    e_safe = jnp.where(keep, dest, 0)
    s_safe = jnp.where(keep, slot, G * cap - 1)

    xbuf = jnp.zeros((A, G * cap, D), x.dtype)
    xbuf = xbuf.at[e_safe, s_safe].add(
        jnp.where(keep[:, None], x[flat_t], 0).astype(x.dtype))
    ybuf = grouped_ffn(cfg, ep["wi"], ep["wo"], xbuf, use_kernel,
                       ep.get("wi_scale"), ep.get("wo_scale"))
    y = ybuf[e_safe, s_safe]
    y = jnp.where(keep[:, None], y, 0) * flat_w[:, None].astype(x.dtype)
    return jnp.zeros_like(x).at[flat_t].add(y)


def moe_paged(cfg: ModelConfig, p: Dict, x, *, fetch_experts,
              policy=None, max_active: Optional[int] = None,
              token_groups: Optional[int] = None
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-phase MoE step for expert-granular paged weights: run the
    router FIRST, then fetch only the activated experts' page spans
    (``fetch_experts(sel (A,)) -> {wi (A,...), wo (A,...)[, scales]}`` —
    resident spans read in place from the device pool, misses stream from
    the host store) and compute on the compacted subset.

    x: (T, D).  Returns (out, aux_loss, counts (E,) int32 — tokens routed
    to each expert, the residency EWMA's observation).  Numerics match
    moe_dense / moe_grouped on the full expert set (skipped experts
    contribute exactly zero there), so greedy transcripts are
    bit-identical to whole-layer streaming.

    token_groups=G (module-based batching): x concatenates G rotation
    groups' tokens group-major.  The activated set (and the span fetch)
    then covers the UNION of the groups' routed experts — each streamed
    span serves every group's staged tokens in one accumulation window —
    while per-group numerics stay bit-identical to G separate calls
    (``_dense_subset`` accumulates the extra experts at exactly ±0;
    ``_grouped_subset`` buckets with per-group capacity).  counts is
    then (G, E) so the host residency cache can book per-window traffic
    yet keep per-group router-ahead predictions."""
    T, D = x.shape
    NE, K = cfg.num_experts, cfg.top_k
    A = max_active if max_active is not None else min(NE, T * K)
    w, idx, aux = route(cfg, p["router"], x)
    flat_e = idx.reshape(-1)
    if token_groups:
        G = token_groups
        g_flat = (jnp.arange(T * K) // (K * (T // G))).astype(jnp.int32)
        counts = jnp.zeros((G, NE), jnp.int32).at[g_flat, flat_e].add(1)
    else:
        counts = jnp.zeros((NE,), jnp.int32).at[flat_e].add(1)
    sel, index_map, n_act = activated_experts(idx, NE, A)
    ep = fetch_experts(sel)
    if "wi_scale" in p:
        # int8 dequant scales live in the shared span (see
        # paging.EXPERT_LEAF_NAMES): gather the activated experts' scales
        ep = dict(ep, wi_scale=p["wi_scale"][sel], wo_scale=p["wo_scale"][sel])
    if policy is not None and policy.moe_impl == "grouped":
        out = _grouped_subset(cfg, ep, x, w, idx, index_map,
                              use_kernel=policy.use_kernels,
                              token_groups=token_groups)
    else:
        out = _dense_subset(cfg, ep, x, w, idx, sel, n_act)
    if cfg.num_shared_experts:
        out = out + gated_ffn(cfg, p["shared"]["wi"], p["shared"]["wo"], x)
    return out, aux, counts


def moe_apply_paged(cfg: ModelConfig, p: Dict, x3, fetch_experts,
                    policy=None, token_groups: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(B, S, D) wrapper around moe_paged (the expert-granular analogue of
    moe_apply).  With token_groups, B must be G·ubatch (decode windows)
    so the flat group-major layout holds."""
    B, S, D = x3.shape
    out, aux, counts = moe_paged(cfg, p, x3.reshape(B * S, D),
                                 fetch_experts=fetch_experts, policy=policy,
                                 token_groups=token_groups)
    return out.reshape(B, S, D), aux, counts


def moe_apply(cfg: ModelConfig, p: Dict, x3, policy=None,
              token_groups: Optional[int] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Dispatch on the execution policy. x3: (B, S, D)."""
    B, S, D = x3.shape
    if policy is not None and policy.moe_fn is not None:
        out, aux = policy.moe_fn(cfg, p, x3)
        return out, aux
    x = x3.reshape(B * S, D)
    if policy is not None and policy.moe_impl == "grouped":
        out, aux = moe_grouped(cfg, p, x, use_kernel=policy.use_kernels,
                               token_groups=token_groups)
    else:
        out, aux = moe_dense(cfg, p, x)
    return out.reshape(B, S, D), aux

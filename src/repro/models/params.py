"""Parameter definition system.

A model's parameters are described as a nested dict of `ParamDef`s, each
carrying a shape, a tuple of *logical axis names* (one per dim), and an init
recipe.  From this single source of truth we derive:

  * `init_params`      — materialized, randomly initialized pytree
  * `abstract_params`  — jax.ShapeDtypeStruct pytree (dry-run, no allocation)
  * `param_axes`       — logical-axes pytree (consumed by distributed.sharding)
  * `count_params`     — exact parameter counts (total / active-per-token)

Stacking: repeated layers are stored stacked along a leading "layers" axis —
one stack per *position in the repeating period* — so the forward pass can
`lax.scan` over periods and the HLO stays O(period), not O(depth).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN_MLA, LayerSpec, ModelConfig


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis names (None = never shard)
    init: str = "normal"                # normal | zeros | ones | embed
    fan_in: int = 0                     # for scaled-normal init
    dtype: str = ""                     # "" = model dtype; e.g. "int8"


def _lin(d_in, d_out, ax_in, ax_out, stack=0) -> ParamDef:
    shape = (d_in, d_out)
    axes = (ax_in, ax_out)
    if stack:
        shape = (stack,) + shape
        axes = ("layers",) + axes
    return ParamDef(shape, axes, "normal", fan_in=d_in)


def _vec(d, ax, init="zeros", stack=0) -> ParamDef:
    shape, axes = (d,), (ax,)
    if stack:
        shape, axes = (stack,) + shape, ("layers",) + axes
    return ParamDef(shape, axes, init)


# ---------------------------------------------------------------------------
# Per-block definitions
# ---------------------------------------------------------------------------

def _norm_def(cfg: ModelConfig, stack: int) -> Dict[str, ParamDef]:
    if cfg.norm == "rmsnorm":
        init = "zeros" if cfg.scale_embeddings else "ones"  # gemma stores w, uses 1+w
        return {"scale": _vec(cfg.d_model, "embed_nr", init, stack)}
    if cfg.norm == "layernorm":
        return {"scale": _vec(cfg.d_model, "embed_nr", "ones", stack),
                "bias": _vec(cfg.d_model, "embed_nr", "zeros", stack)}
    if cfg.norm == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm)


def _attn_defs(cfg: ModelConfig, spec: LayerSpec, stack: int) -> Dict[str, ParamDef]:
    E, Dh = cfg.d_model, cfg.head_dim
    if spec.attn == ATTN_MLA:
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        d = {
            "wdq": _lin(E, cfg.q_lora_rank, "embed", "lora", stack),
            "q_norm": _vec(cfg.q_lora_rank, None, "ones", stack),
            "wuq": _lin(cfg.q_lora_rank, cfg.num_heads * qk_dim, "lora", "heads", stack),
            "wdkv": _lin(E, cfg.kv_lora_rank, "embed", "lora", stack),
            "kv_norm": _vec(cfg.kv_lora_rank, None, "ones", stack),
            "wkr": _lin(E, cfg.qk_rope_head_dim, "embed", None, stack),
            "wuk": _lin(cfg.kv_lora_rank, cfg.num_heads * cfg.qk_nope_head_dim,
                        "lora", "heads", stack),
            "wuv": _lin(cfg.kv_lora_rank, cfg.num_heads * cfg.v_head_dim,
                        "lora", "heads", stack),
            "wo": _lin(cfg.num_heads * cfg.v_head_dim, E, "heads", "embed", stack),
        }
        return d
    d = {
        "wq": _lin(E, cfg.num_heads * Dh, "embed", "heads", stack),
        "wk": _lin(E, cfg.num_kv_heads * Dh, "embed", "kv_heads", stack),
        "wv": _lin(E, cfg.num_kv_heads * Dh, "embed", "kv_heads", stack),
        "wo": _lin(cfg.num_heads * Dh, E, "heads", "embed", stack),
    }
    if cfg.qkv_bias:
        d["bq"] = _vec(cfg.num_heads * Dh, "heads", "zeros", stack)
        d["bk"] = _vec(cfg.num_kv_heads * Dh, "kv_heads", "zeros", stack)
        d["bv"] = _vec(cfg.num_kv_heads * Dh, "kv_heads", "zeros", stack)
    return d


def _ffn_defs(cfg: ModelConfig, d_ff: int, stack: int) -> Dict[str, ParamDef]:
    E = cfg.d_model
    if cfg.ffn_act == "gelu_mlp":            # plain MLP (whisper)
        return {"wi": _lin(E, d_ff, "embed", "ffn", stack),
                "bi": _vec(d_ff, "ffn", "zeros", stack),
                "wo": _lin(d_ff, E, "ffn", "embed", stack),
                "bo": _vec(E, None, "zeros", stack)}
    # gated (SwiGLU / GeGLU): gate+up stored as (E, 2, F) so 'ffn' sharding
    # keeps the two halves aligned on every shard
    shape_wi, axes_wi = (E, 2, d_ff), ("embed", None, "ffn")
    shape_wo, axes_wo = (d_ff, E), ("ffn", "embed")
    if stack:
        shape_wi, axes_wi = (stack,) + shape_wi, ("layers",) + axes_wi
        shape_wo, axes_wo = (stack,) + shape_wo, ("layers",) + axes_wo
    return {"wi": ParamDef(shape_wi, axes_wi, "normal", fan_in=E),
            "wo": ParamDef(shape_wo, axes_wo, "normal", fan_in=d_ff)}


def _moe_defs(cfg: ModelConfig, stack: int) -> Dict[str, ParamDef]:
    E, F, NE = cfg.d_model, cfg.d_ff, cfg.num_experts
    qdt = cfg.expert_dtype            # "" or "int8" (weight-only quant)
    shape_wi, axes_wi = (NE, E, 2, F), ("experts", "embed", None, "effn")
    shape_wo, axes_wo = (NE, F, E), ("experts", "effn", "embed")
    if stack:
        shape_wi, axes_wi = (stack,) + shape_wi, ("layers",) + axes_wi
        shape_wo, axes_wo = (stack,) + shape_wo, ("layers",) + axes_wo
    d = {
        "router": _lin(E, NE, "embed", None, stack),
        "wi": ParamDef(shape_wi, axes_wi, "normal", fan_in=E, dtype=qdt),
        "wo": ParamDef(shape_wo, axes_wo, "normal", fan_in=F, dtype=qdt),
    }
    if qdt == "int8":                 # per-expert dequant scales
        sshape = ((stack, NE) if stack else (NE,))
        saxes = (("layers", "experts") if stack else ("experts",))
        d["wi_scale"] = ParamDef(sshape, saxes, "qscale", fan_in=E,
                                 dtype="float32")
        d["wo_scale"] = ParamDef(sshape, saxes, "qscale", fan_in=F,
                                 dtype="float32")
    if cfg.num_shared_experts:
        d["shared"] = _ffn_defs(cfg, F * cfg.num_shared_experts, stack)
    return d


def _mamba_defs(cfg: ModelConfig, stack: int) -> Dict[str, ParamDef]:
    """Projections are stored per segment (z / x / B / C / dt) rather than
    as one fused in_proj so the inner (d_in) axis can shard over the model
    axis without crossing segment boundaries; B/C (shared across heads,
    single group) stay replicated."""
    E = cfg.d_model
    d_in = cfg.ssm_expand * E
    nh = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    cw = cfg.ssm_conv_width
    return {
        "wz": _lin(E, d_in, "embed", "ssm_inner", stack),
        "wx": _lin(E, d_in, "embed", "ssm_inner", stack),
        "wB": _lin(E, N, "embed", None, stack),
        "wC": _lin(E, N, "embed", None, stack),
        "wdt": _lin(E, nh, "embed", "ssm_heads", stack),
        "conv_x": ParamDef((stack, cw, d_in) if stack else (cw, d_in),
                           (("layers",) if stack else ()) + (None, "ssm_inner"),
                           "normal", fan_in=cw),
        "conv_bx": _vec(d_in, "ssm_inner", "zeros", stack),
        "conv_B": ParamDef((stack, cw, N) if stack else (cw, N),
                           (("layers",) if stack else ()) + (None, None),
                           "normal", fan_in=cw),
        "conv_bB": _vec(N, None, "zeros", stack),
        "conv_C": ParamDef((stack, cw, N) if stack else (cw, N),
                           (("layers",) if stack else ()) + (None, None),
                           "normal", fan_in=cw),
        "conv_bC": _vec(N, None, "zeros", stack),
        "a_log": _vec(nh, "ssm_heads", "ones", stack),
        "d_skip": _vec(nh, "ssm_heads", "ones", stack),
        "dt_bias": _vec(nh, "ssm_heads", "zeros", stack),
        "norm": _vec(d_in, "ssm_inner", "ones", stack),
        "out_proj": _lin(d_in, E, "ssm_inner", "embed", stack),
    }


def _block_defs(cfg: ModelConfig, spec: LayerSpec, stack: int,
                decoder: bool = True) -> Dict:
    d: Dict = {}
    if spec.kind == "mamba":
        d["mamba"] = _mamba_defs(cfg, stack)
        d["mamba_norm"] = _norm_def(cfg, stack)
    else:
        d["attn"] = _attn_defs(cfg, spec, stack)
        d["attn_norm"] = _norm_def(cfg, stack)
        if cfg.post_block_norm:
            d["post_attn_norm"] = _norm_def(cfg, stack)
    if spec.cross_attn and decoder:
        d["xattn"] = _attn_defs(cfg, LayerSpec(), stack)
        d["xattn_norm"] = _norm_def(cfg, stack)
    if spec.ffn:
        if spec.moe:
            d["moe"] = _moe_defs(cfg, stack)
        else:
            d["ffn"] = _ffn_defs(cfg, cfg.dense_d_ff or cfg.d_ff, stack)
        d["ffn_norm"] = _norm_def(cfg, stack)
        if cfg.post_block_norm:
            d["post_ffn_norm"] = _norm_def(cfg, stack)
    return d


# ---------------------------------------------------------------------------
# Whole-model definitions
# ---------------------------------------------------------------------------

def param_defs(cfg: ModelConfig) -> Dict:
    defs: Dict = {
        "embed": {"tokens": ParamDef((cfg.vocab_size, cfg.d_model),
                                     ("vocab", "embed"), "embed",
                                     fan_in=cfg.d_model)},
        "final_norm": _norm_def(cfg, 0),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = _lin(cfg.d_model, cfg.vocab_size, "embed", "vocab")

    if cfg.prologue:
        # group prologue layers (all-identical specs stack together)
        defs["prologue"] = {"p0": _block_defs(cfg, cfg.prologue[0],
                                              len(cfg.prologue))}
    blocks = {}
    for i, spec in enumerate(cfg.period):
        blocks[f"p{i}"] = _block_defs(cfg, spec, cfg.num_periods)
    defs["blocks"] = blocks

    if cfg.encoder_layers:
        enc_spec = LayerSpec(cross_attn=False)
        defs["encoder"] = {
            "blocks": {"p0": _block_defs(cfg, enc_spec, cfg.encoder_layers,
                                         decoder=False)},
            "final_norm": _norm_def(cfg, 0),
        }
    return defs


# ---------------------------------------------------------------------------
# Derivations from defs
# ---------------------------------------------------------------------------

def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, defs):
    if _is_def(defs):
        return fn(defs)
    return {k: tree_map_defs(fn, v) for k, v in defs.items()}


def abstract_params(cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, jnp.dtype(d.dtype) if d.dtype else dtype),
        param_defs(cfg))


def param_axes(cfg: ModelConfig):
    return tree_map_defs(lambda d: d.axes, param_defs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array):
    defs = param_defs(cfg)
    leaves = []

    def collect(d, path):
        if _is_def(d):
            leaves.append((path, d))
        else:
            for k in sorted(d):
                collect(d[k], path + (k,))

    collect(defs, ())
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)

    out: Dict = {}
    for (path, d), k in zip(leaves, keys):
        ldt = jnp.dtype(d.dtype) if d.dtype else dtype
        if d.init == "zeros":
            val = jnp.zeros(d.shape, ldt)
        elif d.init == "ones":
            val = jnp.ones(d.shape, ldt)
        elif d.init == "qscale":
            # dequant scale matched to the int8 init below: w ≈ q * scale
            std = 1.0 / math.sqrt(max(d.fan_in, 1))
            val = jnp.full(d.shape, std / 48.0, jnp.float32)
        elif d.dtype == "int8":
            # weight-only quantized experts: ~48 quant levels per std
            val = jnp.clip(jnp.round(
                jax.random.normal(k, d.shape, jnp.float32) * 48.0),
                -127, 127).astype(jnp.int8)
        else:
            std = (1.0 / math.sqrt(max(d.fan_in, 1))) if d.fan_in else 0.02
            val = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(ldt)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = val
    # mamba a_log: init to log(uniform[1,16]) per mamba2 reference
    return out


def count_params(cfg: ModelConfig, active_only: bool = False,
                 include_embed: bool = True) -> int:
    total = 0

    def visit(d, path):
        nonlocal total
        if _is_def(d):
            n = int(np.prod(d.shape))
            is_embed = "vocab" in (d.axes or ())
            if is_embed and not include_embed:
                return
            if active_only and "experts" in (d.axes or ()):
                n = n * cfg.top_k // cfg.num_experts
            total += n
        else:
            for k, v in d.items():
                visit(v, path + (k,))

    visit(param_defs(cfg), ())
    return total

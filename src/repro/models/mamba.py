"""Mamba-2 mixer with the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060) and an O(1)-state decode step.

Layout (single group, G=1):
  in_proj(x) -> [z (d_in), xBC (d_in + 2N), dt (nh)]
  causal depthwise conv over xBC (width cw), SiLU
  split xBC -> x (d_in), B (N), C (N);  heads: x -> (nh, hd)
  dt = softplus(dt + dt_bias); A = -exp(a_log)  (per head)
  SSD recurrence per head h:
      S_t = exp(dt_t A_h) S_{t-1} + dt_t * B_t x_t^T        (hd x N)
      y_t = C_t . S_t + D_h x_t
  gated RMSNorm(y * silu(z)), out_proj.

`ssd_chunked` scans fixed-size chunks: intra-chunk work is a masked
(L x L) matmul per chunk (MXU-friendly), inter-chunk state is a sequential
scan — compute O(S*L) instead of O(S^2).  `ssd_recurrent_ref` is the
step-by-step oracle used by tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rmsnorm


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_recurrent_ref(x, dt, A, B, C, state0=None):
    """Oracle. x: (b,S,nh,hd); dt: (b,S,nh); A: (nh,); B,C: (b,S,N).
    Returns (y (b,S,nh,hd), state (b,nh,hd,N))."""
    b, S, nh, hd = x.shape
    N = B.shape[-1]
    S0 = jnp.zeros((b, nh, hd, N), jnp.float32) if state0 is None else state0

    def step(s, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt.astype(jnp.float32) * A)             # (b,nh)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(jnp.float32),
                         xt.astype(jnp.float32), Bt.astype(jnp.float32))
        s = s * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", s, Ct.astype(jnp.float32))
        return s, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    state, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(x, dt, A, B, C, state0=None, chunk: int = 256):
    """Chunked SSD. Same signature/semantics as ssd_recurrent_ref."""
    b, S, nh, hd = x.shape
    N = B.shape[-1]
    nchunks = -(-S // chunk)
    pad = nchunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    xc = x.reshape(b, nchunks, L, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(b, nchunks, L, nh).astype(jnp.float32)
    Bc = B.reshape(b, nchunks, L, N).astype(jnp.float32)
    Cc = C.reshape(b, nchunks, L, N).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    S0 = (jnp.zeros((b, nh, hd, N), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]                        # (L,L)

    def body(s, inp):
        xi, dti, Bi, Ci = inp                                    # (b,L,...)
        a = dti * Af                                             # (b,L,nh)
        cumA = jnp.cumsum(a, axis=1)                             # (b,L,nh)
        # intra-chunk: y[i] += sum_{j<=i} (C_i.B_j) exp(cumA_i - cumA_j) dt_j x_j
        CB = jnp.einsum("bin,bjn->bij", Ci, Bi)                  # (b,L,L)
        decay = jnp.exp(cumA[:, :, None, :] - cumA[:, None, :, :])  # (b,i,j,nh)
        M = jnp.where(causal[None, :, :, None], CB[..., None] * decay, 0.0)
        y = jnp.einsum("bijh,bjh,bjhp->bihp", M, dti, xi)
        # inter-chunk: y[i] += C_i exp(cumA_i) . S_prev
        y = y + jnp.einsum("bin,bih,bhpn->bihp", Ci, jnp.exp(cumA), s)
        # state update: S = exp(sumA) S_prev + sum_j exp(sumA - cumA_j) dt_j B_j x_j^T
        sumA = cumA[:, -1, :]                                    # (b,nh)
        w = jnp.exp(sumA[:, None, :] - cumA) * dti               # (b,L,nh)
        upd = jnp.einsum("bjh,bjn,bjhp->bhpn", w, Bi, xi)
        s = s * jnp.exp(sumA)[..., None, None] + upd
        return s, y

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    state, ys = jax.lax.scan(body, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nchunks * L, nh, hd)
    if pad:
        y = y[:, :S]
    return y.astype(x.dtype), state


def ssd_step(xt, dtt, A, Bt, Ct, state):
    """Single decode step. xt: (b,nh,hd); dtt: (b,nh); Bt/Ct: (b,N);
    state: (b,nh,hd,N). Returns (y (b,nh,hd), new_state)."""
    decay = jnp.exp(dtt.astype(jnp.float32) * A.astype(jnp.float32))
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(jnp.float32),
                     xt.astype(jnp.float32), Bt.astype(jnp.float32))
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Ct.astype(jnp.float32))
    return y.astype(xt.dtype), state


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x, w, b):
    """x: (B,S,C); w: (cw,C); depthwise causal.  Computed in f32 so the
    transposed conv in the backward pass sees uniform dtypes."""
    cw, C = w.shape
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],  # (cw,1,C)
        window_strides=(1,), padding=[(cw - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_step(x_new, conv_cache, w, b):
    """x_new: (B,C); conv_cache: (B,cw-1,C). Returns (y (B,C), new_cache)."""
    window = jnp.concatenate([conv_cache, x_new[:, None, :]], axis=1)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x_new.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Full mixer
# ---------------------------------------------------------------------------

def mamba_forward(cfg: ModelConfig, p: Dict, x, *, cache: Optional[Dict],
                  mode: str) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B,S,E). Returns (out (B,S,E), new_layer_cache)."""
    Bsz, S, E = x.shape
    d_in, nh, hd, N = _dims(cfg)

    z = jnp.einsum("bse,ef->bsf", x, p["wz"].astype(x.dtype))
    xr = jnp.einsum("bse,ef->bsf", x, p["wx"].astype(x.dtype))
    Br = jnp.einsum("bse,en->bsn", x, p["wB"].astype(x.dtype))
    Cr = jnp.einsum("bse,en->bsn", x, p["wC"].astype(x.dtype))
    dt_raw = jnp.einsum("bse,eh->bsh", x, p["wdt"].astype(x.dtype))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,S,nh)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (nh,)

    def _silu(v):
        return jax.nn.silu(v.astype(jnp.float32)).astype(x.dtype)

    new_cache = cache
    if mode == "chunk":
        # chunked prefill would need the conv tail + SSM state carried
        # across chunks; the engine gates overlap admission to
        # attention-only configs, so reaching here is a bug
        raise NotImplementedError(
            "chunked prefill is not supported for SSM layers")
    if mode == "decode":
        assert S == 1 and cache is not None
        xs, new_cx = conv_step(xr[:, 0], cache["conv_x"], p["conv_x"],
                               p["conv_bx"])
        Bp, new_cB = conv_step(Br[:, 0], cache["conv_B"], p["conv_B"],
                               p["conv_bB"])
        Cp, new_cC = conv_step(Cr[:, 0], cache["conv_C"], p["conv_C"],
                               p["conv_bC"])
        xs, Bp, Cp = _silu(xs), _silu(Bp), _silu(Cp)
        xh = xs.reshape(Bsz, nh, hd)
        y, new_state = ssd_step(xh, dt[:, 0], A, Bp, Cp, cache["state"])
        y = y.astype(x.dtype) + p["d_skip"].astype(x.dtype)[None, :, None] * xh
        y = y.reshape(Bsz, 1, d_in)
        new_cache = {"conv_x": new_cx, "conv_B": new_cB, "conv_C": new_cC,
                     "state": new_state}
    else:
        xs = _silu(causal_conv(xr, p["conv_x"], p["conv_bx"]))
        Bp = _silu(causal_conv(Br, p["conv_B"], p["conv_bB"]))
        Cp = _silu(causal_conv(Cr, p["conv_C"], p["conv_bC"]))
        xh = xs.reshape(Bsz, S, nh, hd)
        state0 = cache["state"] if cache is not None else None
        y, state = ssd_chunked(xh, dt, A, Bp, Cp, state0=state0,
                               chunk=cfg.ssm_chunk)
        y = y + p["d_skip"].astype(x.dtype)[None, None, :, None] * xh
        y = y.reshape(Bsz, S, d_in)
        if cache is not None:   # prefill: persist state + conv tails
            def tail_of(v, ref):
                t = v[:, -(cfg.ssm_conv_width - 1):]
                pad_t = cfg.ssm_conv_width - 1 - t.shape[1]
                if pad_t > 0:
                    t = jnp.pad(t, ((0, 0), (pad_t, 0), (0, 0)))
                return t.astype(ref.dtype)
            new_cache = {"conv_x": tail_of(xr, cache["conv_x"]),
                         "conv_B": tail_of(Br, cache["conv_B"]),
                         "conv_C": tail_of(Cr, cache["conv_C"]),
                         "state": state}

    # gated RMSNorm + out proj
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fe->bse", y, p["out_proj"].astype(y.dtype))
    return out.astype(x.dtype), new_cache

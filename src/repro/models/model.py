"""Model assembly: embedding → (prologue + scanned periodic blocks) → norm →
unembed, for every assigned architecture (dense / MoE / SSM / hybrid /
enc-dec / vlm-prefix).

The periodic layer stack is executed with ``jax.lax.scan`` over *periods*
(param stacks built by models.params), so the lowered HLO is O(period
length), independent of depth — this is what keeps the 512-device dry-run
compiles of 61-layer DeepSeek-V3 and 72-layer Jamba tractable.

Execution strategy (which MoE path, which sharded-attention combine, remat)
is injected through an `ExecPolicy` so the same model code runs on a laptop
CPU and on a 512-chip mesh.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import kvcache
from repro.models.attention import attn_forward, gqa_forward
from repro.models.common import (act_fn, apply_norm, sinusoidal_positions,
                                 softcap)
from repro.models.mamba import mamba_forward
from repro.models.moe import gated_ffn, moe_apply, moe_apply_paged


@dataclass
class ExecPolicy:
    """How to execute (not what to compute)."""
    moe_impl: str = "dense"               # dense | grouped
    moe_fn: Optional[Callable] = None     # overrides moe_impl when set
    attn_fn: Optional[Callable] = None    # sharded decode-attention combine
    use_kernels: bool = False
    paged_attn_impl: str = "auto"         # paged-decode kernel dispatch:
    # auto (Pallas on TPU, dense-view ref elsewhere) | pallas | interpret
    # | ref — see kernels.ops.paged_gqa_decode
    remat: bool = False
    scan_unroll: int = 1


@dataclass
class _ExpertCtx:
    """Scan-invariant state for one group's expert-granular paged weights:
    the host page store, its manifest, and (optionally) the device
    residency pool + (layer, expert) → slot map snapshot."""
    pages: Any                            # (L, E, ppe, page_elems) host store
    manifest: Any                         # paging.ExpertManifest
    pool: Optional[Any] = None            # (slots, ppe, page_elems) device
    resident_map: Optional[Any] = None    # (L, E) int32, -1 = host only

    def make_fetch(self, layer):
        """Bind the traced layer index: fetch(sel (A,)) gathers the
        activated experts' spans — resident spans read in place from the
        pool, misses stream from the host store (on TPU the store lives in
        pinned host memory, so this gather IS the H2D transfer) — and
        rebuilds the compacted (A, ...) expert params."""
        from repro.core import paging as _paging

        def fetch(sel):
            host_span = self.pages[layer][sel]          # (A, ppe, pe)
            if self.pool is not None:
                slot = self.resident_map[layer][sel]    # (A,)
                pool_span = self.pool[jnp.maximum(slot, 0)]
                span = jnp.where((slot >= 0)[:, None, None],
                                 pool_span, host_span)
            else:
                span = host_span
            return _paging.unflatten_expert_span(span, self.manifest)

        return fetch


# ---------------------------------------------------------------------------
# FFN (dense)
# ---------------------------------------------------------------------------

def dense_ffn(cfg: ModelConfig, p: Dict, x):
    if cfg.ffn_act == "gelu_mlp":
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
        h = act_fn("gelu_mlp")(h + p["bi"].astype(x.dtype))
        return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype)) \
            + p["bo"].astype(x.dtype)
    return gated_ffn(cfg, p["wi"], p["wo"], x)


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def block_apply(cfg: ModelConfig, spec: LayerSpec, p: Dict, x, *,
                positions, cache: Optional[Dict], mode: str,
                pos: Optional[jax.Array], enc_out: Optional[jax.Array],
                xattn_cache: Optional[Dict], policy: Optional[ExecPolicy],
                causal: bool = True, expert_fetch=None,
                token_groups: Optional[int] = None):
    """Returns (x, new_cache, new_xattn_cache, aux_loss, expert_counts).

    With ``expert_fetch`` set (expert-granular paged weights), the MoE FFN
    runs the two-phase step: router first, then a gather of only the
    activated experts' page spans; ``expert_counts`` (E,) reports the
    routing so the host-side residency cache can learn popularity and
    account hits/misses.  Otherwise expert_counts is None.

    token_groups=G (module-based batching): the batch concatenates G
    rotation groups.  Attention/norms are per-row so they are untouched;
    the MoE FFN stages the G groups' routed tokens into one cross-group
    buffer so each expert span is read once per window, and expert_counts
    becomes (G, E)."""
    aux = jnp.float32(0.0)
    ecounts = None
    new_cache, new_x = cache, xattn_cache

    if spec.kind == "mamba":
        h = apply_norm(cfg, p.get("mamba_norm", {}), x)
        y, new_cache = mamba_forward(cfg, p["mamba"], h, cache=cache, mode=mode)
        x = x + y
    else:
        h = apply_norm(cfg, p.get("attn_norm", {}), x)
        y, new_cache = attn_forward(
            cfg, spec, p["attn"], h, positions, cache=cache, mode=mode,
            pos=pos, sharded_fn=policy.attn_fn if policy else None,
            paged_impl=policy.paged_attn_impl if policy else "auto",
            **({} if causal else {"causal": False}))
        if cfg.post_block_norm:
            y = apply_norm(cfg, p["post_attn_norm"], y)
        x = x + y

    if spec.cross_attn:
        h = apply_norm(cfg, p["xattn_norm"], x)
        if mode == "decode":
            kv = (xattn_cache["k"], xattn_cache["v"])
        else:
            # build cross KV from encoder output, persist for decode
            B, Se, _ = enc_out.shape
            Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
            k = jnp.einsum("bse,ef->bsf", enc_out,
                           p["xattn"]["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bse,ef->bsf", enc_out,
                           p["xattn"]["wv"].astype(enc_out.dtype))
            kv = (k.reshape(B, Se, Hkv, Dh), v.reshape(B, Se, Hkv, Dh))
            new_x = {"k": kv[0], "v": kv[1]}
        y, _ = gqa_forward(cfg, LayerSpec(), p["xattn"], h, positions,
                           cache=None, mode="full", kv_override=kv)
        x = x + y

    if spec.ffn:
        h = apply_norm(cfg, p.get("ffn_norm", {}), x)
        if spec.moe:
            if expert_fetch is not None:
                y, aux, ecounts = moe_apply_paged(cfg, p["moe"], h,
                                                  expert_fetch, policy,
                                                  token_groups=token_groups)
            else:
                y, aux = moe_apply(cfg, p["moe"], h, policy,
                                   token_groups=token_groups)
        else:
            y = dense_ffn(cfg, p["ffn"], h)
        if cfg.post_block_norm:
            y = apply_norm(cfg, p["post_ffn_norm"], y)
        x = x + y
    return x, new_cache, new_x, aux, ecounts


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _run_group(cfg, specs, stacked_p, x, *, n_steps, positions, cache_group,
               mode, pos, enc_out, xattn_group, policy, causal=True,
               manifests=None, expert_ctx=None, token_groups=None):
    """Scan `n_steps` times over a group of layer specs whose params (and
    caches) are stacked on the leading axis.  When `manifests` maps a
    group key to a PageManifest, that group's xs entry is a page span
    (paged weights, paper Appendix A.1) rebuilt in-scan.  When
    `expert_ctx` maps a group key to an _ExpertCtx, that group's span is
    the *shared* span only and the MoE expert weights are fetched
    router-gated per layer (two-phase step); the scan then also stacks
    per-layer expert activation counts for the residency control plane.

    Returns (x, aux, new_cache, new_xattn, expert_counts) where
    expert_counts is {key: (n_steps, E)} (empty without expert_ctx)."""

    def body(carry, xs):
        x, aux = carry
        p_sl, cache_sl, xattn_sl, layer = xs
        if manifests:
            from repro.core import paging as _paging
            p_sl = {k: (_paging.unflatten_span(v, manifests[k])
                        if k in manifests else v)
                    for k, v in p_sl.items()}
        has_cache = isinstance(cache_sl, dict)
        has_xc = isinstance(xattn_sl, dict)
        new_caches, new_xs, counts = {}, {}, {}
        for i, spec in enumerate(specs):
            key = f"p{i}"
            fetch = (expert_ctx[key].make_fetch(layer)
                     if expert_ctx and key in expert_ctx else None)
            x, nc, nx, a, ec = block_apply(
                cfg, spec, p_sl[key], x, positions=positions,
                cache=cache_sl.get(key) if has_cache else None, mode=mode,
                pos=pos, enc_out=enc_out,
                xattn_cache=xattn_sl if (spec.cross_attn and has_xc) else None,
                policy=policy, causal=causal, expert_fetch=fetch,
                token_groups=token_groups)
            if nc is not None and has_cache:
                new_caches[key] = nc
            if nx is not None:
                new_xs = nx
            if ec is not None:
                counts[key] = ec
            aux = aux + a
        if new_xs:
            out_xattn = new_xs
        elif has_xc:
            out_xattn = xattn_sl
        else:
            out_xattn = jnp.int32(0)
        return (x, aux), (new_caches, out_xattn, counts)

    if policy and policy.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    p_stacked = {f"p{i}": stacked_p[f"p{i}"] for i in range(len(specs))}
    cache_stacked = cache_group if cache_group else None
    has_x = any(s.cross_attn for s in specs)
    xattn_stacked = xattn_group if has_x else None

    xs = (p_stacked,
          cache_stacked if cache_stacked is not None else
          jnp.zeros((n_steps,), jnp.int32),
          xattn_stacked if xattn_stacked is not None else
          jnp.zeros((n_steps,), jnp.int32),
          jnp.arange(n_steps))
    (x, aux), (new_cache, new_xattn, counts) = jax.lax.scan(
        body, (x, jnp.float32(0.0)), xs,
        unroll=policy.scan_unroll if policy else 1)
    return x, aux, (new_cache if cache_group else None), \
        (new_xattn if has_x else None), counts


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens, positions,
                 patches=None):
    x = params["embed"]["tokens"][tokens]            # (B,S,E) gather
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.vision_tokens and patches is not None:
        nv = min(cfg.vision_tokens, x.shape[1])
        x = x.at[:, :nv].set(patches[:, :nv].astype(x.dtype))
    if cfg.pos == "learned":                         # sinusoidal stand-in
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return x


def encoder_forward(cfg: ModelConfig, params, frames, policy=None):
    """Whisper encoder: frames (B, encS, E) — conv frontend stubbed."""
    B, S, E = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = frames + sinusoidal_positions(positions, E).astype(frames.dtype)
    enc = params["encoder"]
    x, _, _, _, _ = _run_group(
        cfg, (LayerSpec(cross_attn=False),), enc["blocks"], x,
        n_steps=cfg.encoder_layers, positions=positions, cache_group=None,
        mode="encode", pos=None, enc_out=None, xattn_group=None,
        policy=policy, causal=False)
    return apply_norm(cfg, enc["final_norm"], x)


def forward(cfg: ModelConfig, params, tokens, *, cache=None, mode="train",
            frames=None, patches=None, policy: Optional[ExecPolicy] = None,
            paged_blocks=None, fill_len=None, expert_state=None,
            token_groups=None):
    """tokens: (B,S) int32.  mode: train | prefill | decode | chunk_prefill.
    Returns dict(hidden, cache, aux_loss).  Call `unembed` for logits.

    chunk_prefill processes one fixed-width prompt chunk at the row offset
    recorded in cache["pos"]: the chunk's KV is written into the ring at
    absolute positions pos..pos+S-1 and its queries attend to the whole
    ring (history + chunk) under the slot_pos mask.  `fill_len` ((B,) i32)
    gives the true token count of the chunk; padded tail positions are
    clamped to pos+fill_len so they collapse into one causally-masked slot
    instead of wrapping the ring.

    paged_blocks: optional (pages_dict, manifests) from
    core.paging.pack_block_groups — replaces params['blocks'] with paged
    weight spans consumed layer-by-layer inside the scan (the offloaded
    serving path; pages may live in host memory on TPU) — OR a
    core.paging.PagedWeights from pack_block_groups_split for the
    expert-granular path: the scan streams only each layer's *shared*
    span and the MoE experts are fetched router-gated per layer.
    `expert_state` then optionally maps each MoE group key to
    (pool (slots, ppe, page_elems), resident_map (L, E) int32): spans
    whose map entry is >= 0 are read in place from the device pool,
    the rest stream from the host store.  The result dict gains
    "expert_counts" ({key: (n_steps, E)} tokens-routed counts) so the
    host residency cache can learn popularity and account traffic."""
    B, S = tokens.shape
    if mode == "decode":
        assert cache is not None
        pos = cache["pos"]                           # (B,)
        positions = pos[:, None]
        run_mode = "decode"
    elif mode == "chunk_prefill":
        assert cache is not None
        pos = None
        off = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if fill_len is not None:
            off = jnp.minimum(off, fill_len[:, None])
        positions = cache["pos"][:, None] + off
        run_mode = "chunk"
    else:
        pos = None
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        run_mode = mode if mode == "decode" else ("prefill" if cache is not None
                                                  else "train")
        run_mode = "full"

    enc_out = None
    if cfg.encoder_layers and frames is not None:
        enc_out = encoder_forward(cfg, params, frames, policy)

    x = embed_tokens(cfg, params, tokens, positions, patches)
    aux_total = jnp.float32(0.0)
    new_cache = dict(cache) if cache is not None else None

    if cfg.prologue:
        x, aux, npc, _, _ = _run_group(
            cfg, (cfg.prologue[0],), {"p0": params["prologue"]["p0"]}, x,
            n_steps=len(cfg.prologue), positions=positions,
            cache_group={"p0": cache["prologue"]} if cache is not None else None,
            mode=run_mode if mode != "decode" else "decode",
            pos=pos, enc_out=enc_out, xattn_group=None, policy=policy)
        aux_total += aux
        if new_cache is not None and npc is not None:
            new_cache["prologue"] = npc["p0"]

    cache_group = None
    if cache is not None:
        cache_group = {f"p{i}": cache[f"p{i}"] for i in range(len(cfg.period))}
    xattn_group = cache.get("xattn") if (cache is not None and
                                         cfg.encoder_layers) else None
    if cfg.encoder_layers and cache is None:
        xattn_group = None

    blocks = params["blocks"]
    manifests = None
    expert_ctx = None
    if paged_blocks is not None:
        from repro.core import paging as _paging
        if isinstance(paged_blocks, _paging.PagedWeights):
            blocks, manifests = paged_blocks.pages, paged_blocks.manifests
            if paged_blocks.expert_manifests:
                expert_ctx = {}
                for k, em in paged_blocks.expert_manifests.items():
                    pool, rmap = (expert_state or {}).get(k, (None, None))
                    expert_ctx[k] = _ExpertCtx(paged_blocks.expert_pages[k],
                                               em, pool, rmap)
        else:
            blocks, manifests = paged_blocks
    x, aux, npc, nxc, ecounts = _run_group(
        cfg, cfg.period, blocks, x, n_steps=cfg.num_periods,
        positions=positions, cache_group=cache_group,
        mode=run_mode if run_mode in ("decode", "chunk") else "full",
        pos=pos, enc_out=enc_out, xattn_group=xattn_group, policy=policy,
        manifests=manifests, expert_ctx=expert_ctx,
        token_groups=token_groups)
    aux_total += aux
    if new_cache is not None:
        if npc is not None:
            new_cache.update(npc)
        if nxc is not None:
            new_cache["xattn"] = nxc
        step = jnp.int32(1) if mode == "decode" else jnp.int32(S)
        if mode == "chunk_prefill" and fill_len is not None:
            step = fill_len.astype(jnp.int32)        # per-row true fill
        new_cache["pos"] = cache["pos"] + step

    x = apply_norm(cfg, params.get("final_norm", {}), x)
    out = {"hidden": x, "cache": new_cache, "aux_loss": aux_total}
    if expert_ctx is not None:
        out["expert_counts"] = ecounts
    return out


def unembed(cfg: ModelConfig, params, hidden):
    """hidden: (..., E) -> logits (..., V) float32 (with gemma2 softcap)."""
    if cfg.tie_embeddings:
        w = params["embed"]["tokens"]                # (V,E)
        logits = jnp.einsum("...e,ve->...v", hidden.astype(jnp.float32),
                            w.astype(jnp.float32))
    else:
        logits = jnp.einsum("...e,ev->...v", hidden.astype(jnp.float32),
                            params["lm_head"].astype(jnp.float32))
    return softcap(logits, cfg.logit_softcap)

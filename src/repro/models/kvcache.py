"""KV / SSM cache structures.

Caches are plain nested dicts (pytree-friendly, mirrors param structure):

  cache = {
    "pos":  (B,) int32 — current sequence length per row,
    "p{i}": per period-position stacked state, one of
        kv:  {"k": (P,B,W,Hkv,Dh), "v": ..., "slot_pos": (P,B,W) int32}
        mla: {"ckv": (P,B,W,kv_lora), "kr": (P,B,W,rope), "slot_pos": ...}
        ssm: {"conv": (P,B,cw-1,Cch), "state": (P,B,nh,hd,N)}
    "prologue": {...}     (when the arch has non-periodic leading layers)
    "xattn": {"k": (P,B,encS,H,Dh), "v": ...}   (whisper cross-attention)
  }

W is the ring-buffer width: ``min(window, max_seq)`` for sliding-window
layers, ``max_seq`` otherwise.  ``slot_pos`` stores the absolute position
held in each ring slot (-1 = empty), which makes masking exact for both
full and windowed layers without modular-arithmetic case analysis.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_MLA, ATTN_WINDOW, LayerSpec, ModelConfig


def layer_cache_width(cfg: ModelConfig, spec: LayerSpec, max_seq: int) -> int:
    if spec.attn == ATTN_WINDOW:
        return min(cfg.window_size, max_seq)
    return max_seq


def _spec_cache(cfg: ModelConfig, spec: LayerSpec, stack: int, batch: int,
                max_seq: int, dtype) -> Dict:
    kind = spec.cache_kind()
    if kind == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        cw = cfg.ssm_conv_width - 1
        return {
            "conv_x": jnp.zeros((stack, batch, cw, d_in), dtype),
            "conv_B": jnp.zeros((stack, batch, cw, cfg.ssm_state), dtype),
            "conv_C": jnp.zeros((stack, batch, cw, cfg.ssm_state), dtype),
            "state": jnp.zeros((stack, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
        }
    W = layer_cache_width(cfg, spec, max_seq)
    if kind == "mla":
        return {
            "ckv": jnp.zeros((stack, batch, W, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((stack, batch, W, cfg.qk_rope_head_dim), dtype),
            "slot_pos": jnp.full((stack, batch, W), -1, jnp.int32),
        }
    if kind == "kv":
        if cfg.kv_dtype == "int8":
            Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
            return {
                "k": jnp.zeros((stack, batch, W, Hkv, Dh), jnp.int8),
                "v": jnp.zeros((stack, batch, W, Hkv, Dh), jnp.int8),
                "k_scale": jnp.zeros((stack, batch, W, Hkv), jnp.float32),
                "v_scale": jnp.zeros((stack, batch, W, Hkv), jnp.float32),
                "slot_pos": jnp.full((stack, batch, W), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((stack, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((stack, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "slot_pos": jnp.full((stack, batch, W), -1, jnp.int32),
        }
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: Dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    for i, spec in enumerate(cfg.period):
        cache[f"p{i}"] = _spec_cache(cfg, spec, cfg.num_periods, batch,
                                     max_seq, dtype)
    if cfg.prologue:
        cache["prologue"] = _spec_cache(cfg, cfg.prologue[0],
                                        len(cfg.prologue), batch, max_seq, dtype)
    if cfg.encoder_layers:
        cache["xattn"] = {
            "k": jnp.zeros((cfg.num_periods, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.num_periods, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """ShapeDtypeStruct mirror of init_cache (no allocation, for dry-runs)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))


# ---------------------------------------------------------------------------
# Slot-pool operations.  A cache allocated once with batch = number of slots
# is treated as a pool of independent per-row "slots": a finished row can be
# reset and refilled with a new request without touching its neighbors
# (continuous batching).  Batch is axis 0 for "pos" and axis 1 (after the
# layer-stack axis) for every other leaf.
# ---------------------------------------------------------------------------

def _map_named_leaves(tree: Dict, fn) -> Dict:
    """Map fn(leaf_name, leaf) over a nested-dict pytree, keeping names."""
    out = {}
    for k, v in tree.items():
        out[k] = _map_named_leaves(v, fn) if isinstance(v, dict) else fn(k, v)
    return out


def reset_slot(cache: Dict, row) -> Dict:
    """Return `cache` with batch row `row` restored to its init_cache state
    (slot_pos = -1, pos = 0, zeros elsewhere) and all other rows untouched.
    `row` may be a traced scalar, so one jit covers every slot."""
    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[row].set(0)
        else:
            out[k] = _map_named_leaves(
                v, lambda name, a: a.at[:, row].set(
                    jnp.asarray(-1 if name == "slot_pos" else 0, a.dtype)))
    return out


def insert_slot(cache: Dict, single: Dict, row) -> Dict:
    """Slot-indexed prefill write: copy batch row 0 of `single` (a cache
    built with batch=1, e.g. freshly prefilled for one request) into batch
    row `row` of the pooled `cache`.  Only that row changes."""
    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[row].set(single[k][0])
        else:
            out[k] = jax.tree.map(
                lambda a, b: a.at[:, row].set(b[:, 0].astype(a.dtype)),
                v, single[k])
    return out


# leaves whose third axis is NOT the ring (SSM recurrent state / conv tails
# and whisper cross-attention KV over encoder positions): a span copy makes
# no sense for them, so partial inserts copy the whole row instead
_NON_RING_LEAVES = ("conv_x", "conv_B", "conv_C", "state")


def insert_slot_span(cache: Dict, single: Dict, row, start,
                     *, length: int) -> Dict:
    """Partial slot insert at a row offset: copy only the ring slots
    holding absolute positions [start, start + length) of batch row 0 of
    `single` into batch row `row` of the pooled `cache` (plus `single`'s
    row-0 pos).  This is the chunked-prefill admission path — each staged
    prefill chunk lands in the pool as soon as it is computed instead of
    one whole-row copy at the end, so per-tick work stays bounded.

    `length` must be static (one jit specialization per chunk-width
    bucket); `start` may be traced.  Ring indices are taken modulo each
    leaf's own ring width, so sliding-window layers wrap correctly.
    NOTE unlike `insert_slot`, a span write does not clear the rest of the
    row — callers must `reset_slot` the target row once before the first
    span of a new request (stale `slot_pos` entries from the previous
    occupant would otherwise leak into attention masks)."""
    span = jnp.asarray(start, jnp.int32) + jnp.arange(length, dtype=jnp.int32)

    def copy(name, a, b):
        if name in _NON_RING_LEAVES or a.shape[2:] != b.shape[2:] \
                or a.ndim < 3:
            return a.at[:, row].set(b[:, 0].astype(a.dtype))
        idx = span % a.shape[2]
        return a.at[:, row, idx].set(b[:, 0, idx].astype(a.dtype))

    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[row].set(single[k][0])
        elif k == "xattn":
            out[k] = jax.tree.map(
                lambda a, b: a.at[:, row].set(b[:, 0].astype(a.dtype)),
                v, single[k])
        else:
            out[k] = {}
            for name in v:
                out[k][name] = (
                    {n: copy(n, v[name][n], single[k][name][n])
                     for n in v[name]}
                    if isinstance(v[name], dict)
                    else copy(name, v[name], single[k][name]))
    return out


# ---------------------------------------------------------------------------
# Ring-buffer writes.  All write helpers operate on a *single layer slice*
# (no leading stack dim) — model.py maps them over the stack inside scan.
# ---------------------------------------------------------------------------

def quantize_kv(k, v):
    """Per-token-per-head symmetric int8 quantization.
    k/v: (B, S, Hkv, D) -> dict of int8 values + f32 scales."""
    def q(x):
        scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        qx = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                      -127, 127).astype(jnp.int8)
        return qx, scale
    qk, sk = q(k)
    qv, sv = q(v)
    return {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}


def dequantize_kv(layer_cache: Dict):
    """Returns (k, v) in f32 from an int8 layer cache (jnp validation
    path; the TPU kernel dequantizes tile-wise in VMEM instead)."""
    k = layer_cache["k"].astype(jnp.float32) * \
        layer_cache["k_scale"][..., None]
    v = layer_cache["v"].astype(jnp.float32) * \
        layer_cache["v_scale"][..., None]
    return k, v


def write_prefill(layer_cache: Dict, new: Dict, seq_positions: jax.Array) -> Dict:
    """Write a full prefill chunk.  new[name]: (B, S, ...);
    seq_positions: (S,) absolute positions being written.  If S exceeds the
    ring width W (sliding-window layer), only the last W positions are kept
    so scatter indices stay unique."""
    out = dict(layer_cache)
    W = layer_cache["slot_pos"].shape[-1]
    S = seq_positions.shape[0]
    if S > W:
        new = {k: v[:, -W:] for k, v in new.items()}
        seq_positions = seq_positions[-W:]
    slots = seq_positions % W                                  # (S,)
    for name in new:
        buf = layer_cache[name]
        out[name] = buf.at[:, slots].set(new[name].astype(buf.dtype))
    B = layer_cache["slot_pos"].shape[0]
    sp = layer_cache["slot_pos"].at[:, slots].set(
        jnp.broadcast_to(seq_positions[None, :], (B, len(seq_positions))).astype(jnp.int32))
    out["slot_pos"] = sp
    return out


def write_decode(layer_cache: Dict, new: Dict, pos: jax.Array) -> Dict:
    """Write one token per row.  new[name]: (B, 1, ...); pos: (B,) absolute."""
    out = dict(layer_cache)
    W = layer_cache["slot_pos"].shape[-1]
    slots = (pos % W).astype(jnp.int32)                        # (B,)
    brow = jnp.arange(slots.shape[0])
    for name in new:
        buf = layer_cache[name]
        out[name] = buf.at[brow, slots].set(new[name][:, 0].astype(buf.dtype))
    out["slot_pos"] = layer_cache["slot_pos"].at[brow, slots].set(pos.astype(jnp.int32))
    return out

"""KV / SSM cache structures.

Caches are plain nested dicts (pytree-friendly, mirrors param structure):

  cache = {
    "pos":  (B,) int32 — current sequence length per row,
    "p{i}": per period-position stacked state, one of
        kv:  {"k": (P,B,W,Hkv,Dh), "v": ..., "slot_pos": (P,B,W) int32}
        mla: {"ckv": (P,B,W,kv_lora), "kr": (P,B,W,rope), "slot_pos": ...}
        ssm: {"conv": (P,B,cw-1,Cch), "state": (P,B,nh,hd,N)}
    "prologue": {...}     (when the arch has non-periodic leading layers)
    "xattn": {"k": (P,B,encS,H,Dh), "v": ...}   (whisper cross-attention)
  }

W is the ring-buffer width: ``min(window, max_seq)`` for sliding-window
layers, ``max_seq`` otherwise.  ``slot_pos`` stores the absolute position
held in each ring slot (-1 = empty), which makes masking exact for both
full and windowed layers without modular-arithmetic case analysis.

Block-granular paged pool (the ``r_c`` execution path): full-attention
kv/mla period positions can swap their per-slot dense rings for one
shared **arena** of fixed-size token blocks plus a
``(slot, logical_block) → physical_block`` page table
(``init_paged_arena`` / ``paged_view`` / ``write_decode_paged``; the
slot ops below are paged-aware).  A paged layer cache is recognized by
its ``page_table`` leaf; attention gathers a dense ring view of the
mapped blocks under the same ``slot_pos`` masking, so paged and dense
execution are bit-identical.  Sliding-window rings stay dense (the ring
already bounds their footprint at ``window``), as do SSM state and
encoder cross-attention.  The arena's last physical block is the
**trash block**: the scatter target for rows/positions with no mapped
block — its contents are never read, because gathers force
``slot_pos = -1`` wherever the page table is unmapped.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_MLA, ATTN_WINDOW, LayerSpec, ModelConfig


def layer_cache_width(cfg: ModelConfig, spec: LayerSpec, max_seq: int) -> int:
    if spec.attn == ATTN_WINDOW:
        return min(cfg.window_size, max_seq)
    return max_seq


def _spec_cache(cfg: ModelConfig, spec: LayerSpec, stack: int, batch: int,
                max_seq: int, dtype) -> Dict:
    kind = spec.cache_kind()
    if kind == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        cw = cfg.ssm_conv_width - 1
        return {
            "conv_x": jnp.zeros((stack, batch, cw, d_in), dtype),
            "conv_B": jnp.zeros((stack, batch, cw, cfg.ssm_state), dtype),
            "conv_C": jnp.zeros((stack, batch, cw, cfg.ssm_state), dtype),
            "state": jnp.zeros((stack, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                               jnp.float32),
        }
    W = layer_cache_width(cfg, spec, max_seq)
    if kind == "mla":
        return {
            "ckv": jnp.zeros((stack, batch, W, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((stack, batch, W, cfg.qk_rope_head_dim), dtype),
            "slot_pos": jnp.full((stack, batch, W), -1, jnp.int32),
        }
    if kind == "kv":
        if cfg.kv_dtype == "int8":
            Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
            return {
                "k": jnp.zeros((stack, batch, W, Hkv, Dh), jnp.int8),
                "v": jnp.zeros((stack, batch, W, Hkv, Dh), jnp.int8),
                "k_scale": jnp.zeros((stack, batch, W, Hkv), jnp.float32),
                "v_scale": jnp.zeros((stack, batch, W, Hkv), jnp.float32),
                "slot_pos": jnp.full((stack, batch, W), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((stack, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((stack, batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
            "slot_pos": jnp.full((stack, batch, W), -1, jnp.int32),
        }
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None, *, skip_keys=()) -> Dict:
    """`skip_keys` omits those period positions (the paged-pool engine
    allocates them as a shared block arena instead of per-slot rings)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: Dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    for i, spec in enumerate(cfg.period):
        if f"p{i}" in skip_keys:
            continue
        cache[f"p{i}"] = _spec_cache(cfg, spec, cfg.num_periods, batch,
                                     max_seq, dtype)
    if cfg.prologue:
        cache["prologue"] = _spec_cache(cfg, cfg.prologue[0],
                                        len(cfg.prologue), batch, max_seq, dtype)
    if cfg.encoder_layers:
        cache["xattn"] = {
            "k": jnp.zeros((cfg.num_periods, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.num_periods, batch, cfg.encoder_seq,
                            cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """ShapeDtypeStruct mirror of init_cache (no allocation, for dry-runs)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))


# ---------------------------------------------------------------------------
# Block-granular paged KV pool.  One shared arena of fixed-size token
# blocks replaces the per-slot dense rings of the pageable period
# positions; a (slot, logical_block) -> physical_block page table (managed
# host-side by core.blockpool, passed in as a device array) maps each
# slot's logical ring onto arena blocks.  Attention gathers a dense ring
# view (`paged_view`) so the math — and therefore greedy output — is
# bit-identical to the dense path.
# ---------------------------------------------------------------------------

_PAGED_KINDS = ("kv", "mla")

# Arena layout: **bt-major head-major tiling**.  Dense rings keep the
# token-major (B, W, Hkv, D) layout (one contiguous W-row per slot), but
# a token-major arena block tile (bt, Hkv, D) puts the tiny ``bt`` span
# on a leading tile axis — for ``bt < 8`` that wastes TPU sublanes and
# splits one head's slab across the whole block.  Arena kv leaves are
# therefore head-major, with the block axis *inside* the head axis:
#
#   k / v              (Hkv, NB+1, bt, D)     [stacked: (P, Hkv, NB+1, bt, D)]
#   k_scale / v_scale  (Hkv, NB+1, bt)
#   slot_pos           (NB+1, bt)             (no head axis)
#   ckv / kr (MLA)     (NB+1, bt, lat|dr)     (latents have no head axis)
#
# so one (block, head) DMA is a contiguous (bt, D) slab whose trailing
# (bt, D) tile maps onto (sublane, lane) natively, for every bt.  The
# helpers below are the single source of truth for which leaves carry
# the head-major layout and where each leaf's physical-block axis sits.

_HEAD_MAJOR = ("k", "v", "k_scale", "v_scale")


def arena_block_axis(name: str, *, stacked: bool = False) -> int:
    """Physical-block axis of an arena leaf (``stacked`` adds the leading
    period-stack axis the engine's shared arena carries)."""
    ax = 1 if name in _HEAD_MAJOR else 0
    return ax + 1 if stacked else ax


def retile_arena_leaf(name: str, a, *, stacked: bool = False):
    """Token-major block layout (…, NB, bt, Hkv[, D]) → the head-major
    arena layout above.  Identity for leaves without a head axis."""
    if name not in _HEAD_MAJOR:
        return a
    off = 1 if stacked else 0
    return jnp.moveaxis(a, off + 2, off)


def untile_arena_leaf(name: str, a, *, stacked: bool = False):
    """Inverse of ``retile_arena_leaf`` (head-major → token-major)."""
    if name not in _HEAD_MAJOR:
        return a
    off = 1 if stacked else 0
    return jnp.moveaxis(a, off, off + 2)


def _to_arena_tile(name, blk):
    """One dense-ring block tile (…, bt, Hkv[, D]) → the arena tile
    (…, Hkv, bt[, D]) for head-major leaves (identity otherwise).  The
    (bt, Hkv) pair sits at a fixed offset from the END, so this works
    with any number of leading stack/batch axes."""
    if name not in _HEAD_MAJOR:
        return blk
    ax_bt = blk.ndim - (3 if name in ("k", "v") else 2)
    return jnp.swapaxes(blk, ax_bt, ax_bt + 1)


def paged_period_keys(cfg: ModelConfig) -> tuple:
    """Period positions whose KV ring is block-pageable: full-attention
    kv/mla layers.  Sliding-window layers are exempt (their ring already
    bounds the footprint at `window`), as are SSM state (O(1)) and
    encoder cross-attention; prologue layers stay dense for simplicity."""
    return tuple(f"p{i}" for i, spec in enumerate(cfg.period)
                 if spec.cache_kind() in _PAGED_KINDS
                 and spec.attn != ATTN_WINDOW)


def init_paged_arena(cfg: ModelConfig, device_blocks: int,
                     block_tokens: int, dtype=None) -> Dict:
    """Shared physical-block arena for the pageable period positions:
    every data leaf of the dense layer cache with its per-slot ring
    (B, W, ...) replaced by (device_blocks + 1) blocks of `block_tokens`
    ring slots each, in the head-major bt-tiled layout (see
    ``arena_block_axis``).  Block index `device_blocks` is the trash
    block."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    arena: Dict = {}
    for key in paged_period_keys(cfg):
        spec = cfg.period[int(key[1:])]
        dense = _spec_cache(cfg, spec, cfg.num_periods,
                            device_blocks + 1, block_tokens, dtype)
        arena[key] = {name: retile_arena_leaf(name, a, stacked=True)
                      for name, a in dense.items()}
    return arena


def is_paged(layer_cache: Dict) -> bool:
    return "page_table" in layer_cache


def paged_view(layer_cache: Dict) -> Dict:
    """Gather a dense (B, W, ...) ring view of a paged layer cache slice
    (head-major arena leaves per ``arena_block_axis`` plus
    ``page_table`` (B, MB)), with W = MB * bt.  Logical block lb covers
    ring positions
    [lb*bt, (lb+1)*bt), exactly the dense ring's layout; unmapped blocks
    read the trash block but their slot_pos is forced to -1, so they are
    invisible to the validity masks.

    NOTE this is no longer the decode hot path: the page-table-native
    flash-decode kernels (kernels.paged_decode, dispatched through
    kernels.ops.paged_gqa_decode / paged_mla_decode) read the arena
    directly and gather only mapped blocks.  The dense view remains the
    ref oracle (the ops `ref` impl and the CPU `auto` path), the
    sequence-sharded combine's input, and a debugging aid."""
    pt = layer_cache["page_table"]                     # (B, MB)
    B, MB = pt.shape
    trash = layer_cache["slot_pos"].shape[0] - 1
    bt = layer_cache["slot_pos"].shape[1]
    mapped = pt >= 0
    idx = jnp.where(mapped, pt, trash)
    out = {}
    for name, a in layer_cache.items():
        if name == "page_table":
            continue
        ax = arena_block_axis(name)
        g = jnp.take(a, idx.reshape(-1), axis=ax)
        if ax:       # head-major: (Hkv, B·MB, bt, …) → (B·MB, bt, Hkv, …)
            g = jnp.moveaxis(g, 0, 2)
        g = g.reshape((B, MB) + g.shape[1:])
        if name == "slot_pos":
            g = jnp.where(mapped[:, :, None], g, -1)
        out[name] = g.reshape((B, MB * bt) + g.shape[3:])
    return out


def decode_scatter_target(layer_cache: Dict, pos: jax.Array):
    """The one-token decode scatter's coordinates: (pb, off) — each row's
    physical block (trash where unmapped) and in-block offset for ring
    position ``pos % W``.  Shared by ``write_decode_paged`` and the fused
    decode-write dispatchers in ``kernels.ops``."""
    pt = layer_cache["page_table"]                     # (B, MB)
    MB = pt.shape[1]
    trash = layer_cache["slot_pos"].shape[0] - 1
    bt = layer_cache["slot_pos"].shape[1]
    i = (pos % (MB * bt)).astype(jnp.int32)            # (B,) ring index
    lb = i // bt
    off = i % bt
    pb = jnp.take_along_axis(pt, lb[:, None], axis=1)[:, 0]
    return jnp.where(pb >= 0, pb, trash), off


def _decode_scatter(layer_cache: Dict, new: Dict, pos: jax.Array) -> Dict:
    pb, off = decode_scatter_target(layer_cache, pos)
    out = dict(layer_cache)
    for name in new:
        buf = layer_cache[name]
        tok = new[name][:, 0].astype(buf.dtype)        # (B, Hkv[, D]) | (B, r)
        if name in _HEAD_MAJOR:
            out[name] = buf.at[:, pb, off].set(jnp.moveaxis(tok, 0, 1))
        else:
            out[name] = buf.at[pb, off].set(tok)
    out["slot_pos"] = layer_cache["slot_pos"].at[pb, off].set(
        pos.astype(jnp.int32))
    return out


def write_decode_paged(layer_cache: Dict, new: Dict, pos: jax.Array) -> Dict:
    """Paged analogue of `write_decode`: scatter one token per row into
    the arena block its page table maps for ring position pos % W.  Rows
    with no mapped block there (masked/free slots) scatter into the
    trash block instead — harmless by construction.

    NOTE this is no longer dispatched on the paged decode hot path: the
    fused decode-write dispatchers (``kernels.ops.paged_gqa_decode_fused``
    / ``paged_mla_decode_fused``) perform the identical scatter inside
    the same compiled step as the attention kernel.  It remains the
    sharded-combine path's write and the standalone scatter primitive."""
    return _decode_scatter(layer_cache, new, pos)


# ---------------------------------------------------------------------------
# Slot-pool operations.  A cache allocated once with batch = number of slots
# is treated as a pool of independent per-row "slots": a finished row can be
# reset and refilled with a new request without touching its neighbors
# (continuous batching).  Batch is axis 0 for "pos" and axis 1 (after the
# layer-stack axis) for every other leaf.
# ---------------------------------------------------------------------------

def _map_named_leaves(tree: Dict, fn) -> Dict:
    """Map fn(leaf_name, leaf) over a nested-dict pytree, keeping names."""
    out = {}
    for k, v in tree.items():
        out[k] = _map_named_leaves(v, fn) if isinstance(v, dict) else fn(k, v)
    return out


def reset_slot(cache: Dict, row) -> Dict:
    """Return `cache` with batch row `row` restored to its init_cache state
    (slot_pos = -1, pos = 0, zeros elsewhere) and all other rows untouched.
    `row` may be a traced scalar, so one jit covers every slot.  Paged
    groups are left alone: a freed slot maps no arena blocks (the block
    pool released them on drain), and fresh allocations clear their
    slot_pos plane at map time."""
    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[row].set(0)
        elif isinstance(v, dict) and is_paged(v):
            out[k] = v
        else:
            out[k] = _map_named_leaves(
                v, lambda name, a: a.at[:, row].set(
                    jnp.asarray(-1 if name == "slot_pos" else 0, a.dtype)))
    return out


def _insert_row_blocks(group: Dict, single_group: Dict, row, src) -> Dict:
    """Copy a dense ring row of `single_group` into the arena blocks the
    page table maps for slot `row`: one static loop over the slot's
    logical blocks, each landing in its physical block (or the trash
    block where unmapped — content discarded, exactly what the dense
    ring's unwritten slot_pos=-1 span represents)."""
    pt = group["page_table"][0, row]                   # (MB,) layer-invariant
    MB = pt.shape[0]
    trash = group["slot_pos"].shape[1] - 1
    bt = group["slot_pos"].shape[2]
    out = dict(group)
    for lb in range(MB):
        pb = jnp.where(pt[lb] >= 0, pt[lb], trash)
        for name, a in group.items():
            if name == "page_table":
                continue
            blk = single_group[name][:, src, lb * bt:(lb + 1) * bt]
            tile = _to_arena_tile(name, blk.astype(a.dtype))
            if name in _HEAD_MAJOR:
                out[name] = out[name].at[:, :, pb].set(tile)
            else:
                out[name] = out[name].at[:, pb].set(tile)
    return out


def insert_slot(cache: Dict, single: Dict, row, src=0) -> Dict:
    """Slot-indexed prefill write: copy batch row `src` of `single` (a
    dense cache, e.g. freshly prefilled for one request) into batch row
    `row` of the pooled `cache`.  Only that row changes.  Paged groups
    scatter the dense ring into the slot's mapped arena blocks (the block
    pool must have mapped blocks covering the row's footprint first)."""
    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[row].set(single[k][src])
        elif isinstance(v, dict) and is_paged(v):
            out[k] = _insert_row_blocks(v, single[k], row, src)
        else:
            out[k] = jax.tree.map(
                lambda a, b: a.at[:, row].set(b[:, src].astype(a.dtype)),
                v, single[k])
    return out


# leaves whose third axis is NOT the ring (SSM recurrent state / conv tails
# and whisper cross-attention KV over encoder positions): a span copy makes
# no sense for them, so partial inserts copy the whole row instead
_NON_RING_LEAVES = ("conv_x", "conv_B", "conv_C", "state")


def insert_slot_span(cache: Dict, single: Dict, row, start,
                     *, length: int) -> Dict:
    """Partial slot insert at a row offset: copy only the ring slots
    holding absolute positions [start, start + length) of batch row 0 of
    `single` into batch row `row` of the pooled `cache` (plus `single`'s
    row-0 pos).  This is the chunked-prefill admission path — each staged
    prefill chunk lands in the pool as soon as it is computed instead of
    one whole-row copy at the end, so per-tick work stays bounded.

    `length` must be static (one jit specialization per chunk-width
    bucket); `start` may be traced.  Ring indices are taken modulo each
    leaf's own ring width, so sliding-window layers wrap correctly.
    NOTE unlike `insert_slot`, a span write does not clear the rest of the
    row — callers must `reset_slot` the target row once before the first
    span of a new request (stale `slot_pos` entries from the previous
    occupant would otherwise leak into attention masks).  Paged groups
    instead copy only the arena blocks the span overlaps (whole blocks:
    the scratch ring is the source of truth for the slot's entire prefix,
    so re-copying a block's pre-span part is an idempotent overwrite)."""
    span = jnp.asarray(start, jnp.int32) + jnp.arange(length, dtype=jnp.int32)

    def copy(name, a, b):
        if name in _NON_RING_LEAVES or a.shape[2:] != b.shape[2:] \
                or a.ndim < 3:
            return a.at[:, row].set(b[:, 0].astype(a.dtype))
        idx = span % a.shape[2]
        return a.at[:, row, idx].set(b[:, 0, idx].astype(a.dtype))

    def copy_paged(group, single_group):
        pt = group["page_table"][0, row]               # (MB,)
        MB = pt.shape[0]
        trash = group["slot_pos"].shape[1] - 1
        bt = group["slot_pos"].shape[2]
        s0 = jnp.asarray(start, jnp.int32)
        first = s0 // bt
        out_g = dict(group)
        # blocks the span can overlap, in unwrapped coordinates; the ring
        # index lb % MB matches the dense branch's `span % W` wrap.  The
        # MB cap keeps scatter targets unique (spans longer than the ring
        # would revisit a block; the dense branch degrades identically).
        for j in range(min(length // bt + 2, MB)):
            lb = first + j
            lb_c = lb % MB
            pb = jnp.take(pt, lb_c)
            hit = ((pb >= 0)
                   & (lb * bt < s0 + length) & ((lb + 1) * bt > s0))
            pb = jnp.where(hit, pb, trash)
            for name, a in group.items():
                if name == "page_table":
                    continue
                blk = jax.lax.dynamic_slice_in_dim(
                    single_group[name], lb_c * bt, bt, axis=2)[:, 0]
                tile = _to_arena_tile(name, blk.astype(a.dtype))
                if name in _HEAD_MAJOR:
                    out_g[name] = out_g[name].at[:, :, pb].set(tile)
                else:
                    out_g[name] = out_g[name].at[:, pb].set(tile)
        return out_g

    out = {}
    for k, v in cache.items():
        if k == "pos":
            out[k] = v.at[row].set(single[k][0])
        elif k == "xattn":
            out[k] = jax.tree.map(
                lambda a, b: a.at[:, row].set(b[:, 0].astype(a.dtype)),
                v, single[k])
        elif isinstance(v, dict) and is_paged(v):
            out[k] = copy_paged(v, single[k])
        else:
            out[k] = {}
            for name in v:
                out[k][name] = (
                    {n: copy(n, v[name][n], single[k][name][n])
                     for n in v[name]}
                    if isinstance(v[name], dict)
                    else copy(name, v[name], single[k][name]))
    return out


# ---------------------------------------------------------------------------
# Window composition (module-based batching).  A decode *window* runs G
# rotation groups through one combined forward: the engine concatenates
# the groups' slot-pool caches on the batch axis, dispatches a (G·B)-row
# decode chunk, and splits the result back per group.  Batch is axis 0
# for "pos" and axis 1 for every other leaf (after the layer-stack axis),
# exactly the slot-pool convention above.  Paged-KV groups must NOT pass
# through these helpers (their arena leaves have no batch axis) — the
# engine composes the shared arena once with a multi-row page table and
# strips it before splitting.
# ---------------------------------------------------------------------------

def _batch_axis(path) -> int:
    return 0 if path and getattr(path[-1], "key", None) == "pos" else 1


def concat_slot_caches(caches):
    """Concatenate per-group slot caches batch-wise into one window cache
    (group-major: window row g*B + b is group g's slot b)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, *leaves: jnp.concatenate(leaves, axis=_batch_axis(path)),
        *caches)


def split_slot_cache(cache: Dict, n: int):
    """Inverse of `concat_slot_caches`: split a window cache back into
    `n` equal per-group slot caches."""
    splits = jax.tree_util.tree_map_with_path(
        lambda path, leaf: jnp.split(leaf, n, axis=_batch_axis(path)), cache)
    return [jax.tree.map(lambda s: s[g], splits,
                         is_leaf=lambda x: isinstance(x, list))
            for g in range(n)]


# ---------------------------------------------------------------------------
# Ring-buffer writes.  All write helpers operate on a *single layer slice*
# (no leading stack dim) — model.py maps them over the stack inside scan.
# ---------------------------------------------------------------------------

def quantize_kv(k, v):
    """Per-token-per-head symmetric int8 quantization.
    k/v: (B, S, Hkv, D) -> dict of int8 values + f32 scales."""
    def q(x):
        scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        qx = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                      -127, 127).astype(jnp.int8)
        return qx, scale
    qk, sk = q(k)
    qv, sv = q(v)
    return {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}


def dequantize_kv(layer_cache: Dict):
    """Returns (k, v) in f32 from an int8 layer cache (jnp validation
    path; the TPU kernel dequantizes tile-wise in VMEM instead)."""
    k = layer_cache["k"].astype(jnp.float32) * \
        layer_cache["k_scale"][..., None]
    v = layer_cache["v"].astype(jnp.float32) * \
        layer_cache["v_scale"][..., None]
    return k, v


def write_prefill(layer_cache: Dict, new: Dict, seq_positions: jax.Array) -> Dict:
    """Write a full prefill chunk.  new[name]: (B, S, ...);
    seq_positions: (S,) absolute positions being written.  If S exceeds the
    ring width W (sliding-window layer), only the last W positions are kept
    so scatter indices stay unique."""
    out = dict(layer_cache)
    W = layer_cache["slot_pos"].shape[-1]
    S = seq_positions.shape[0]
    if S > W:
        new = {k: v[:, -W:] for k, v in new.items()}
        seq_positions = seq_positions[-W:]
    slots = seq_positions % W                                  # (S,)
    for name in new:
        buf = layer_cache[name]
        out[name] = buf.at[:, slots].set(new[name].astype(buf.dtype))
    B = layer_cache["slot_pos"].shape[0]
    sp = layer_cache["slot_pos"].at[:, slots].set(
        jnp.broadcast_to(seq_positions[None, :], (B, len(seq_positions))).astype(jnp.int32))
    out["slot_pos"] = sp
    return out


def write_decode(layer_cache: Dict, new: Dict, pos: jax.Array) -> Dict:
    """Write one token per row.  new[name]: (B, 1, ...); pos: (B,) absolute."""
    out = dict(layer_cache)
    W = layer_cache["slot_pos"].shape[-1]
    slots = (pos % W).astype(jnp.int32)                        # (B,)
    brow = jnp.arange(slots.shape[0])
    for name in new:
        buf = layer_cache[name]
        out[name] = buf.at[brow, slots].set(new[name][:, 0].astype(buf.dtype))
    out["slot_pos"] = layer_cache["slot_pos"].at[brow, slots].set(pos.astype(jnp.int32))
    return out

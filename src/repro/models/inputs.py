"""ShapeDtypeStruct input specs for every (architecture × shape) cell, plus
concrete random-input builders for smoke tests.

``input_specs`` returns exactly the kwargs that ``train_step`` /
``prefill_step`` / ``serve_step`` are lowered with — weak-type-correct,
shardable, no device allocation.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import kvcache


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def modality_specs(cfg: ModelConfig, batch: int) -> Dict:
    """Stubbed modality-frontend inputs (precomputed embeddings)."""
    extra = {}
    dt = jnp.dtype(cfg.dtype)
    if cfg.encoder_layers:
        extra["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.vision_tokens:
        extra["patches"] = _sds((batch, cfg.vision_tokens, cfg.d_model), dt)
    return extra


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract inputs for the step function implied by shape.mode."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        specs = {"tokens": _sds((B, S), jnp.int32),
                 "targets": _sds((B, S), jnp.int32)}
        specs.update(modality_specs(cfg, B))
        return specs
    if shape.mode == "prefill":
        specs = {"tokens": _sds((B, S), jnp.int32)}
        specs.update(modality_specs(cfg, B))
        return specs
    if shape.mode == "decode":
        # one new token against a cache of length seq_len
        cache = kvcache.abstract_cache(cfg, B, S)
        specs = {"tokens": _sds((B, 1), jnp.int32), "cache": cache}
        if cfg.encoder_layers:
            # decode still cross-attends the (cached) encoder KV
            pass
        return specs
    raise ValueError(shape.mode)


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> Dict:
    """Random concrete inputs matching input_specs (smoke-test scale only)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        if name == "cache":
            cache = kvcache.init_cache(cfg, shape.global_batch, shape.seq_len)
            # pretend the cache is half full
            cache["pos"] = jnp.full((shape.global_batch,), shape.seq_len // 2,
                                    jnp.int32)
            out[name] = cache
        elif spec.dtype == jnp.int32:
            out[name] = jnp.asarray(
                rng.integers(0, max(cfg.vocab_size, 2), spec.shape), jnp.int32)
        else:
            out[name] = jnp.asarray(rng.normal(0, 1, spec.shape), spec.dtype)
    return out

"""Attention blocks: GQA (full / sliding-window, softcap, bias) and
DeepSeek-style MLA, with prefill and ring-buffer decode paths.

Decode attention is expressed through *partials* (unnormalized output,
running max, running denominator) so the sequence-sharded distributed path
(distributed/collectives.py) can combine shards with a log-sum-exp psum —
the TPU adaptation of the paper's CPU attention (compute where the KV
lives, move only q/o).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_MLA, ATTN_WINDOW, LayerSpec, ModelConfig
from repro.kernels import ops
from repro.models import kvcache
from repro.models.common import (NEG_INF, apply_rope, chunked_attention,
                                 rmsnorm, softcap)


# ---------------------------------------------------------------------------
# Decode attention over a ring cache, via partials
# ---------------------------------------------------------------------------

def attention_partials(q, k, v, valid, *, scale: float,
                       attn_softcap: float = 0.0,
                       k_scale=None, v_scale=None):
    """q: (B,H,D), k/v: (B,W,Hkv,Dv), valid: (B,W) bool.
    Returns (o_unnorm (B,H,Dv) f32, m (B,H) f32, l (B,H) f32).

    int8 KV passes its per-(token, head) ``k_scale``/``v_scale`` planes
    ((B,W,Hkv) f32) and the dequant folds into the tiles —
    ``s = (q · k_int) · k_scale`` and ``o = (p · v_scale) @ v_int`` — so
    no dequantized f32 ring is ever materialized (the Pallas kernels
    apply the same per-block folding)."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bwhd->bhgw", qf, k.astype(jnp.float32))
    if k_scale is not None:
        s = s * jnp.swapaxes(k_scale, 1, 2)[:, :, None, :]
    s = softcap(s, attn_softcap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    # guard: a shard may hold zero valid slots
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None]) * (s > NEG_INF / 2)
    l = jnp.sum(p, axis=-1)
    if v_scale is not None:
        p = p * jnp.swapaxes(v_scale, 1, 2)[:, :, None, :]
    o = jnp.einsum("bhgw,bwhd->bhgd", p, v.astype(jnp.float32))
    Dv = v.shape[-1]
    return o.reshape(B, H, Dv), m_safe.reshape(B, H), l.reshape(B, H)


def combine_partials(o, m, l):
    """Normalize partials (single shard)."""
    return o / jnp.maximum(l[..., None], 1e-30)


def decode_valid_mask(slot_pos, pos, window: int):
    """slot_pos: (B,W) absolute positions in ring slots; pos: (B,) current
    query position.  Valid = written & causal (& within window)."""
    v = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        v &= slot_pos > (pos[:, None] - window)
    return v


def chunk_valid_mask(slot_pos, q_positions, window: int):
    """Multi-query variant for chunked prefill: q_positions (B,S) absolute
    query positions; returns (B,S,W).  Because the chunk's own KV is
    written into the ring *before* attention, intra-chunk causality falls
    out of the same slot_pos <= q_pos test as history does."""
    sp = slot_pos[:, None, :]
    v = (sp >= 0) & (sp <= q_positions[:, :, None])
    if window:
        v &= sp > (q_positions[:, :, None] - window)
    return v


def chunk_attention_ring(q, k, v, valid, *, scale: float,
                         attn_softcap: float = 0.0,
                         k_scale=None, v_scale=None):
    """Chunked-prefill attention: S chunk queries against the full ring.
    q: (B,S,H,D); k/v: (B,W,Hkv,Dv); valid: (B,S,W) bool.
    Returns (B,S,H,Dv) f32 — the multi-query generalization of
    attention_partials + combine_partials.  int8 ring history passes
    ``k_scale``/``v_scale`` ((B,W,Hkv) f32) and the dequant folds into
    the score/value contractions tile-wise, same as attention_partials —
    the overlap mode's decode-vs-chunk reads never build an f32 ring."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qf = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, g, D)
    s = jnp.einsum("bshgd,bwhd->bshgw", qf, k.astype(jnp.float32))
    if k_scale is not None:
        s = s * jnp.swapaxes(k_scale, 1, 2)[:, None, :, None, :]
    s = softcap(s, attn_softcap)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None]) * (s > NEG_INF / 2)
    l = jnp.sum(p, axis=-1)
    if v_scale is not None:
        p = p * jnp.swapaxes(v_scale, 1, 2)[:, None, :, None, :]
    o = jnp.einsum("bshgw,bwhd->bshgd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, S, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def _proj(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def gqa_forward(cfg: ModelConfig, spec: LayerSpec, p: Dict, x,
                positions, *, cache: Optional[Dict], mode: str,
                pos: Optional[jax.Array] = None, sharded_fn=None,
                kv_override: Optional[Tuple] = None, causal: bool = True,
                paged_impl: str = "auto"):
    """x: (B,S,E). mode: 'full' (train/prefill w/ optional cache write) or
    'decode' (S==1, read+write ring cache).  Returns (out, new_layer_cache).

    kv_override: (k, v) already-built KV (whisper cross-attention).
    paged_impl: kernel dispatch for paged-cache decode
    (ops.paged_gqa_decode: auto | pallas | interpret | ref)."""
    B, S, E = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = cfg.query_scale or Dh ** -0.5
    # cfg.window_size is authoritative (smoke() rescales it; spec.window is
    # structural documentation) — it also sizes the ring cache.
    window = cfg.window_size if spec.attn == ATTN_WINDOW else 0

    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, H, Dh)
    if kv_override is None:
        k = _proj(x, p["wk"], p.get("bk")).reshape(B, S, Hkv, Dh)
        v = _proj(x, p["wv"], p.get("bv")).reshape(B, S, Hkv, Dh)
        if cfg.pos == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if cfg.pos == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)

    quantized = cfg.kv_dtype == "int8" and kv_override is None
    new_cache = cache
    if mode == "decode":
        assert S == 1 and cache is not None
        new = kvcache.quantize_kv(k, v) if quantized else {"k": k, "v": v}
        kw = dict(scale=scale, attn_softcap=cfg.attn_softcap)
        paged = kvcache.is_paged(cache)
        if paged and sharded_fn is None:
            # block-paged pool, hot path: fused decode-write — one compiled
            # step scatters the fresh token through the page table AND
            # attends over it (the kernel merges the token into its target
            # block's tile in-register, so no separate write dispatch
            # precedes attention; ref impl = scatter + the old paged_view
            # oracle, kept as the bit-reference and CPU execution path)
            part, new_cache = ops.paged_gqa_decode_fused(
                q[:, 0], cache, new, pos, window=window,
                impl=paged_impl, **kw)
            o = combine_partials(*part)
        else:
            new_cache = (kvcache.write_decode_paged(cache, new, pos)
                         if paged else kvcache.write_decode(cache, new, pos))
            # sequence-sharded combine consumes a dense ring view
            ring = kvcache.paged_view(new_cache) if paged else new_cache
            valid = decode_valid_mask(ring["slot_pos"], pos, window)
            if quantized and sharded_fn is not None:
                # sharded_fn's contract has no scale planes: fall back to
                # the dequantized ring for the distributed combine
                kc, vc = kvcache.dequantize_kv(ring)
                args = (q[:, 0], kc, vc, valid)
            else:
                args = (q[:, 0], ring["k"], ring["v"], valid)
                if quantized:
                    kw.update(k_scale=ring["k_scale"],
                              v_scale=ring["v_scale"])
            if sharded_fn is not None:
                o = sharded_fn(*args, **kw)
            else:
                o = combine_partials(*attention_partials(*args, **kw))
        o = o[:, None].astype(x.dtype)                      # (B,1,H,Dh)
    elif mode == "chunk":
        # chunked prefill at a row offset: write this chunk's KV into the
        # ring at its absolute positions, then attend the chunk's queries
        # against the whole ring (history + the chunk itself) under the
        # slot_pos validity mask.  Padded chunk tail positions are clamped
        # by the caller to one-past-the-end, so they land in a single slot
        # that stays causally masked until decode overwrites it.  Prefill
        # always runs on a dense scratch; the paged pool is written by
        # the slot-insert ops, never by prefill directly.
        assert cache is not None and kv_override is None
        assert not kvcache.is_paged(cache)
        new = kvcache.quantize_kv(k, v) if quantized else {"k": k, "v": v}
        # admission chunks run on a batch-1 scratch (or rows sharing one
        # offset), so the ring scatter uses row 0's positions
        new_cache = kvcache.write_prefill(cache, new,
                                          positions[0].astype(jnp.int32))
        valid = chunk_valid_mask(new_cache["slot_pos"], positions, window)
        ckw = {}
        if quantized:        # per-tile dequant: no f32 ring materialized
            ckw = dict(k_scale=new_cache["k_scale"],
                       v_scale=new_cache["v_scale"])
        o = chunk_attention_ring(q, new_cache["k"], new_cache["v"], valid,
                                 scale=scale, attn_softcap=cfg.attn_softcap,
                                 **ckw)
        o = o.astype(x.dtype)                               # (B,S,H,Dh)
    elif kv_override is not None:
        # cross-attention (non-causal over encoder positions)
        o = chunked_attention(q, k, v, causal=False, scale=scale,
                              attn_softcap=cfg.attn_softcap)
    else:
        # full-sequence forward always begins at absolute position 0
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              attn_softcap=cfg.attn_softcap, scale=scale)
        if cache is not None:    # prefill: persist KV into the ring
            seq_pos = (positions if positions.ndim == 1
                       else positions[0]).astype(jnp.int32)
            new = kvcache.quantize_kv(k, v) if quantized else {"k": k, "v": v}
            new_cache = kvcache.write_prefill(cache, new, seq_pos)
    out = _proj(o.reshape(B, S, H * Dh), p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3).
#
# Prefill uses the naive (decompressed) form; decode uses the *absorbed*
# form — W_uk folded into the query and W_uv applied after attention over
# the latent cache — so the per-token cache is kv_lora+rope bytes and the
# decode matvecs run against the compressed latents.  test_layers asserts
# the two forms agree.
# ---------------------------------------------------------------------------

def mla_forward(cfg: ModelConfig, spec: LayerSpec, p: Dict, x,
                positions, *, cache: Optional[Dict], mode: str,
                pos: Optional[jax.Array] = None, sharded_fn=None,
                causal: bool = True, paged_impl: str = "auto"):
    B, S, E = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (dn + dr) ** -0.5

    cq = rmsnorm(_proj(x, p["wdq"]), p["q_norm"], cfg.norm_eps)
    q = _proj(cq, p["wuq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rmsnorm(_proj(x, p["wdkv"]), p["kv_norm"], cfg.norm_eps)  # (B,S,r)
    kr = _proj(x, p["wkr"]).reshape(B, S, 1, dr)
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0]         # (B,S,dr)

    wuk = p["wuk"].reshape(cfg.kv_lora_rank, H, dn)
    wuv = p["wuv"].reshape(cfg.kv_lora_rank, H, dv)

    new_cache = cache
    if mode == "decode":
        assert S == 1 and cache is not None
        # absorbed queries: q_lat (B,H,r) = q_nope @ W_uk^T
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                           wuk.astype(jnp.float32))
        # fold the rope part in by concatenating along the "latent" dim:
        # score = q_lat . ckv + q_rope . kr
        qcat = jnp.concatenate([q_lat, q_rope[:, 0].astype(jnp.float32)], -1)
        paged = kvcache.is_paged(cache)
        new = {"ckv": ckv, "kr": kr}
        if paged and sharded_fn is None:
            # paged hot path, fused decode-write: the MLA kernel gathers
            # the latent + rope leaves per mapped block through the page
            # table and merges the fresh latent in-register — no separate
            # scatter dispatch, no concatenated dense ring
            part, new_cache = ops.paged_mla_decode_fused(
                qcat.astype(x.dtype), cache, new, pos, scale=scale,
                lat=cfg.kv_lora_rank, impl=paged_impl)
            o_lat = combine_partials(*part)
        else:
            new_cache = (kvcache.write_decode_paged(cache, new, pos)
                         if paged else kvcache.write_decode(cache, new, pos))
            ring = kvcache.paged_view(new_cache) if paged else new_cache
            valid = decode_valid_mask(ring["slot_pos"], pos, 0)
            kcat = jnp.concatenate([ring["ckv"], ring["kr"]],
                                   -1)[:, :, None, :]           # (B,W,1,r+dr)
            kw = dict(scale=scale, attn_softcap=0.0)
            args = (qcat.astype(x.dtype), kcat.astype(x.dtype),
                    ring["ckv"][:, :, None, :], valid)
            if sharded_fn is not None:
                o_lat = sharded_fn(*args, **kw)
            else:
                o_lat = combine_partials(*attention_partials(*args, **kw))
        # o_lat: (B,H,r) attention-weighted latents; decompress with W_uv
        o = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
        o = o[:, None].astype(x.dtype)                          # (B,1,H,dv)
    elif mode == "chunk":
        # chunked prefill: persist this chunk's latents at their absolute
        # positions, then run the naive (decompressed) form over the ring
        assert cache is not None
        new_cache = kvcache.write_prefill(cache, {"ckv": ckv, "kr": kr},
                                          positions[0].astype(jnp.int32))
        ckv_r = new_cache["ckv"].astype(jnp.float32)            # (B,W,r)
        k_nope_r = jnp.einsum("bwr,rhd->bwhd", ckv_r,
                              wuk.astype(jnp.float32))
        v_r = jnp.einsum("bwr,rhd->bwhd", ckv_r, wuv.astype(jnp.float32))
        W = ckv_r.shape[1]
        kr_r = jnp.broadcast_to(new_cache["kr"][:, :, None, :],
                                (B, W, H, dr)).astype(jnp.float32)
        k_r = jnp.concatenate([k_nope_r, kr_r], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        valid = chunk_valid_mask(new_cache["slot_pos"], positions, 0)
        o = chunk_attention_ring(qfull, k_r, v_r, valid,
                                 scale=scale).astype(x.dtype)
    else:
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, wuk.astype(ckv.dtype))
        v = jnp.einsum("bsr,rhd->bshd", ckv, wuv.astype(ckv.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, dr))], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        o = chunked_attention(qfull, k, v, causal=causal, scale=scale)
        if cache is not None:
            seq_pos = (positions if positions.ndim == 1
                       else positions[0]).astype(jnp.int32)
            new_cache = kvcache.write_prefill(cache, {"ckv": ckv, "kr": kr},
                                              seq_pos)
    out = _proj(o.reshape(B, S, H * dv), p["wo"])
    return out, new_cache


def attn_forward(cfg, spec, p, x, positions, **kw):
    if spec.attn == ATTN_MLA:
        return mla_forward(cfg, spec, p, x, positions, **kw)
    return gqa_forward(cfg, spec, p, x, positions, **kw)

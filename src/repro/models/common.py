"""Shared model building blocks: norms, activations, RoPE, softcap,
memory-efficient (flash-style) chunked attention in pure jnp.

Everything here is a pure function over explicit parameter dicts; no module
framework is used (flax is unavailable offline and unnecessary).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float, offset: float = 0.0):
    """RMSNorm; gemma-style uses (1 + w) which callers get via offset=1."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * (offset + weight.astype(jnp.float32))
    return x.astype(dt)


def layernorm(x, weight, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(cfg: ModelConfig, p: Optional[dict], x):
    """Dispatch on cfg.norm. `p` is the norm's param dict (may be empty)."""
    if cfg.norm == "rmsnorm":
        offset = 1.0 if cfg.scale_embeddings else 0.0  # gemma family: (1+w)
        return rmsnorm(x, p["scale"], cfg.norm_eps, offset=offset)
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    if cfg.norm == "nonparametric_ln":
        return layernorm(x, None, None, cfg.norm_eps)
    raise ValueError(cfg.norm)


# ---------------------------------------------------------------------------
# Activations / softcap
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "gelu_mlp": functools.partial(jax.nn.gelu, approximate=True)}[name]


def softcap(x, cap: float):
    """gemma2 logit soft-capping: cap * tanh(x / cap). No-op when cap==0."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                            # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    """Whisper-style sinusoidal embeddings computed on the fly."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Memory-efficient chunked attention (flash-style, pure jnp).
#
# This is the prefill/train attention path: it never materializes the full
# (S x S) score matrix — it scans KV chunks with a running (max, sumexp)
# pair, which is what keeps the 32k-prefill dry-run memory bounded and what
# an on-TPU Pallas flash kernel would do tile-by-tile in VMEM.
# ---------------------------------------------------------------------------

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      attn_softcap: float = 0.0, scale: Optional[float] = None,
                      q_offset=0, kv_len: Optional[jax.Array] = None,
                      chunk: int = 1024):
    """Grouped-query chunked attention.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, Dv-compatible). Hq % Hkv == 0.
    q_offset: absolute position of q[0] (int or array) for causal masking
      against an already-populated KV cache.
    kv_len: optional (B,) valid-length mask for the KV sequence.
    Returns (B, Sq, Hq, Dv).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, group, D)

    nchunks = -(-Skv // chunk)
    pad = nchunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, Hkv, -1)
    vc = v.reshape(B, nchunks, chunk, Hkv, Dv)

    q_pos = q_offset + jnp.arange(Sq)                          # (Sq,)
    if kv_len is None:
        kv_len_arr = jnp.full((B,), Skv, dtype=jnp.int32)
    else:
        kv_len_arr = kv_len.astype(jnp.int32)

    def body(carry, inputs):
        m, l, o = carry                                        # running stats
        ci, kci, vci = inputs
        kv_pos = ci * chunk + jnp.arange(chunk)                # (chunk,)
        # scores: (B, Sq, Hkv, group, chunk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kci.astype(jnp.float32))
        s = softcap(s, attn_softcap)
        mask = kv_pos[None, :] < kv_len_arr[:, None]           # (B, chunk)
        mask = mask[:, None, :]                                # (B, 1, chunk)
        if causal:
            cm = kv_pos[None, :] <= q_pos[:, None]             # (Sq, chunk)
            if window:
                cm &= kv_pos[None, :] > (q_pos[:, None] - window)
            mask = mask & cm[None, :, :]
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vci.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Sq, Hkv, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), jnp.float32)
    o0 = jnp.zeros((B, Sq, Hkv, group, Dv), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (jnp.arange(nchunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def attention_reference(q, k, v, *, causal: bool, window: int = 0,
                        attn_softcap: float = 0.0, scale=None, q_offset=0,
                        kv_len=None):
    """O(S^2)-materializing oracle used only in tests."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, group, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    s = softcap(s, attn_softcap)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    if kv_len is None:
        mask = jnp.ones((B, Skv), bool)
    else:
        mask = kv_pos[None, :] < kv_len[:, None]
    mask = mask[:, None, :]
    if causal:
        cm = kv_pos[None, :] <= q_pos[:, None]
        if window:
            cm &= kv_pos[None, :] > (q_pos[:, None] - window)
        mask = mask & cm[None, :, :]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dv).astype(q.dtype)

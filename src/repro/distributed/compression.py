"""Gradient compression with error feedback, for the slow (DCN / 'pod')
all-reduce in multi-pod training.

int8 path: per-tensor symmetric quantization, all-reduce in int32 (exact
sum of quantized values), dequantize, with the quantization residual fed
back into the next step (error feedback keeps SGD convergence — Karimireddy
et al. 2019).  bf16 path: simple downcast-allreduce-upcast.

Compression only applies to the cross-pod hop; the intra-pod reduction
stays full precision (ICI is cheap, DCN is not).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grad, axis_name: str, *, method: str = "int8",
                    error: Optional[jax.Array] = None):
    """psum `grad` over `axis_name` in compressed form.
    Returns (reduced_grad, new_error).  Call inside shard_map/pmap."""
    g = grad.astype(jnp.float32)
    if error is not None:
        g = g + error
    if method == "int8":
        # shared scale across the axis so the int32 sum is exact
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_error = g - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out = total.astype(jnp.float32) * scale
    elif method == "bf16":
        c = g.astype(jnp.bfloat16)
        new_error = g - c.astype(jnp.float32)
        out = jax.lax.psum(c, axis_name).astype(jnp.float32)
    else:
        out = jax.lax.psum(g, axis_name)
        new_error = jnp.zeros_like(g)
    return out, new_error


def tree_compressed_psum(grads, axis_name: str, method: str = "int8",
                         errors=None):
    """Apply compressed_psum over a pytree, threading error-feedback state."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = (jax.tree.leaves(errors) if errors is not None
            else [None] * len(leaves))
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        o, ne = compressed_psum(g, axis_name, method=method, error=e)
        outs.append(o)
        new_errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)

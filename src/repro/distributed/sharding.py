"""Sharding plans: logical-axis rules → PartitionSpecs per (arch × shape ×
mesh), plus the wired ExecPolicy (MoE path, sharded decode attention).

Axis roles:
  pod    — pure data parallelism across pods (DCN); gradients all-reduce.
  data   — FSDP/ZeRO + batch sharding inside a pod (and the major expert
           axis for very large MoEs).
  model  — tensor parallelism (heads / ffn / vocab), expert parallelism,
           and the KV-sequence axis for sharded decode attention.

MoE expert-axis selection (per-chip capacity driven, see DESIGN.md §5):
  1. experts over ('data','model') when divisible (deepseek-v3: 256/256),
  2. else experts over ('model',) when divisible (moonshot 64, jamba 16),
     plus ffn over 'data' if the per-chip expert slice still exceeds the
     budget (jamba),
  3. else no expert sharding; ffn over 'model' (mixtral's 8 experts on a
     16-wide axis).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import ExecPolicy
from repro.models.params import param_axes

EXPERT_BYTES_BUDGET = 8e9        # per-chip expert-slice budget (bf16 bytes)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclass
class Plan:
    mesh: Mesh
    rules: Dict[str, object]              # logical axis -> mesh axes
    dp_axes: Tuple[str, ...]              # batch axes
    kv_axes: Tuple[str, ...]              # decode KV sequence axes
    expert_axes: Tuple[str, ...]
    moe_variant: str                      # ep_a2a | ep_psum | grouped_pjit | dense
    param_specs: Dict = None
    policy: ExecPolicy = None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def expert_sharding_for(cfg: ModelConfig, mesh: Mesh) -> Tuple[Tuple[str, ...], bool]:
    """Returns (expert_axes, shard_ffn_over_data)."""
    if not cfg.is_moe:
        return (), False
    have = mesh.shape
    cands = []
    if "data" in have and "model" in have:
        cands.append(("data", "model"))
    if "model" in have:
        cands.append(("model",))
    expert_bytes = (cfg.num_experts * 3 * cfg.d_model * cfg.d_ff
                    * cfg.num_layers * 2)
    for axes in cands:
        n = _axis_size(mesh, axes)
        if cfg.num_experts % n == 0:
            per_chip = expert_bytes / n
            shard_ffn = per_chip > EXPERT_BYTES_BUDGET and "data" not in axes
            return axes, shard_ffn
    return (), False


def make_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    have = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in have)
    train = shape.mode == "train"
    expert_axes, shard_ffn_data = expert_sharding_for(cfg, mesh)

    rules = {
        "vocab": "model" if "model" in have else None,
        "heads": "model" if "model" in have else None,
        "kv_heads": "model" if "model" in have else None,
        "experts": expert_axes or None,
        "lora": None,
        "embed_nr": None,                       # norm scales replicated
        "layers": None,
        "conv": None,
        "ssm_inner": "model" if "model" in have else None,
        "ssm_heads": "model" if "model" in have else None,
    }
    rules["ffn"] = "model" if "model" in have else None     # dense FFNs
    if cfg.is_moe:
        if expert_axes:
            rules["effn"] = ("data" if (shard_ffn_data and "data" in have)
                             else None)
        else:
            rules["effn"] = "model" if "model" in have else None
    # FSDP over 'data' for the embed dim in training (all-gathers amortized
    # by a long sequence); decode keeps embed replicated to avoid per-step
    # all-gathers unless the model cannot fit on the model axis alone.
    from repro.models.params import count_params
    big = count_params(cfg) * 2 / max(_axis_size(mesh, "model"), 1) > 12e9
    rules["embed"] = ("data" if ("data" in have and (train or big)) else None)
    return rules


def spec_for_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  rules: Dict, mesh: Mesh) -> P:
    """Map a leaf's logical axes to a PartitionSpec, enforcing divisibility
    and one-mesh-axis-per-leaf uniqueness."""
    used = set()
    parts = []
    for dim, logical in zip(shape, axes):
        assign = None
        rule = rules.get(logical) if logical else None
        if rule:
            cand = (rule,) if isinstance(rule, str) else tuple(rule)
            cand = tuple(a for a in cand if a not in used)
            if cand and dim % _axis_size(mesh, cand) == 0:
                assign = cand if len(cand) > 1 else cand[0]
                used.update(cand)
        parts.append(assign)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_specs(cfg: ModelConfig, rules: Dict, mesh: Mesh):
    axes_tree = param_axes(cfg)
    from repro.models.params import param_defs, tree_map_defs, ParamDef

    def one(d: ParamDef):
        return spec_for_axes(d.axes, d.shape, rules, mesh)

    return tree_map_defs(one, param_defs(cfg))


def cache_specs(cfg: ModelConfig, cache_tree, dp: Tuple[str, ...],
                kv_axes: Tuple[str, ...], rules: Dict, mesh: Mesh):
    """Specs for the decode cache pytree (mirrors kvcache.init_cache)."""
    dpa = dp if dp else None

    def leaf_spec(path, leaf):
        name = path[-1]
        if name == "pos":
            return P(dpa)
        ndim = len(leaf.shape)
        if name in ("k", "v"):          # (L,B,W,Hkv,Dh)
            if path[0] == "xattn":      # encoder positions: don't seq-shard
                return P(None, dpa, None, None, None)
            return P(None, dpa, kv_axes or None, None, None)
        if name in ("ckv", "kr"):       # (L,B,W,r)
            return P(None, dpa, kv_axes or None, None)
        if name == "slot_pos":          # (L,B,W)
            return P(None, dpa, kv_axes or None)
        if name == "state":             # (L,B,nh,hd,N)
            m = "model" if "model" in mesh.axis_names and \
                leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, dpa, m, None, None)
        if name == "conv_x":            # (L,B,cw-1,d_in)
            m = "model" if "model" in mesh.axis_names and \
                leaf.shape[3] % mesh.shape["model"] == 0 else None
            return P(None, dpa, None, m)
        if name in ("conv_B", "conv_C"):
            return P(None, dpa, None, None)
        return P(*([None] * ndim))

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return leaf_spec(path, tree)

    return walk(cache_tree)


def batch_specs(batch_tree, dp: Tuple[str, ...]):
    """tokens/targets/frames/patches: batch over dp."""
    dpa = dp if dp else None

    def leaf(x):
        return P(dpa, *([None] * (len(x.shape) - 1)))

    return jax.tree.map(leaf, batch_tree)


def choose_moe_variant(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       expert_axes) -> str:
    if not cfg.is_moe:
        return "dense"
    if not expert_axes:
        return "grouped_pjit"
    n_exp = _axis_size(mesh, expert_axes)
    if shape.mode == "decode":
        # tiny activations: psum combine over 'model' only; with
        # ('data','model') expert sharding fall back to the pjit path
        return "ep_psum" if expert_axes == ("model",) else "grouped_pjit"
    # train/prefill: all-to-all when the sequence can shard over the
    # non-data expert axes
    seq_axes = tuple(a for a in expert_axes if a != "data")
    if seq_axes and shape.seq_len % _axis_size(mesh, seq_axes) == 0:
        return "ep_a2a"
    return "grouped_pjit"


def make_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
              use_kernels: bool = False, remat: Optional[bool] = None,
              moe_variant: Optional[str] = None,
              kv_axes: Optional[Tuple[str, ...]] = None,
              scan_unroll: int = 1, decode_2d: bool = False) -> Plan:
    """decode_2d: stationary-weights decode for very large models — the
    batch is REPLICATED (dp=()); 'data' becomes a second weight-sharding
    axis (embed dim / expert-FFN dim), so each decode step psums
    (batch × d_model)-sized activations instead of all-gathering
    multi-GB weight shards.  KV pages shard over ('data','model')."""
    have = set(mesh.axis_names)
    dp_full = tuple(a for a in ("pod", "data") if a in have)
    # batch must divide the dp axes; shrink until it does
    dp = dp_full
    while dp and shape.global_batch % _axis_size(mesh, dp) != 0:
        dp = dp[1:]
    if shape.mode == "decode" and "data" in have and not decode_2d:
        # stationary-weights decode is the default whenever 1D (model-axis)
        # sharding cannot hold the weights in HBM (perf-log: jamba decode
        # HLO collectives 3971ms -> 24ms, memory 35GB -> 21GB).  Models
        # whose experts already shard over ('data','model') (deepseek-v3)
        # are excluded: their bulk never gathers, and batch replication
        # would inflate the MLA attention-partial psums (H*r per token) —
        # measured 10.4 -> 164 ms (perf log).
        from repro.models.params import count_params
        e_ax, _ = expert_sharding_for(cfg, mesh)
        if (count_params(cfg) * 2 / max(_axis_size(mesh, "model"), 1) > 12e9
                and e_ax != ("data", "model")):
            decode_2d = True
    if decode_2d:
        dp = tuple(a for a in dp if a == "pod")
    if kv_axes is None:
        if shape.mode == "decode":
            spare = tuple(a for a in ("data", "model")
                          if a in have and a not in dp)
            kv_axes = spare if spare else (("model",) if "model" in have else ())
        else:
            kv_axes = ()
    rules = make_rules(cfg, shape, mesh)
    expert_axes, shard_ffn_data = expert_sharding_for(cfg, mesh)
    if decode_2d and "data" in have:
        rules["embed"] = "data"
        if cfg.is_moe and expert_axes == ("model",):
            rules["effn"] = "data"
            shard_ffn_data = True
    variant = moe_variant or choose_moe_variant(cfg, shape, mesh, expert_axes)
    if decode_2d and cfg.is_moe and expert_axes == ("model",):
        variant = "ep_psum"

    pspecs = param_specs(cfg, rules, mesh)

    # wire the execution policy
    from repro.distributed import collectives as C
    moe_fn = None
    moe_impl = "dense"
    if cfg.is_moe:
        if variant in ("ep_psum", "ep_a2a"):
            ffn_axes = (("data",) if (rules.get("effn") == "data"
                                      and variant == "ep_psum"
                                      and "data" not in expert_axes
                                      and "data" not in dp) else ())
            moe_fn = C.make_moe_shard_fn(
                mesh, cfg, variant=variant, dp_axes=dp,
                expert_axes=expert_axes, use_kernels=use_kernels,
                ffn_axes=ffn_axes)
        elif variant == "grouped_pjit":
            moe_impl = "grouped"
    attn_fn = None
    if shape.mode == "decode" and kv_axes and not cfg.is_attention_free:
        attn_fn = C.make_seq_sharded_attn(mesh, dp, tuple(kv_axes))

    policy = ExecPolicy(
        moe_impl=moe_impl, moe_fn=moe_fn, attn_fn=attn_fn,
        use_kernels=use_kernels,
        remat=(shape.mode == "train") if remat is None else remat,
        scan_unroll=scan_unroll)
    return Plan(mesh=mesh, rules=rules, dp_axes=dp, kv_axes=tuple(kv_axes),
                expert_axes=expert_axes, moe_variant=variant,
                param_specs=pspecs, policy=policy)

"""Distributed collectives built on shard_map.

`make_seq_sharded_attn` is the TPU adaptation of the paper's CPU attention
(DESIGN.md §2): the KV cache is sharded along the *sequence* axis across
chips; at each decode step the (tiny) per-token q is broadcast, every chip
computes attention partials against its local KV pages, and partials are
combined with a log-sum-exp-weighted psum.  Wire bytes per step are
O(batch × heads × head_dim) — independent of context length — exactly the
paper's "move the hidden state, not the KV cache".
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.attention import attention_partials

# shard_map compatibility: jax >= 0.6 exposes jax.shard_map (check_vma);
# older releases have jax.experimental.shard_map.shard_map (check_rep)
if hasattr(jax, "shard_map"):
    def _shard_map(body, *, mesh, in_specs, out_specs):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _sm

    def _shard_map(body, *, mesh, in_specs, out_specs):
        return _sm(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)


def lse_combine(o, m, l, axes):
    """Combine attention partials across mesh `axes`.
    o: (B,H,Dv) f32 unnormalized; m, l: (B,H) f32."""
    m_glob = jax.lax.pmax(m, axes)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axes)
    o_glob = jax.lax.psum(o * corr[..., None], axes)
    return o_glob / jnp.maximum(l_glob[..., None], 1e-30)


def make_seq_sharded_attn(mesh: Mesh, dp_axes: Tuple[str, ...],
                          kv_axes: Tuple[str, ...]):
    """Returns fn(q, k, v, valid, *, scale, attn_softcap) -> (B,H,Dv).

    q: (B,H,D) sharded over dp_axes on B, replicated over kv_axes.
    k/v: (B,W,Hkv,D*) with W sharded over kv_axes.
    valid: (B,W) bool, same sharding as the KV sequence dim.
    """
    dp = dp_axes if dp_axes else None

    def body(q, k, v, valid, *, scale, attn_softcap):
        o, m, l = attention_partials(q, k, v, valid, scale=scale,
                                     attn_softcap=attn_softcap)
        out = lse_combine(o, m, l, kv_axes)
        return out.astype(q.dtype)

    def fn(q, k, v, valid, *, scale, attn_softcap):
        sm = _shard_map(
            functools.partial(body, scale=scale, attn_softcap=attn_softcap),
            mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, kv_axes, None, None),
                      P(dp, kv_axes, None, None), P(dp, kv_axes)),
            out_specs=P(dp, None, None))
        return sm(q, k, v, valid)

    return fn


def make_moe_shard_fn(mesh: Mesh, cfg, *, variant: str,
                      dp_axes: Tuple[str, ...], expert_axes: Tuple[str, ...],
                      token_axis: str = None, use_kernels: bool = False,
                      shared_sharded: bool = False,
                      capacity_factor: float = None,
                      ffn_axes: Tuple[str, ...] = ()):
    """Wrap a moe_ep_* body in shard_map.

    variant "ep_psum": tokens replicated over expert_axes (x spec keeps
      only dp on batch); output psum'ed.  With `ffn_axes`, each expert's
      FFN dim is additionally sharded over those axes (2D stationary
      weights for decode) and the psum covers both groups.
    variant "ep_a2a": tokens additionally sharded over expert_axes —
      batch over dp, sequence over `token_axis` (defaults to the last
      expert axis); routed tokens exchanged with all_to_all.
    """
    from repro.models import moe as moe_mod
    dp = dp_axes if dp_axes else None
    NE = cfg.num_experts

    # per-leaf specs for the (layer-sliced) moe param subtree
    e_ax = expert_axes
    f_ax = tuple(ffn_axes) or None
    p_specs = {"router": P(None, None),
               "wi": P(e_ax, None, None, f_ax),
               "wo": P(e_ax, f_ax, None)}
    if cfg.expert_dtype == "int8":
        p_specs["wi_scale"] = P(e_ax)
        p_specs["wo_scale"] = P(e_ax)
    if cfg.num_shared_experts:
        p_specs["shared"] = {"wi": P(None, None, f_ax), "wo": P(f_ax, None)}

    if variant == "ep_psum":
        x_spec = P(dp, None, None)
        body = functools.partial(moe_mod.moe_ep_psum_local, cfg,
                                 expert_axes=expert_axes,
                                 use_kernel=use_kernels,
                                 capacity_factor=capacity_factor,
                                 ffn_axes=tuple(ffn_axes),
                                 shared_sharded=False)
    elif variant == "ep_a2a":
        tok_ax = token_axis or expert_axes[-1]
        seq_axes = tuple(a for a in expert_axes if a != "data") or (tok_ax,)
        # batch over dp(+data if data is an expert axis handled below)
        if "data" in expert_axes:
            # tokens must be sharded over ALL expert axes: batch carries
            # 'data' (it already does via dp) and the sequence carries the
            # rest ('model')
            x_spec = P(dp, tuple(a for a in expert_axes if a != "data") or None,
                       None)
        else:
            x_spec = P(dp, expert_axes, None)
        body = functools.partial(moe_mod.moe_ep_a2a_local, cfg,
                                 expert_axes=expert_axes,
                                 use_kernel=use_kernels,
                                 capacity_factor=capacity_factor,
                                 shared_sharded=False)
    else:
        raise ValueError(variant)

    def wrapped(p_local, x2d):
        out, aux = body(p_local, x2d)
        return out, aux

    all_axes = tuple(mesh.axis_names)

    def fn(cfg_, p, x3):
        B, S, D = x3.shape

        def body3(p_local, x3l):
            b, s, _ = x3l.shape
            out, aux = wrapped(p_local, x3l.reshape(b * s, D))
            aux = jax.lax.pmean(aux, all_axes)   # replicated metric
            return out.reshape(b, s, D), aux

        sm = _shard_map(
            body3, mesh=mesh,
            in_specs=(p_specs, x_spec),
            out_specs=(x_spec, P()))
        return sm(p, x3)

    return fn

"""Training loop: data pipeline → (micro-batched) train step → metrics,
with fault tolerance: auto-resume from the latest checkpoint, periodic
async checkpoints, heartbeat + straggler watchdog.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.models.params import init_params
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.watchdog import Watchdog
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import (make_microbatched_train_step,
                                       make_train_step)


@dataclass
class TrainConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    num_micro: int = 1
    seed: int = 0
    log_every: int = 10
    straggler_policy: str = "log"


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 opt: Optional[OptConfig] = None, policy=None,
                 step_fn: Optional[Callable] = None):
        self.cfg, self.tcfg = cfg, tcfg
        self.opt = opt or OptConfig(warmup_steps=10)
        if step_fn is None:
            if tcfg.num_micro > 1:
                step_fn = make_microbatched_train_step(
                    cfg, self.opt, policy, tcfg.num_micro)
            else:
                step_fn = make_train_step(cfg, self.opt, policy)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        self.watchdog = Watchdog(policy=tcfg.straggler_policy)
        self.data = DataPipeline(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
            batch_size=tcfg.batch_size, seed=tcfg.seed))
        self.metrics_log: list = []

        # init or resume -------------------------------------------------
        self.step = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            self.step, tree, extra = self.ckpt.restore()
            self.params, self.opt_state = tree["params"], tree["opt_state"]
            self.data.skip(extra.get("data_step", self.step))
        else:
            self.params = init_params(cfg, jax.random.key(tcfg.seed))
            self.opt_state = init_opt_state(self.params, self.opt)

    def run(self) -> Dict:
        last = {}
        while self.step < self.tcfg.steps:
            batch = {k: jax.numpy.asarray(v) for k, v in next(self.data).items()}
            self.watchdog.step_start()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.step_end()
            self.step += 1
            last = {k: float(v) for k, v in metrics.items()}
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.steps:
                self.metrics_log.append({"step": self.step, **last})
            if self.ckpt and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(
                    self.step,
                    {"params": self.params, "opt_state": self.opt_state},
                    extra={"data_step": self.data.step})
        if self.ckpt:
            self.ckpt.save(self.step,
                           {"params": self.params,
                            "opt_state": self.opt_state},
                           extra={"data_step": self.data.step})
            self.ckpt.wait()
        self.data.close()
        return last

"""Losses.  Cross-entropy is computed in sequence chunks under
jax.checkpoint so the (B, S, vocab) float32 logits are never materialized
at once — essential for vocab=256k × seq=4k training memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import unembed


def xent(logits, targets, mask):
    """logits (T,V) f32; targets (T,) i32; mask (T,) f32.
    Returns (sum_loss, sum_mask)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def chunked_lm_loss(cfg: ModelConfig, params, hidden, targets, *,
                    mask=None, chunk: int = 512):
    """hidden (B,S,E); targets (B,S).  Mean NLL over mask (defaults to
    targets >= 0, with the vision prefix masked for VLMs)."""
    B, S, E = hidden.shape
    if mask is None:
        mask = (targets >= 0)
        if cfg.vision_tokens:
            pos = jnp.arange(S)[None, :]
            mask = mask & (pos >= cfg.vision_tokens)
    mask = mask.astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)

    nchunks = -(-S // chunk)
    pad = nchunks * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = hidden.reshape(B, nchunks, chunk, E)
    tc = tgt.reshape(B, nchunks, chunk)
    mc = mask.reshape(B, nchunks, chunk)

    @jax.checkpoint
    def body(carry, xs):
        h, t, m = xs                           # (B,chunk,E) ...
        logits = unembed(cfg, params, h)       # recomputed in backward
        s, n = xent(logits.reshape(-1, logits.shape[-1]),
                    t.reshape(-1), m.reshape(-1))
        return (carry[0] + s, carry[1] + n), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(tc, 1, 0),
         jnp.moveaxis(mc, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)

"""AdamW (built from scratch — optax is not available offline).

Moments inherit the parameter sharding (pjit keeps them distributed; with
FSDP'd params this is ZeRO-equivalent).  `moment_dtype` lets very large
models halve optimizer memory (bf16 moments), which the dry-run memory
analysis exercises for deepseek-v3/jamba training.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100


def init_opt_state(params, opt: OptConfig) -> Dict:
    dt = jnp.dtype(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(opt: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(opt.warmup_steps, 1), 1.0)
    return opt.lr * warm


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params, grads, state: Dict, opt: OptConfig
                  ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))
    lr = _schedule(opt, step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(opt.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if opt.weight_decay:
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    new_state = {"mu": tdef.unflatten(new_mu), "nu": tdef.unflatten(new_nu),
                 "step": step}
    return tdef.unflatten(new_p), new_state, {"grad_norm": gnorm, "lr": lr}

"""Train step: forward → chunked LM loss (+ MoE aux) → backward → clip →
AdamW.  Built as a closure so it can be jitted with explicit shardings by
the launcher and lowered abstractly by the dry-run.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import ExecPolicy, forward
from repro.training.losses import chunked_lm_loss
from repro.training.optimizer import OptConfig, apply_updates

AUX_LOSS_WEIGHT = 0.01


def make_loss_fn(cfg: ModelConfig, policy: Optional[ExecPolicy]) -> Callable:
    def loss_fn(params, batch):
        extras = {k: batch[k] for k in ("frames", "patches") if k in batch}
        out = forward(cfg, params, batch["tokens"], mode="train",
                      policy=policy, **extras)
        lm = chunked_lm_loss(cfg, params, out["hidden"], batch["targets"])
        aux = out["aux_loss"]
        loss = lm + AUX_LOSS_WEIGHT * aux
        return loss, {"lm_loss": lm, "aux_loss": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    policy: Optional[ExecPolicy] = None) -> Callable:
    loss_fn = make_loss_fn(cfg, policy)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_params, new_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_state, metrics

    return train_step


def make_microbatched_train_step(cfg: ModelConfig, opt: OptConfig,
                                 policy: Optional[ExecPolicy],
                                 num_micro: int) -> Callable:
    """Gradient accumulation over `num_micro` micro-batches (scan), the
    training analogue of the paper's μ: bounds activation memory while
    keeping the weight-gather cost amortized over the full batch."""
    loss_fn = make_loss_fn(cfg, policy)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        assert B % num_micro == 0
        mb = B // num_micro

        def split(x):
            return x.reshape(num_micro, mb, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(acc, mbatch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / num_micro,
                acc_g, grads)
            return (acc_g, acc_l + loss / num_micro), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
        new_params, new_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt)
        return new_params, new_state, {"loss": loss, **opt_metrics}

    return train_step

"""Data pipeline: deterministic synthetic corpus → document packing →
per-host sharding → background prefetch.

Every stage is seeded and host-indexed so N hosts draw disjoint,
reproducible streams (restart-safe: the stream position is part of the
checkpoint metadata).  The synthetic corpus is a Zipf-ish token source
with document structure (EOS-terminated variable-length docs) so packing
and masking paths are exercised realistically.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int                  # per-host
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    eos_id: int = 1
    mean_doc_len: int = 256
    zipf_a: float = 1.3


class SyntheticCorpus:
    """Deterministic stream of EOS-terminated documents."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # distinct stream per (seed, host): PCG64 jumped by host index
        seq = np.random.SeedSequence([cfg.seed, cfg.host_id])
        self.rng = np.random.default_rng(seq)

    def documents(self) -> Iterator[np.ndarray]:
        c = self.cfg
        while True:
            n = max(2, int(self.rng.exponential(c.mean_doc_len)))
            toks = self.rng.zipf(c.zipf_a, size=n) % (c.vocab_size - 2) + 2
            yield np.concatenate([toks.astype(np.int32), [c.eos_id]])


def pack_documents(docs: Iterator[np.ndarray], seq_len: int
                   ) -> Iterator[np.ndarray]:
    """Greedy packing of documents into fixed seq_len+1 rows (the +1 makes
    the (inputs, targets) shift trivial)."""
    buf = np.empty(0, np.int32)
    need = seq_len + 1
    for d in docs:
        buf = np.concatenate([buf, d])
        while len(buf) >= need:
            yield buf[:need]
            buf = buf[need:]


class DataPipeline:
    """Batched, prefetching iterator of {"tokens", "targets"} host arrays."""

    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        self._rows = pack_documents(SyntheticCorpus(cfg).documents(),
                                    cfg.seq_len)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rows = np.stack([next(self._rows) for _ in range(c.batch_size)])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "targets": rows[:, 1:].astype(np.int32)}

    def _producer(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._make_batch(), timeout=0.5)
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        self._step += 1
        return self._q.get()

    @property
    def step(self) -> int:
        return self._step

    def skip(self, n: int):
        """Fast-forward after checkpoint restore (stream determinism)."""
        for _ in range(n):
            self._make_batch_direct()

    def _make_batch_direct(self):
        c = self.cfg
        for _ in range(c.batch_size):
            next(self._rows)
        self._step += 1

    def close(self):
        self._stop.set()

"""deepseek-v3-671b [moe] — arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.  MLA attention
(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128), MoE with
1 shared + 256 routed experts top-8 (sigmoid routing w/ normalization),
first 3 layers dense FFN with d_ff=18432.  The MTP auxiliary head is NOT
implemented (orthogonal to the reproduced paper; see DESIGN.md §4).
"""
from repro.configs.base import ATTN_MLA, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,     # MLA: logical kv heads == query heads
    head_dim=192,         # qk_nope + qk_rope
    d_ff=2048,
    dense_d_ff=18_432,
    vocab_size=129_280,
    prologue=(LayerSpec(attn=ATTN_MLA),) * 3,
    period=(LayerSpec(attn=ATTN_MLA, moe=True),),
    num_experts=256,
    top_k=8,
    num_shared_experts=1,
    router_scale=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    norm="rmsnorm",
    ffn_act="silu",
    tie_embeddings=False,
    rope_theta=10_000.0,
)

"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

48L d_model=2048, attention-free, vocab=50280, ssm_state=128.
expand=2 -> d_inner=4096, ssm_head_dim=64 -> 64 SSD heads, conv width 4,
chunked SSD with chunk=256.  No FFN (the mamba mixer is the whole block).
"""
from repro.configs.base import ATTN_NONE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    period=(LayerSpec(kind="mamba", attn=ATTN_NONE, ffn=False),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    norm="rmsnorm",
    pos="none",
    tie_embeddings=True,
)

"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 (+1.5 report).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Mamba:attention 7:1 interleave (one attention layer per 8-layer period, at
position 4 as in the Jamba block), MoE FFN every other layer (odd positions),
dense FFN otherwise.  Jamba uses no positional encoding (the Mamba layers
carry position); attention layers are full-causal.  The long_500k shape runs:
the single KV cache per 8 layers is paged + sequence-sharded.

Deviation noted in DESIGN.md: the published Jamba uses Mamba-1 (d_state=16);
we use our Mamba-2/SSD mixer (d_state=128) as the single SSM substrate.
"""
from repro.configs.base import ATTN_FULL, ATTN_NONE, LayerSpec, ModelConfig

_M = LayerSpec(kind="mamba", attn=ATTN_NONE, ffn=True)           # mamba + dense FFN
_MM = LayerSpec(kind="mamba", attn=ATTN_NONE, ffn=True, moe=True)  # mamba + MoE FFN
_A = LayerSpec(kind="attn", attn=ATTN_FULL, ffn=True)            # attn + dense FFN

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    dense_d_ff=24_576,
    vocab_size=65_536,
    # period of 8: mamba at 0..3 & 5..7, attention at 4; MoE on odd positions
    period=(_M, _MM, _M, _MM, _A, _MM, _M, _MM),
    num_experts=16,
    top_k=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    norm="rmsnorm",
    ffn_act="silu",
    pos="none",
    tie_embeddings=False,
)

"""glm4-9b [dense] — hf:THUDM/glm-4-9b.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
RoPE, GQA, QKV bias, SwiGLU, RMSNorm, untied head.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13_696,
    vocab_size=151_552,
    period=(LayerSpec(),),
    qkv_bias=True,
    norm="rmsnorm",
    norm_eps=1.5625e-07,
    ffn_act="silu",
    tie_embeddings=False,
    rope_theta=10_000.0,
)

"""gemma2-2b [dense] — arXiv:2408.00118; hf:google/gemma-2-2b.

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
Local(4096-window)/global alternating attention, attn-logit softcap 50,
final-logit softcap 30, GeGLU, RMSNorm with post-block norms, embeddings
scaled by sqrt(d_model), tied LM head.
"""
from repro.configs.base import ATTN_FULL, ATTN_WINDOW, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    period=(LayerSpec(attn=ATTN_WINDOW, window=4096),
            LayerSpec(attn=ATTN_FULL)),
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    query_scale=256 ** -0.5,
    ffn_act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    post_block_norm=True,
    rope_theta=10_000.0,
)

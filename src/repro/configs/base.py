"""Config system: model architecture configs + workload shape registry.

Every assigned architecture is a `ModelConfig` instance living in its own
module under ``repro.configs``.  Configs are plain frozen dataclasses so they
are hashable (usable as jit static args) and trivially serializable.

Layer structure is described by a *period*: a tuple of `LayerSpec`s that
repeats down the stack (plus optional non-repeating prologue layers).  This
lets `repro.models.model` scan over periods so HLO size is O(period), not
O(depth) — required to keep the 512-device dry-run compiles tractable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

ATTN_FULL = "full"          # full causal attention
ATTN_WINDOW = "window"      # sliding-window causal attention
ATTN_MLA = "mla"            # DeepSeek multi-head latent attention
ATTN_NONE = "none"          # attention-free (pure-FFN or mamba layer)


@dataclass(frozen=True)
class LayerSpec:
    """One layer position inside the repeating period."""
    kind: str = "attn"                  # "attn" | "mamba"
    attn: str = ATTN_FULL               # attention flavor (if kind == "attn")
    window: int = 0                     # sliding window size (attn == "window")
    moe: bool = False                   # MoE FFN instead of dense FFN
    ffn: bool = True                    # has an FFN at all (mamba layers: False)
    cross_attn: bool = False            # encoder-decoder cross attention (whisper)

    def cache_kind(self) -> str:
        if self.kind == "mamba":
            return "ssm"
        if self.attn == ATTN_MLA:
            return "mla"
        if self.attn == ATTN_NONE:
            return "none"
        return "kv"


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Field names follow the paper's Table 1 where
    applicable (h1 = d_model, h2 = d_ff, n_q/n_kv heads, n_e/k experts)."""

    name: str
    family: str                          # dense | moe | ssm | hybrid | vlm | audio

    # Core dims
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # Layer-structure period (repeats); prologue precedes the periodic part.
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prologue: Tuple[LayerSpec, ...] = ()

    # Attention details
    pos: str = "rope"                    # rope | learned | none
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    logit_softcap: float = 0.0           # gemma2 final-logit softcap
    attn_softcap: float = 0.0            # gemma2 attention-logit softcap
    window_size: int = 4096              # sliding window width for ATTN_WINDOW
    query_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # Norm / embedding details
    norm: str = "rmsnorm"                # rmsnorm | layernorm | nonparametric_ln
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embeddings: bool = False       # gemma: multiply embed by sqrt(d_model)
    post_block_norm: bool = False        # gemma2: extra norms after attn/ffn

    # FFN
    ffn_act: str = "silu"                # silu (swiglu) | gelu (geglu) | gelu_mlp
    dense_d_ff: int = 0                  # d_ff for non-MoE layers when mixed (dsv3)

    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0          # deepseek shared expert(s)
    router_scale: bool = False           # deepseek sigmoid-routing normalization
    capacity_factor: float = 1.25        # train-time expert capacity

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                 # fixed encoder positions (1500 frames)

    # VLM prefix (paligemma)
    vision_tokens: int = 0               # number of stubbed patch-embedding tokens

    # Numerics
    dtype: str = "bfloat16"
    expert_dtype: str = ""        # "" (= dtype) | "int8" weight-only quant
    kv_dtype: str = ""            # "" (= dtype) | "int8" KV-cache quant
                                  # (per-token-per-head scales; paper §3.3
                                  # discusses int4 KV for the same reason)

    # ---------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # Derived -------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return all(s.kind == "mamba" for s in self.period + self.prologue)

    @property
    def has_subquadratic_path(self) -> bool:
        """True if *every* attention in the stack is windowed or absent —
        the criterion for running the long_500k shape."""
        specs = self.period + self.prologue
        return all(s.kind == "mamba" or s.attn in (ATTN_NONE, ATTN_WINDOW)
                   for s in specs)

    @property
    def layers_per_period(self) -> int:
        return len(self.period)

    @property
    def num_periods(self) -> int:
        n = self.num_layers - len(self.prologue)
        assert n % len(self.period) == 0, (
            f"{self.name}: {self.num_layers} layers minus {len(self.prologue)} "
            f"prologue not divisible by period {len(self.period)}")
        return n // len(self.period)

    # Parameter accounting (used by HRM and the roofline report) -----
    def param_count(self) -> int:
        from repro.models.params import count_params  # avoid cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params
        return count_params(self, active_only=True)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        n_period = len(self.period)
        n_pro = len(self.prologue)
        kw = dict(
            name=self.name + "-smoke",
            num_layers=n_pro + 2 * n_period,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            dense_d_ff=128 if self.dense_d_ff else 0,
            vocab_size=min(self.vocab_size, 512) if self.vocab_size else 0,
            head_dim=16,
        )
        if self.num_heads:
            kw["num_heads"] = 4
            kw["num_kv_heads"] = max(1, min(self.num_kv_heads, 2))
        if self.is_moe:
            kw["num_experts"] = min(self.num_experts, 8)
            kw["top_k"] = min(self.top_k, 2)
        if self.q_lora_rank or self.kv_lora_rank:
            kw.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16, head_dim=24)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=32)
        if self.vision_tokens:
            kw.update(vision_tokens=16)
        if self.window_size:
            kw["window_size"] = min(self.window_size, 32)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                # "train" | "prefill" | "decode"

    def smoke(self) -> "ShapeConfig":
        return ShapeConfig(self.name + "-smoke", min(self.seq_len, 64),
                           min(self.global_batch, 4), self.mode)


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Task-spec applicability matrix. Returns (runnable, reason-if-not).

    long_500k runs for SSM / hybrid stacks (per task spec): a hybrid's few
    attention layers keep a paged, sequence-sharded KV cache; pure
    full-attention stacks skip."""
    if shape.name.startswith("long_"):
        ok = cfg.family in ("ssm", "hybrid") or cfg.has_subquadratic_path
        if not ok:
            return False, ("skip(full-attn): long_500k requires "
                           "sub-quadratic attention")
    return True, ""

"""Architecture registry: ``get_config("gemma2-2b")`` etc.

The 10 assigned architectures plus the paper's own model (mixtral-8x7b).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                shape_applicable)

_ARCH_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "olmo-1b": "olmo_1b",
    "glm4-9b": "glm4_9b",
    "qwen2.5-3b": "qwen2_5_3b",
    "paligemma-3b": "paligemma_3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mamba2-1.3b": "mamba2_1_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-small": "whisper_small",
    "mixtral-8x7b": "mixtral_8x7b",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a != "mixtral-8x7b"]
ALL_ARCHS: List[str] = list(_ARCH_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _cache:
        if arch not in _ARCH_MODULES:
            raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
        _cache[arch] = mod.CONFIG
    return _cache[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "get_shape",
           "shape_applicable", "ASSIGNED_ARCHS", "ALL_ARCHS"]

"""whisper-small [audio] — arXiv:2212.04356.

Enc-dec, 12L encoder + 12L decoder, d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  Conv frontend is a STUB per task spec: ``input_specs()``
supplies precomputed 1500-frame embeddings; the encoder is the transformer
stack over those frames, the decoder cross-attends every layer.  LayerNorm,
plain GELU MLP, learned positions.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    period=(LayerSpec(cross_attn=True),),
    encoder_layers=12,
    encoder_seq=1500,
    norm="layernorm",
    norm_eps=1e-5,
    ffn_act="gelu_mlp",
    pos="learned",
    tie_embeddings=True,
)

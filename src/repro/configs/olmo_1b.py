"""olmo-1b [dense] — arXiv:2402.00838; hf:allenai/OLMo-1B.

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no learned scale/bias), SwiGLU, RoPE, tied head,
no biases anywhere.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    period=(LayerSpec(),),
    norm="nonparametric_ln",
    norm_eps=1e-5,
    ffn_act="silu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)

"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5-3B (family config per task card).

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
GQA with QKV bias, SwiGLU, RMSNorm, RoPE theta 1e6, tied head.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11_008,
    vocab_size=151_936,
    period=(LayerSpec(),),
    qkv_bias=True,
    norm="rmsnorm",
    ffn_act="silu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

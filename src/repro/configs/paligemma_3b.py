"""paligemma-3b [vlm] — arXiv:2407.07726; hf:google/paligemma-3b.

Gemma-2B language backbone: 18L d_model=2048 8H (GQA kv=1, head_dim=256)
d_ff=16384 vocab=257216.  SigLIP vision tower is a STUB per task spec:
``input_specs()`` supplies 256 precomputed patch embeddings which the model
consumes as a prefix (full bidirectional-within-prefix attention is
approximated as causal; loss masked to text positions).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    period=(LayerSpec(),),
    query_scale=256 ** -0.5,
    ffn_act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    rope_theta=10_000.0,
    vision_tokens=256,
)

"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (task-card dims).

48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840, MoE 64 experts
top-6.  Task card specifies GQA kv=16 and standard attention (the HF release
uses the DeepSeek-V3 layout; we follow the assigned card exactly and note the
difference here).  Every layer is MoE.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    period=(LayerSpec(moe=True),),
    num_experts=64,
    top_k=6,
    norm="rmsnorm",
    ffn_act="silu",
    tie_embeddings=False,
    rope_theta=50_000.0,
)

"""mixtral-8x7b [moe] — arXiv:2401.04088.  The paper's own evaluation model.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
Used by every paper-table benchmark (Figs. 4/5/7/9/10, Tabs. 4/5).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    period=(LayerSpec(moe=True),),
    num_experts=8,
    top_k=2,
    norm="rmsnorm",
    ffn_act="silu",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
)

"""Checkpointing (orbax is unavailable offline — built from scratch).

Layout: <dir>/step_<N>/
  manifest.json     — leaf paths, shapes, dtypes, step, extra metadata
  <leaf-path>.npy   — one file per pytree leaf (host-gathered)

Guarantees:
  * atomic:  written to step_<N>.tmp then os.rename'd — a crash mid-write
    never corrupts the latest checkpoint;
  * async:   `save_async` snapshots to host memory synchronously (cheap)
    and writes on a background thread — training continues;
  * elastic: `restore` takes a target mesh/shardings and device_puts each
    leaf with the NEW sharding, so a checkpoint taken on one mesh resumes
    on any other (runtime/elastic.py wraps this for re-scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _flatten(tree[k], prefix + (str(k),))
        return out
    return [(prefix, tree)]


def _unflatten(items):
    root: Dict = {}
    for path, val in items:
        node = root
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = val
    return root


def _leaf_file(path: Tuple[str, ...]) -> str:
    return "__".join(path) + ".npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Dict, extra: Optional[Dict] = None):
        self.wait()                      # never race a pending async writer
        snap = [(p, np.asarray(jax.device_get(v))) for p, v in _flatten(tree)]
        self._write(step, snap, extra or {})

    def save_async(self, step: int, tree: Dict, extra: Optional[Dict] = None):
        self.wait()                      # one writer at a time
        snap = [(p, np.asarray(jax.device_get(v))) for p, v in _flatten(tree)]
        self._thread = threading.Thread(
            target=self._write, args=(step, snap, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, snap: List, extra: Dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for path, arr in snap:
            fn = _leaf_file(path)
            dtype_name = str(arr.dtype)
            if arr.dtype.kind not in "fiub":      # ml_dtypes (bf16, fp8...)
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            np.save(tmp / fn, arr)
            manifest["leaves"].append({"path": list(path), "file": fn,
                                       "shape": list(arr.shape),
                                       "dtype": dtype_name})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in self.dir.glob("step_*"):
            if d.is_dir() and not d.name.endswith(".tmp") and \
                    (d / "manifest.json").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None
                ) -> Tuple[int, Dict, Dict]:
        """Returns (step, tree, extra).  `shardings`: optional pytree of
        jax.sharding.Sharding mirroring the checkpointed tree — leaves are
        device_put with it (elastic re-shard)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        shard_flat = (_flatten(shardings) if shardings is not None else None)
        items = []
        for i, leaf in enumerate(manifest["leaves"]):
            arr = np.load(d / leaf["file"])
            want = leaf["dtype"]
            if str(arr.dtype) != want:            # restore ml_dtypes views
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i][1])
            items.append((tuple(leaf["path"]), arr))
        return step, _unflatten(items), manifest["extra"]

"""Elastic re-scaling: resume any checkpoint on a different mesh.

The checkpoint holds host numpy leaves; re-scaling is re-sharding: build
the sharding plan for the NEW mesh and device_put every leaf with the new
NamedSharding.  Works for grow (16→256 chips) and shrink; the only
requirement is that the new mesh's axis sizes divide the sharded dims
(sharding.spec_for_axes degrades to replication otherwise, so restore
never fails — it just uses more memory per chip).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as SH
from repro.runtime.checkpoint import CheckpointManager


def reshard_tree(tree: Dict, spec_tree, mesh) -> Dict:
    def put(x, spec):
        return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, spec_tree,
                        is_leaf=lambda x: not isinstance(x, dict))


def restore_elastic(ckpt: CheckpointManager, cfg: ModelConfig,
                    shape: ShapeConfig, mesh, step: Optional[int] = None
                    ) -> Tuple[int, Dict, Dict, SH.Plan]:
    """Restore (params[, opt_state]) onto `mesh`, whatever mesh wrote it."""
    plan = SH.make_plan(cfg, shape, mesh)
    step_, tree, extra = ckpt.restore(step=step)
    out: Dict = {}
    if "params" in tree:
        out["params"] = reshard_tree(tree["params"], plan.param_specs, mesh)
    if "opt_state" in tree:
        p = jax.sharding.PartitionSpec()
        o_specs = {"mu": plan.param_specs, "nu": plan.param_specs, "step": p}
        out["opt_state"] = reshard_tree(tree["opt_state"], o_specs, mesh)
    for k in tree:
        if k not in out:
            out[k] = tree[k]
    return step_, out, extra, plan

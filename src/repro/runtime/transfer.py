"""Retrying transfer engine: bounded retry + backoff + EWMA deadlines.

Every mandatory H2D/D2H op the serving engine executes (BlockPool
spill/fetch plans, expert-span fills) runs through `TransferEngine`:

  * a `TransientTransferError` (injected by the fault plan, or raised by
    a real transport) is retried up to `max_retries` times with
    exponential backoff; an exhausted retry cycle books an **abort** and
    notifies the degradation ladder — and, for *mandatory* ops
    (`run_mandatory`), starts a fresh cycle, because a KV fetch or an
    admitted expert span must eventually land for correctness (dropping
    it would corrupt the cache the jitted step reads);
  * a `HostMemoryError` is never retried at the same tier: it propagates
    to the caller's `on_hostmem` hook (the engine demotes the pinned
    host tier to pageable there) and the op re-issues against the new
    tier;
  * each op's duration is scored against a per-site EWMA deadline
    (`runtime.watchdog.Watchdog.observe` — the training-loop straggler
    guard generalized to transfer ops).  Injected stalls add *virtual*
    seconds so chaos schedules stay deterministic without real sleeps; a
    deadline violation books a **stall** (and raises `StallTimeout`
    under ``stall_policy="abort"``).

Counters (retries / aborts / stalls / ok_ops / bytes) surface through
`Engine.fault_traffic()` in the same style as `weight_traffic()`.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.runtime.faults import (DegradationLadder, FaultInjector,
                                  HostMemoryError, StallTimeout,
                                  TransientTransferError)
from repro.runtime.watchdog import Watchdog


class TransferEngine:
    def __init__(self, injector: Optional[FaultInjector] = None, *,
                 max_retries: int = 4, backoff_s: float = 0.0,
                 backoff_base: float = 2.0, sleep: bool = False,
                 deadline_factor: float = 8.0, min_deadline_s: float = 0.05,
                 stall_policy: str = "log",
                 ladder: Optional[DegradationLadder] = None):
        assert stall_policy in ("log", "abort")
        self.inj = injector or FaultInjector()
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_base = float(backoff_base)
        self.sleep = bool(sleep)         # real sleeps (prod); tests keep False
        self.deadline_factor = deadline_factor
        self.min_deadline_s = min_deadline_s
        self.stall_policy = stall_policy
        self.ladder = ladder
        self._deadlines: Dict[str, Watchdog] = {}
        self.retries = 0
        self.aborts = 0
        self.stalls = 0
        self.ok_ops = 0
        self.hostmem_faults = 0
        self.bytes_moved = 0

    # ----------------------------------------------------------- plumbing
    def _deadline(self, site: str) -> Watchdog:
        wd = self._deadlines.get(site)
        if wd is None:
            wd = Watchdog(deadline_factor=self.deadline_factor,
                          min_deadline_s=self.min_deadline_s, policy="log")
            self._deadlines[site] = wd
        return wd

    def _note_fault(self, site: str) -> None:
        if self.ladder is not None:
            self.ladder.note_fault(site)

    def _note_ok(self) -> None:
        if self.ladder is not None:
            self.ladder.note_ok()

    def book_retry(self, site: str) -> None:
        """External retry bookkeeping for chokepoints that retry in
        place instead of through run() (BlockPool ensure loops)."""
        self.retries += 1
        self._note_fault(site)

    def book_abort(self, site: str) -> None:
        self.aborts += 1
        self._note_fault(site)

    def book_stall(self, site: str) -> None:
        self.stalls += 1
        self._note_fault(site)

    def deadline_s(self, site: str) -> float:
        return self._deadline(site).deadline()

    # ---------------------------------------------------------- execution
    def run(self, site: str, fn: Callable, *, nbytes: int = 0):
        """Execute `fn` with bounded retry/backoff.  Raises
        `TransientTransferError` when the retry budget is exhausted
        (abort booked) and `HostMemoryError` immediately (no same-tier
        retry).  Successful ops are scored against the site's EWMA
        deadline; injected stalls charge virtual seconds."""
        delay = self.backoff_s
        attempt = 0
        while True:
            t0 = time.perf_counter()
            virt = 0.0
            try:
                ev = self.inj.fire(site)
                if ev is not None:
                    if ev.kind == "stall":
                        virt = ev.stall_ms * 1e-3
                        if self.sleep and virt > 0:
                            time.sleep(virt)
                    elif ev.kind == "hostmem":
                        raise HostMemoryError(
                            f"injected hostmem fault @ {site}", site)
                    else:
                        raise TransientTransferError(
                            f"injected {ev.kind} @ {site} "
                            f"(attempt {attempt})", site)
                out = fn()
            except HostMemoryError:
                self.hostmem_faults += 1
                self._note_fault(site)
                raise
            except TransientTransferError:
                self._note_fault(site)
                if attempt >= self.max_retries:
                    self.aborts += 1
                    raise
                self.retries += 1
                attempt += 1
                if self.sleep and delay > 0:
                    time.sleep(delay)
                delay = (delay or self.backoff_s) * self.backoff_base
                continue
            dt = time.perf_counter() - t0 + virt
            wd = self._deadline(site)
            if not wd.observe(dt):
                self.stalls += 1
                self._note_fault(site)
                if self.stall_policy == "abort":
                    raise StallTimeout(
                        f"{site} op took {dt:.3f}s > deadline "
                        f"{wd.deadline():.3f}s", site)
            else:
                self._note_ok()
            self.ok_ops += 1
            self.bytes_moved += int(nbytes)
            return out

    def run_mandatory(self, site: str, fn: Callable, *, nbytes: int = 0,
                      on_hostmem: Optional[Callable[[], None]] = None):
        """Run an op that MUST eventually complete (correctness, not
        advisory prefetch).  Exhausted retry cycles notify the ladder
        and start over — the fault plan is transient by construction
        (scripted bursts are finite, probabilistic draws have p < 1 or a
        max_faults bound), so this terminates.  `on_hostmem` handles a
        pinned-tier allocation failure (demote the tier) before the op
        re-issues."""
        while True:
            try:
                return self.run(site, fn, nbytes=nbytes)
            except TransientTransferError:
                continue          # abort already booked; fresh retry cycle
            except HostMemoryError:
                if on_hostmem is None:
                    raise
                on_hostmem()

    # ---------------------------------------------------------- reporting
    def stats(self) -> Dict[str, float]:
        return {
            "retries": self.retries,
            "aborts": self.aborts,
            "stalls": self.stalls,
            "ok_ops": self.ok_ops,
            "hostmem_faults": self.hostmem_faults,
            "bytes_moved": self.bytes_moved,
            "deadline_s": {s: wd.deadline()
                           for s, wd in self._deadlines.items()},
        }

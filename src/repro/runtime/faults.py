"""Fault injection + degradation ladder for the offload plane.

The serving engine's throughput story (CGOPipe, DESIGN.md §2) assumes the
CPU–GPU–I/O pipeline never stalls; this module is the story for when it
does.  Three pieces:

  * a structured **error taxonomy** replacing the silent paths: a failed
    or stalled transfer is a `TransientTransferError` / `StallTimeout`,
    a failed pinned-host allocation a `HostMemoryError` — all subclasses
    of `OffloadFaultError` carrying the fault site;
  * a seeded, schedulable **FaultPlan**: per-site fault probabilities
    and/or a scripted trace of `FaultEvent`s (fail / stall-N-ms /
    partial-plan / hostmem / pool-exhaust), drawn deterministically per
    site-op so a chaos schedule replays bit-for-bit from its seed.  The
    engine consults it through a `FaultInjector` at the chokepoints all
    H2D/D2H bytes already flow through: `paging.transfer_plan` drains,
    `BlockPool` spill/fetch execution, `ExpertResidency` span fills and
    `core/offload.py` pinned-host placement;
  * a reversible **DegradationLadder**: persistent faults step the
    engine down one rung at a time (pinned→pageable host tier, suspend
    predictive prefetch, clamp module windows to lockstep, shrink the
    residency pool / drop replica pins, SLO-shed at admission), and a
    hysteresis-guarded streak of healthy operations steps it back up.
    Every transition is an emitted structured event.

North-star invariant (tests/test_chaos.py): faults may cost throughput
but never change tokens — every rung only moves *where bytes stream
from and when*, never what the jitted step computes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class OffloadFaultError(RuntimeError):
    """Base class for offload-plane faults; carries the fault site."""

    def __init__(self, msg: str, site: str = "?"):
        super().__init__(msg)
        self.site = site


class TransientTransferError(OffloadFaultError):
    """A transfer (H2D/D2H plan op, span fill) failed; retryable."""


class HostMemoryError(OffloadFaultError):
    """A pinned-host allocation / pinned-tier write failed.  Not
    retryable at the same tier — the caller demotes to pageable and
    re-issues (the degradation ladder re-probes on promotion)."""


class StallTimeout(OffloadFaultError):
    """An op exceeded its EWMA-based deadline (transfer stall)."""


# ---------------------------------------------------------------------------
# Fault plan
# ---------------------------------------------------------------------------

FAULT_KINDS = ("fail", "stall", "partial", "hostmem", "exhaust")


@dataclass
class FaultEvent:
    """One scripted fault: fires on the `site`'s ops [after, after+count).

    kind ∈ FAULT_KINDS: "fail" → TransientTransferError, "hostmem" →
    HostMemoryError, "exhaust" → pool refusal (BlockPool behaves as
    arena-exhausted), "stall" → the op proceeds but `stall_ms` of
    (virtual) latency is charged against its deadline, "partial" → only
    a `frac` prefix of a drained transfer-plan slice completes (the rest
    re-queues)."""
    site: str
    kind: str = "fail"
    after: int = 0
    count: int = 1
    stall_ms: float = 0.0
    frac: float = 0.5

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind


class FaultPlan:
    """Seeded, schedulable fault source.

    ``probs`` maps a site name (or "*" for any site) to either a float —
    the per-op probability of a "fail" — or a {kind: prob} dict (at most
    one kind fires per op; probabilities are taken in kind order).
    ``trace`` is a sequence of scripted `FaultEvent`s keyed on the
    site's own op counter, so a schedule like "the 5th kv_fetch fails
    three times" is exact and replayable.  Scripted events win over the
    probabilistic draw.  ``max_faults`` bounds total injections — the
    backstop that keeps a high-probability plan from starving a
    mandatory retry loop forever.

    Determinism: draws depend only on (seed, per-site op order), so the
    same engine run under the same plan replays identically — the chaos
    fuzzer's whole premise.
    """

    def __init__(self, seed: int = 0,
                 probs: Optional[Dict[str, Union[float, Dict[str, float]]]]
                 = None,
                 trace: Sequence[FaultEvent] = (),
                 stall_ms: float = 250.0,
                 partial_frac: float = 0.5,
                 max_faults: Optional[int] = None):
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self.probs = dict(probs or {})
        self.trace = list(trace)
        self.stall_ms = float(stall_ms)
        self.partial_frac = float(partial_frac)
        self.max_faults = max_faults
        self.ops: Dict[str, int] = {}        # per-site op counter
        self.injected = 0

    def _scripted(self, site: str, n: int) -> Optional[FaultEvent]:
        for ev in self.trace:
            if ev.site == site and ev.after <= n < ev.after + ev.count:
                return ev
        return None

    def draw(self, site: str) -> Optional[FaultEvent]:
        """One op at `site`: returns the fault to inject, or None."""
        n = self.ops.get(site, 0)
        self.ops[site] = n + 1
        if self.max_faults is not None and self.injected >= self.max_faults:
            return None
        ev = self._scripted(site, n)
        if ev is None:
            spec = self.probs.get(site, self.probs.get("*"))
            if spec is not None:
                u = float(self._rng.random())
                kinds = ({"fail": float(spec)} if np.isscalar(spec)
                         else spec)
                acc = 0.0
                for kind in FAULT_KINDS:
                    p = float(kinds.get(kind, 0.0))
                    if p <= 0.0:
                        continue
                    acc += p
                    if u < acc:
                        ev = FaultEvent(site, kind,
                                        stall_ms=self.stall_ms,
                                        frac=self.partial_frac)
                        break
        if ev is not None:
            self.injected += 1
        return ev


class FaultInjector:
    """The engine-side handle: wraps an optional FaultPlan and keeps the
    injection counters (`fault_traffic()` surfaces them).  With no plan
    every call is a cheap no-op — the injector is always present so the
    chokepoints need no conditional wiring."""

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan
        self.counts: Dict[str, int] = {}        # "site/kind" -> n

    @property
    def armed(self) -> bool:
        return self.plan is not None

    def fire(self, site: str) -> Optional[FaultEvent]:
        if self.plan is None:
            return None
        ev = self.plan.draw(site)
        if ev is not None:
            k = f"{site}/{ev.kind}"
            self.counts[k] = self.counts.get(k, 0) + 1
        return ev

    def stall_s(self, site: str) -> float:
        """Fire `site`; return the injected stall in seconds (0.0 when
        no stall fired).  Non-stall kinds drawn at a stall-only site are
        ignored — used for the dispatch-deadline site where a failed
        'transfer' has no meaning."""
        ev = self.fire(site)
        if ev is not None and ev.kind == "stall":
            return ev.stall_ms * 1e-3
        return 0.0

    def total(self) -> int:
        return sum(self.counts.values())

    def raise_for(self, site: str) -> None:
        """Fire `site` and raise for the placement-probe chokepoint:
        there is no transfer to stall or partially complete, so every
        hard kind (fail/hostmem/exhaust) means the same thing — the
        allocation did not happen — and raises HostMemoryError."""
        ev = self.fire(site)
        if ev is None or ev.kind in ("stall", "partial"):
            return
        raise HostMemoryError(f"injected {ev.kind} @ {site}", site)


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------

LADDER_LEVELS: Tuple[str, ...] = (
    "healthy",            # 0: full pipeline
    "pageable_host",      # 1: pinned host tier demoted to pageable numpy
    "no_predict",         # 2: gate-predictor prefetch suspended
    "lockstep",           # 3: module windows clamped to lockstep (G=1)
    "residency_shrunk",   # 4: replica pins dropped, pool capacity halved
    "admission_shed",     # 5: scheduler sheds lowest-priority admissions
)


class DegradationLadder:
    """Reversible degradation state machine with hysteresis.

    ``note_fault`` / ``note_ok`` feed op outcomes (from the transfer
    engine and the dispatch watchdog); `down_after` consecutive faults
    move the *target* one rung down, `up_after` consecutive healthy ops
    one rung up (up_after > down_after is the hysteresis that stops
    flapping).  Side effects are applied only at `apply()` — the engine
    calls it at a safe point (start of each tick), crossing one rung at
    a time through an `enact(old, new, direction)` callback and
    emitting a structured event per transition.  `force_at_least`
    handles faults that cannot wait (a pinned-tier write that already
    failed): the engine demotes immediately and the ladder records the
    rung at the next apply."""

    def __init__(self, *, down_after: int = 3, up_after: int = 16,
                 max_level: int = len(LADDER_LEVELS) - 1):
        assert up_after > down_after > 0, "hysteresis needs up > down > 0"
        self.down_after = down_after
        self.up_after = up_after
        self.max_level = min(max_level, len(LADDER_LEVELS) - 1)
        self.level = 0
        self.target = 0
        self.events: List[dict] = []
        self.demotions = 0
        self.promotions = 0
        self._fault_streak = 0
        self._ok_streak = 0
        self._last_site = ""

    @property
    def level_name(self) -> str:
        return LADDER_LEVELS[self.level]

    def note_fault(self, site: str) -> None:
        self._last_site = site
        self._ok_streak = 0
        self._fault_streak += 1
        if self._fault_streak >= self.down_after \
                and self.target < self.max_level:
            self.target += 1
            self._fault_streak = 0

    def note_ok(self) -> None:
        self._fault_streak = 0
        self._ok_streak += 1
        if self._ok_streak >= self.up_after and self.target > 0:
            self.target -= 1
            self._ok_streak = 0

    def force_at_least(self, level_name: str, site: str = "") -> None:
        lvl = LADDER_LEVELS.index(level_name)
        if site:
            self._last_site = site
        self.target = max(self.target, min(lvl, self.max_level))

    def pending(self) -> bool:
        return self.target != self.level

    def apply(self, enact: Optional[Callable[[int, int, str], None]] = None,
              tick: int = 0) -> List[dict]:
        """Cross rungs one at a time toward the target; returns the
        transition events emitted (also appended to `self.events`)."""
        out: List[dict] = []
        while self.level != self.target:
            new = self.level + (1 if self.target > self.level else -1)
            direction = "down" if new > self.level else "up"
            # snapshot before enacting: a rung's side effect may itself
            # call force_at_least (tier demotion) and clobber the site
            reason = (self._last_site if direction == "down"
                      else "health_restored")
            if enact is not None:
                enact(self.level, new, direction)
            if direction == "down":
                self.demotions += 1
            else:
                self.promotions += 1
            ev = {"seq": len(self.events), "tick": tick,
                  "direction": direction,
                  "from": LADDER_LEVELS[self.level],
                  "to": LADDER_LEVELS[new],
                  "from_level": self.level, "to_level": new,
                  "reason": reason}
            self.level = new
            self.events.append(ev)
            out.append(ev)
        return out

"""Watchdog: heartbeat + straggler detection for the training loop.

At 1000+ nodes the common failure modes are (a) a host that dies — caught
by the missed-heartbeat timeout and answered with restart-from-checkpoint
(the trainer's main loop), and (b) a straggler step — caught by the
per-step deadline (EWMA × factor) and answered per policy:

  "log"   — record and continue (default),
  "skip"  — abandon the step's data (re-dispatched next step),
  "abort" — raise, letting the launcher restart from the last checkpoint.

On a real multi-host deployment the heartbeat file lives on shared
storage and each host monitors its peers; in this single-process harness
the same object guards the local step loop (and is unit-tested as such).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional


class StragglerError(RuntimeError):
    pass


@dataclass
class Watchdog:
    deadline_factor: float = 3.0
    min_deadline_s: float = 1.0
    policy: str = "log"                  # log | skip | abort
    heartbeat_path: Optional[str] = None
    ewma: float = 0.0
    alpha: float = 0.1
    slow_steps: int = 0
    steps_seen: int = 0
    _t0: float = field(default=0.0, repr=False)

    def deadline(self) -> float:
        return max(self.min_deadline_s, self.deadline_factor * self.ewma)

    def step_start(self):
        self._t0 = time.monotonic()
        self.beat()

    def step_end(self, extra_s: float = 0.0) -> bool:
        """Returns True if the step was within deadline.  ``extra_s``
        adds virtual latency (injected stalls) so fault schedules stay
        deterministic without real sleeps."""
        return self.observe(time.monotonic() - self._t0 + extra_s)

    def observe(self, dt: float) -> bool:
        """Score one step/op duration against the EWMA deadline.  Split
        from step_end so callers that measure their own durations (the
        transfer engine's per-site deadlines) share the policy logic.

        The EWMA is seeded by the first observed sample (by step count,
        not by value — a 0.0-duration first step must not re-seed
        forever) and updated on EVERY step with a deadline-clipped
        sample, *including* steps that violate the deadline — before the
        abort policy raises — so one straggler neither poisons nor
        freezes the deadline estimate."""
        if self.steps_seen == 0:
            self.ewma = dt
        deadline = self.deadline()
        ok = dt <= deadline
        self.ewma = (1 - self.alpha) * self.ewma \
            + self.alpha * min(dt, deadline)
        self.steps_seen += 1
        if not ok:
            self.slow_steps += 1
            if self.policy == "abort":
                raise StragglerError(
                    f"step took {dt:.2f}s > deadline {deadline:.2f}s")
        return ok

    def beat(self):
        if self.heartbeat_path:
            Path(self.heartbeat_path).write_text(
                json.dumps({"t": time.time()}))

    @staticmethod
    def peer_alive(heartbeat_path: str, timeout_s: float = 60.0) -> bool:
        p = Path(heartbeat_path)
        if not p.exists():
            return False
        t = json.loads(p.read_text())["t"]
        return (time.time() - t) < timeout_s

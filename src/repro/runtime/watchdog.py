"""Watchdog: heartbeat + straggler detection for the training loop.

At 1000+ nodes the common failure modes are (a) a host that dies — caught
by the missed-heartbeat timeout and answered with restart-from-checkpoint
(the trainer's main loop), and (b) a straggler step — caught by the
per-step deadline (EWMA × factor) and answered per policy:

  "log"   — record and continue (default),
  "skip"  — abandon the step's data (re-dispatched next step),
  "abort" — raise, letting the launcher restart from the last checkpoint.

On a real multi-host deployment the heartbeat file lives on shared
storage and each host monitors its peers; in this single-process harness
the same object guards the local step loop (and is unit-tested as such).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional


class StragglerError(RuntimeError):
    pass


@dataclass
class Watchdog:
    deadline_factor: float = 3.0
    min_deadline_s: float = 1.0
    policy: str = "log"                  # log | skip | abort
    heartbeat_path: Optional[str] = None
    ewma: float = 0.0
    alpha: float = 0.1
    slow_steps: int = 0
    _t0: float = field(default=0.0, repr=False)

    def step_start(self):
        self._t0 = time.monotonic()
        self.beat()

    def step_end(self) -> bool:
        """Returns True if the step was within deadline."""
        dt = time.monotonic() - self._t0
        if self.ewma == 0.0:
            self.ewma = dt
        deadline = max(self.min_deadline_s, self.deadline_factor * self.ewma)
        ok = dt <= deadline
        if not ok:
            self.slow_steps += 1
            if self.policy == "abort":
                raise StragglerError(
                    f"step took {dt:.2f}s > deadline {deadline:.2f}s")
        # EWMA updated with a clipped sample so one straggler doesn't
        # poison the deadline
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(dt, deadline)
        return ok

    def beat(self):
        if self.heartbeat_path:
            Path(self.heartbeat_path).write_text(
                json.dumps({"t": time.time()}))

    @staticmethod
    def peer_alive(heartbeat_path: str, timeout_s: float = 60.0) -> bool:
        p = Path(heartbeat_path)
        if not p.exists():
            return False
        t = json.loads(p.read_text())["t"]
        return (time.time() - t) < timeout_s

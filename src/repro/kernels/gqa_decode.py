"""Pallas flash-decode GQA kernel (TPU target, interpret-validated on CPU).

The TPU-native replacement for the paper's MKL CPU-GQA kernel: one decode
step of grouped-query attention against a (possibly ring-buffered,
sequence-sharded) KV cache.  The KV sequence is tiled into VMEM blocks;
a running (max, sumexp, accumulator) triple lives in VMEM scratch across
the sequential KV-block grid dimension, so HBM traffic is exactly one read
of K and V — the kernel is memory-roof-bound by construction, which is
what the HRM analysis (Fig. 4) says decode attention must be.

Returns *partials* (o_unnorm, m, l) so the sequence-sharded combine
(distributed.collectives.lse_combine) can merge shards — the kernel slots
directly under the paper's "compute attention where the KV lives" rule.

Layout notes:
  * q is pre-reshaped to (B, Hkv, G, D): the G*D tile is MXU-aligned for
    G=8..128 query groups.
  * K/V blocks are (block_w, D) tiles per (batch, kv-head) — contiguous in
    the cache layout (B, W, Hkv, D) after a transpose the wrapper does.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, valid_ref, *rest,
            scale: float, attn_softcap: float, blocks_w: int,
            quantized: bool):
    if quantized:       # int8 arena: per-(token, head) dequant scales
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc, m_s, l_s = rest
    else:
        o_ref, m_ref, l_ref, acc, m_s, l_s = rest
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (bw, D)
    v = v_ref[0, 0].astype(jnp.float32)                # (bw, Dv)
    valid = valid_ref[0]                               # (bw,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (G, bw)
    if quantized:       # fold k_scale per tile: s = (q . k_int) * ks
        s = s * ks_ref[0, 0][None, :]
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_s[...]                                  # (G,)
    m_blk = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None]) * (s > NEG_INF / 2)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                     jnp.exp(m_prev - m_safe))
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1)
    if quantized:       # fold v_scale into p: o = (p * vs) @ v_int
        p = p * vs_ref[0, 0][None, :]
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))                # (G, Dv)
    # keep the TRUE running max (NEG_INF while nothing valid yet) so the
    # emitted m matches the single-pass oracle even when an all-invalid
    # block precedes a block whose true max is negative
    m_s[...] = m_new

    @pl.when(w == blocks_w - 1)
    def _fin():
        o_ref[0, 0] = acc[...]
        m_ref[0, 0] = jnp.where(m_s[...] <= NEG_INF / 2, 0.0, m_s[...])
        l_ref[0, 0] = l_s[...]


def gqa_decode(q, k, v, valid, *, scale: float, attn_softcap: float = 0.0,
               k_scale=None, v_scale=None, block_w: int = 512,
               interpret: bool = True):
    """q: (B,H,D); k: (B,W,Hkv,D); v: (B,W,Hkv,Dv); valid: (B,W) bool.
    int8 caches pass k_scale/v_scale (B,W,Hkv) f32 — the dequant runs
    tile-wise in VMEM, never as a materialized f32 ring.
    Returns (o_unnorm (B,H,Dv) f32, m (B,H) f32, l (B,H) f32)."""
    B, H, D = q.shape
    _, W, Hkv, Dv = v.shape
    G = H // Hkv
    block_w = min(block_w, W)
    assert W % block_w == 0, (W, block_w)
    blocks_w = W // block_w
    quantized = k_scale is not None

    qg = q.reshape(B, Hkv, G, D)
    kt = jnp.swapaxes(k, 1, 2)           # (B, Hkv, W, D)
    vt = jnp.swapaxes(v, 1, 2)           # (B, Hkv, W, Dv)

    grid = (B, Hkv, blocks_w)
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, w: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, block_w, D), lambda b, h, w: (b, h, w, 0)),
        pl.BlockSpec((1, 1, block_w, Dv), lambda b, h, w: (b, h, w, 0)),
        pl.BlockSpec((1, block_w), lambda b, h, w: (b, w)),
    ]
    inputs = [qg, kt, vt, valid]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, block_w),
                                  lambda b, h, w: (b, h, w))] * 2
        inputs += [jnp.swapaxes(k_scale, 1, 2), jnp.swapaxes(v_scale, 1, 2)]
    out_shapes = (
        jax.ShapeDtypeStruct((B, Hkv, G, Dv), jnp.float32),
        jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
    )
    kern = functools.partial(_kernel, scale=scale, attn_softcap=attn_softcap,
                             blocks_w=blocks_w, quantized=quantized)
    o, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, 1, G, Dv), lambda b, h, w: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, w: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, w: (b, h, 0)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        # memory-roof-bound by construction: one K + one V read dominates;
        # the hint keeps XLA's scheduler from mis-costing the dispatch
        cost_estimate=pl.CostEstimate(
            flops=2 * B * W * H * (D + Dv),
            bytes_accessed=B * W * Hkv * (D + Dv)
            * k.dtype.itemsize + B * H * (D + Dv) * 4,
            transcendentals=B * W * H,
        ),
        interpret=interpret,
    )(*inputs)
    return (o.reshape(B, H, Dv), m.reshape(B, H), l.reshape(B, H))

"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gqa_decode_ref(q, k, v, valid, *, scale: float, attn_softcap: float = 0.0):
    """q: (B,H,D); k: (B,W,Hkv,D); v: (B,W,Hkv,Dv); valid: (B,W) bool.
    Returns (o_unnorm (B,H,Dv) f32, m (B,H) f32, l (B,H) f32) — the same
    partials contract as models.attention.attention_partials."""
    from repro.models.attention import attention_partials
    return attention_partials(q, k, v, valid, scale=scale,
                              attn_softcap=attn_softcap)


def moe_ffn_ref(xbuf, wi, wo, *, act: str = "silu"):
    """xbuf: (E,C,D); wi: (E,D,2,F); wo: (E,F,D) -> (E,C,D)."""
    actf = {"silu": jax.nn.silu,
            "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[act]
    h = jnp.einsum("ecd,edgf->ecgf", xbuf.astype(jnp.float32),
                   wi.astype(jnp.float32))
    y = actf(h[..., 0, :]) * h[..., 1, :]
    out = jnp.einsum("ecf,efd->ecd", y, wo.astype(jnp.float32))
    return out.astype(xbuf.dtype)

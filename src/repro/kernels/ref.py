"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gqa_decode_ref(q, k, v, valid, *, scale: float, attn_softcap: float = 0.0,
                   k_scale=None, v_scale=None):
    """q: (B,H,D); k: (B,W,Hkv,D); v: (B,W,Hkv,Dv); valid: (B,W) bool.
    Returns (o_unnorm (B,H,Dv) f32, m (B,H) f32, l (B,H) f32) — the same
    partials contract as models.attention.attention_partials.  int8 KV
    passes k_scale/v_scale (B,W,Hkv) f32; the dequant folds into the
    score/value contractions (never a materialized f32 ring)."""
    from repro.models.attention import attention_partials
    return attention_partials(q, k, v, valid, scale=scale,
                              attn_softcap=attn_softcap,
                              k_scale=k_scale, v_scale=v_scale)


def paged_gqa_decode_ref(q, layer_cache, pos, *, scale: float,
                         attn_softcap: float = 0.0, window: int = 0):
    """The paged-decode oracle: gather a dense ring view of the mapped
    blocks (``kvcache.paged_view``) and run the partials over it — the
    exact composition the hot path used before the page-table-native
    kernels, kept as the bit-reference and the CPU execution path."""
    from repro.models import kvcache
    from repro.models.attention import attention_partials, decode_valid_mask
    ring = kvcache.paged_view(layer_cache)
    valid = decode_valid_mask(ring["slot_pos"], pos, window)
    kw = {}
    if "k_scale" in ring:
        kw = dict(k_scale=ring["k_scale"], v_scale=ring["v_scale"])
    return attention_partials(q, ring["k"], ring["v"], valid, scale=scale,
                              attn_softcap=attn_softcap, **kw)


def paged_mla_decode_ref(qcat, layer_cache, pos, *, scale: float):
    """Absorbed-MLA paged-decode oracle: dense latent ring view, key =
    concat(ckv, kr) as a single kv head, value = the latent."""
    from repro.models import kvcache
    from repro.models.attention import attention_partials, decode_valid_mask
    ring = kvcache.paged_view(layer_cache)
    valid = decode_valid_mask(ring["slot_pos"], pos, 0)
    kcat = jnp.concatenate([ring["ckv"], ring["kr"]], -1)[:, :, None, :]
    return attention_partials(qcat, kcat.astype(qcat.dtype),
                              ring["ckv"][:, :, None, :], valid, scale=scale)


def moe_ffn_ref(xbuf, wi, wo, *, act: str = "silu"):
    """xbuf: (E,C,D); wi: (E,D,2,F); wo: (E,F,D) -> (E,C,D)."""
    actf = {"silu": jax.nn.silu,
            "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[act]
    h = jnp.einsum("ecd,edgf->ecgf", xbuf.astype(jnp.float32),
                   wi.astype(jnp.float32))
    y = actf(h[..., 0, :]) * h[..., 1, :]
    out = jnp.einsum("ecf,efd->ecd", y, wo.astype(jnp.float32))
    return out.astype(xbuf.dtype)

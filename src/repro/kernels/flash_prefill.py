"""Pallas flash-attention kernel for prefill/training (TPU target,
interpret-validated on CPU).

Causal (optionally sliding-window, optionally softcapped) GQA attention
tiled for VMEM: (bq × D) query tiles stream against (bk × D) KV tiles with
the running (max, sumexp, accumulator) triple in VMEM scratch — the full
(S × S) score matrix never exists, matching models.common.chunked_attention
(the pure-jnp prefill path) tile for tile.

Grid: (B·H, Sq/bq, Skv/bk); the KV-head index is derived from the query
head (GQA sharing).  The last grid dim is sequential so the scratch triple
carries across KV tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            scale: float, causal: bool, window: int, attn_softcap: float,
            block_q: int, block_k: int, blocks_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32) * scale              # (bq, D)
    k = k_ref[0].astype(jnp.float32)                      # (bk, D)
    v = v_ref[0].astype(jnp.float32)                      # (bk, Dv)
    kv_len = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
    kv_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = kv_pos[None, :] < kv_len
    if causal:
        cm = kv_pos[None, :] <= q_pos[:, None]
        if window:
            cm &= kv_pos[None, :] > (q_pos[:, None] - window)
        mask = mask & cm
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None]) * (s > NEG_INF / 2)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1)
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_s[...] = m_safe

    @pl.when(ik == blocks_k - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...][:, None], 1e-30)
                    ).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  attn_softcap: float = 0.0, scale=None, kv_len=None,
                  block_q: int = 256, block_k: int = 256,
                  interpret: bool = True):
    """q: (B,S,H,D); k/v: (B,Skv,Hkv,Dv-compat); kv_len: optional (B,).
    Returns (B,S,H,Dv)."""
    B, S, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = H // Hkv
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)

    pq = (-S) % block_q
    pk = (-Skv) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    if kv_len is None:
        kv_len = jnp.full((B,), Skv, jnp.int32)
    Sp, Skp = S + pq, Skv + pk

    qf = jnp.swapaxes(q, 1, 2).reshape(B * H, Sp, D)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * Hkv, Skp, D)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * Hkv, Skp, Dv)

    grid = (B * H, Sp // block_q, Skp // block_k)

    def kv_idx(bh, iq, ik):
        return (bh // H * Hkv + (bh % H) // G, ik, 0)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        attn_softcap=attn_softcap, block_q=block_q, block_k=block_k,
        blocks_k=Skp // block_k)
    o = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bh, iq, ik: (bh // H,)),
            pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D), kv_idx),
            pl.BlockSpec((1, block_k, Dv), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qf, kf, vf)
    o = jnp.swapaxes(o.reshape(B, H, Sp, Dv), 1, 2)
    return o[:, :S] if pq else o

"""Pallas grouped MoE-FFN kernel (TPU target, interpret-validated on CPU).

The TPU analogue of the paper's paged MoE-FFN GPU kernel (Appendix A.1,
Fig. 11): tokens arrive capacity-bucketed per expert as (E, C, D); the
kernel walks experts on the outer grid dimension — with paged weights,
each expert's (wi, wo) pages are exactly the units the CGOPipe weight
streamer double-buffers, so the grid order IS the page-consumption order.

Tiling: grid (E, C/bc, F/bf).  For each (expert, token-block) the F
dimension is the innermost (sequential) loop: the gate/up projections for
an F-tile are computed, activated, multiplied, and immediately folded into
the (bc, D) output accumulator via the down-projection tile — the (bc, F)
hidden activation never exists in HBM.  VMEM per step ≈
bc*D + D*2*bf + bf*D + bc*D(f32 acc), MXU-aligned for bf, bc multiples
of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wi_ref, wo_ref, si_ref, so_ref, o_ref, acc, *,
            act: str, blocks_f: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0].astype(jnp.float32)          # (bc, D)
    wi = wi_ref[0].astype(jnp.float32)        # (D, 2, bf)  (int8 ok)
    wo = wo_ref[0].astype(jnp.float32)        # (bf, D)

    h = jax.lax.dot_general(x, wi.reshape(x.shape[1], -1),
                            (((1,), (0,)), ((), ())))       # (bc, 2*bf)
    # fused weight-only dequant: per-expert scale applied to the matmul
    # OUTPUT tile — the bf16/int8 weights never materialize dequantized
    h = h * si_ref[0]
    bf = wi.shape[2]
    gate, up = h[:, :bf], h[:, bf:]
    if act == "silu":
        g = gate * jax.nn.sigmoid(gate)
    else:                                     # gelu (tanh approx)
        g = jax.nn.gelu(gate, approximate=True)
    y = g * up                                # (bc, bf)
    acc[...] += jax.lax.dot_general(y, wo,
                                    (((1,), (0,)), ((), ()))) * so_ref[0]

    @pl.when(f == blocks_f - 1)
    def _fin():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def moe_ffn(xbuf, wi, wo, *, wi_scale=None, wo_scale=None, act: str = "silu",
            block_c: int = 128, block_f: int = 512, interpret: bool = True):
    """xbuf: (E,C,D); wi: (E,D,2,F); wo: (E,F,D) -> (E,C,D).

    wi/wo may be int8 (weight-only quantization): pass per-expert
    wi_scale/wo_scale (E,) f32 and the dequant is fused into the tile
    loop — the paper's §3.3 intensity-raising lever with zero extra HBM
    traffic.

    NOTE on the (D,2,F) layout: the kernel reshapes its (D,2,bf) tile to
    (D, 2*bf) for one MXU matmul; gate rows are h[:, :bf], up rows are
    h[:, bf:], matching the model-side convention.
    """
    E, C, D = xbuf.shape
    F = wo.shape[1]
    if wi_scale is None:
        wi_scale = jnp.ones((E,), jnp.float32)
    if wo_scale is None:
        wo_scale = jnp.ones((E,), jnp.float32)
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    # pad C/F to block multiples
    pc = (-C) % block_c
    pf = (-F) % block_f
    if pc:
        xbuf = jnp.pad(xbuf, ((0, 0), (0, pc), (0, 0)))
    if pf:
        wi = jnp.pad(wi, ((0, 0), (0, 0), (0, 0), (0, pf)))
        wo = jnp.pad(wo, ((0, 0), (0, pf), (0, 0)))
    Cp, Fp = C + pc, F + pf
    grid = (E, Cp // block_c, Fp // block_f)
    kern = functools.partial(_kernel, act=act, blocks_f=Fp // block_f)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, 2, block_f), lambda e, c, f: (e, 0, 0, f)),
            pl.BlockSpec((1, block_f, D), lambda e, c, f: (e, f, 0)),
            pl.BlockSpec((1,), lambda e, c, f: (e,)),
            pl.BlockSpec((1,), lambda e, c, f: (e,)),
        ],
        out_specs=pl.BlockSpec((1, block_c, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, D), xbuf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, D), jnp.float32)],
        interpret=interpret,
    )(xbuf, wi, wo, wi_scale.astype(jnp.float32),
      wo_scale.astype(jnp.float32))
    return out[:, :C] if pc else out

"""jit'd public wrappers for the Pallas kernels.

Dispatch: on TPU the compiled kernels run natively; elsewhere (this CPU
container) ``interpret=True`` executes the kernel bodies in Python for
correctness validation, and callers that want XLA-optimized CPU execution
use the jnp reference path instead (models pass use_kernels=False by
default off-TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gqa_decode as _gqa
from repro.kernels import moe_ffn as _moe
from repro.kernels import ref as _ref


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("scale", "attn_softcap",
                                             "block_w", "impl"))
def gqa_decode(q, k, v, valid, *, scale: float, attn_softcap: float = 0.0,
               block_w: int = 512, impl: str = "auto"):
    """Flash-decode GQA partials. impl: auto | pallas | interpret | ref."""
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        return _ref.gqa_decode_ref(q, k, v, valid, scale=scale,
                                   attn_softcap=attn_softcap)
    interpret = (impl == "interpret") or not on_tpu()
    return _gqa.gqa_decode(q, k, v, valid, scale=scale,
                           attn_softcap=attn_softcap, block_w=block_w,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "impl"))
def moe_ffn(xbuf, wi, wo, wi_scale=None, wo_scale=None, *,
            act: str = "silu", block_c: int = 128,
            block_f: int = 512, impl: str = "auto"):
    """Grouped gated expert FFN (int8 weights + scales supported).
    impl: auto | pallas | interpret | ref."""
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        import jax.numpy as jnp
        if wi_scale is not None:
            wi = wi.astype(jnp.float32) * wi_scale[:, None, None, None]
            wo = wo.astype(jnp.float32) * wo_scale[:, None, None]
        return _ref.moe_ffn_ref(xbuf, wi, wo, act=act)
    interpret = (impl == "interpret") or not on_tpu()
    return _moe.moe_ffn(xbuf, wi, wo, wi_scale=wi_scale, wo_scale=wo_scale,
                        act=act, block_c=block_c,
                        block_f=block_f, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "attn_softcap", "scale", "block_q", "block_k",
    "impl"))
def flash_prefill(q, k, v, kv_len=None, *, causal: bool = True,
                  window: int = 0, attn_softcap: float = 0.0, scale=None,
                  block_q: int = 256, block_k: int = 256,
                  impl: str = "auto"):
    """Prefill/training flash attention. impl: auto | pallas | interpret |
    ref (ref = models.common.chunked_attention, the jnp tile-equivalent)."""
    if impl == "ref" or (impl == "auto" and not on_tpu()):
        from repro.models.common import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 attn_softcap=attn_softcap, scale=scale,
                                 kv_len=kv_len)
    from repro.kernels.flash_prefill import flash_prefill as _fp
    interpret = (impl == "interpret") or not on_tpu()
    return _fp(q, k, v, causal=causal, window=window,
               attn_softcap=attn_softcap, scale=scale, kv_len=kv_len,
               block_q=block_q, block_k=block_k, interpret=interpret)

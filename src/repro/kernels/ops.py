"""jit'd public wrappers for the Pallas kernels.

Dispatch: on TPU the compiled kernels run natively; elsewhere (this CPU
container) ``interpret=True`` executes the kernel bodies in Python for
correctness validation, and callers that want XLA-optimized CPU execution
use the jnp reference path instead (models pass use_kernels=False by
default off-TPU).  Every dispatcher shares one ``impl`` contract:

  * ``auto``      — Pallas on TPU, the jnp reference path elsewhere;
  * ``pallas``    — the kernel, compiled natively (interpreted off-TPU);
  * ``interpret`` — the kernel body under the Pallas interpreter;
  * ``ref``       — the pure-jnp oracle.
"""
from __future__ import annotations

import functools
import json

import jax
import jax.numpy as jnp

from repro.kernels import gqa_decode as _gqa
from repro.kernels import moe_ffn as _moe
from repro.kernels import paged_decode as _paged
from repro.kernels import ref as _ref
from repro.models import kvcache as _kvcache

_IMPLS = ("auto", "pallas", "interpret", "ref")


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


# -- measured dense-vs-paged crossover (benchmarks/bench_transfer.py) -------
#
# The paged kernel gathers mapped_blocks × block_bytes; the dense path reads
# the whole B × max_seq ring but with simpler addressing.  On real devices
# there is an occupancy above which dense wins; bench_transfer.py measures
# it and engines resolve impl='auto' against it at init (host-side — the
# impl string stays a static jit arg).  Unmeasured -> always-paged on TPU.

_CROSSOVER: dict = {"occ": None}


def set_paged_crossover(occupancy) -> None:
    """Install (or clear, with None) the measured occupancy threshold at
    which the dense-view path overtakes the paged kernel."""
    _CROSSOVER["occ"] = None if occupancy is None else float(occupancy)


def load_paged_crossover(path: str = "BENCH_transfer.json"):
    """Load the measured crossover from a bench_transfer artifact.  Missing
    or malformed file (or a null measurement — interpret-mode runs record
    none) leaves the threshold unset and returns None."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    occ = data.get("crossover_occupancy")
    if occ is not None:
        set_paged_crossover(occ)
    return _CROSSOVER["occ"]


def paged_auto_impl(occupancy: float) -> str:
    """Resolve impl='auto' for paged decode from the measured crossover.

    Off-TPU the jnp dense-view oracle is always the fast path ('ref').  On
    TPU: the paged kernel below the measured crossover occupancy, the dense
    view at/above it; with no measurement on record, always the kernel
    (paged is the byte-count-optimal default the benches validated)."""
    if not on_tpu():
        return "ref"
    thr = _CROSSOVER["occ"]
    if thr is not None and occupancy >= thr:
        return "ref"
    return "pallas"


def _resolve_impl(impl: str):
    """The shared on-TPU/interpret dance: returns (use_ref, interpret)."""
    if impl not in _IMPLS:
        raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")
    tpu = on_tpu()
    use_ref = impl == "ref" or (impl == "auto" and not tpu)
    return use_ref, (impl == "interpret") or not tpu


@functools.partial(jax.jit, static_argnames=("scale", "attn_softcap",
                                             "block_w", "impl"))
def gqa_decode(q, k, v, valid, *, scale: float, attn_softcap: float = 0.0,
               k_scale=None, v_scale=None, block_w: int = 512,
               impl: str = "auto"):
    """Flash-decode GQA partials. impl: auto | pallas | interpret | ref.
    int8 KV passes k_scale/v_scale (B,W,Hkv) f32 — dequant folds into the
    tiles in both the kernel and the ref path."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        return _ref.gqa_decode_ref(q, k, v, valid, scale=scale,
                                   attn_softcap=attn_softcap,
                                   k_scale=k_scale, v_scale=v_scale)
    return _gqa.gqa_decode(q, k, v, valid, scale=scale,
                           attn_softcap=attn_softcap, k_scale=k_scale,
                           v_scale=v_scale, block_w=block_w,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "attn_softcap",
                                             "window", "impl"))
def paged_gqa_decode(q, layer_cache, pos, *, scale: float,
                     attn_softcap: float = 0.0, window: int = 0,
                     impl: str = "auto"):
    """Paged flash-decode GQA partials, straight through the page table.

    q: (B,H,D); layer_cache: a paged layer-cache slice — head-major block
    arena leaves ``k``/``v`` (Hkv, NB, bt, D*) (+ ``k_scale``/``v_scale``
    (Hkv, NB, bt) for int8), ``slot_pos`` (NB, bt), and ``page_table``
    (B, MB); pos: (B,) decode positions.  Returns the
    ``attention_partials`` triple.

    impl ``ref`` (and ``auto`` off-TPU) is the dense-view oracle: the
    old ``kvcache.paged_view`` + ``attention_partials`` composition —
    the Pallas path gathers only the mapped blocks instead."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        return _ref.paged_gqa_decode_ref(q, layer_cache, pos, scale=scale,
                                         attn_softcap=attn_softcap,
                                         window=window)
    return _paged.paged_gqa_decode(
        q, layer_cache["k"], layer_cache["v"], layer_cache["slot_pos"],
        layer_cache["page_table"], pos, scale=scale,
        attn_softcap=attn_softcap, window=window,
        k_scale=layer_cache.get("k_scale"),
        v_scale=layer_cache.get("v_scale"), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "lat", "impl"))
def paged_mla_decode(qcat, layer_cache, pos, *, scale: float, lat: int,
                     impl: str = "auto"):
    """Paged absorbed-MLA decode partials through the page table.

    qcat: (B,H,lat+dr) — absorbed latent queries ++ rope queries;
    layer_cache: paged MLA slice (``ckv`` (NB, bt, lat), ``kr``
    (NB, bt, dr), ``slot_pos``, ``page_table``); pos: (B,).  The value
    is the latent itself (Dv = lat).  impl contract as above."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        return _ref.paged_mla_decode_ref(qcat, layer_cache, pos,
                                         scale=scale)
    return _paged.paged_mla_decode(
        qcat, layer_cache["ckv"], layer_cache["kr"],
        layer_cache["slot_pos"], layer_cache["page_table"], pos,
        scale=scale, lat=lat, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "attn_softcap",
                                             "window", "impl"))
def paged_gqa_decode_fused(q, layer_cache, new, pos, *, scale: float,
                           attn_softcap: float = 0.0, window: int = 0,
                           impl: str = "auto"):
    """Fused decode-write paged GQA: one compiled step that both attends
    over the fresh token and scatters it into the arena — decode no longer
    dispatches ``kvcache.write_decode_paged`` separately before attention.

    q: (B,H,D); layer_cache: the *pre-write* paged slice (head-major
    arena); new: the decode-step write dict — ``k``/``v`` (B,1,Hkv,D*)
    (+ ``k_scale``/``v_scale`` (B,1,Hkv) for int8); pos: (B,).  Returns
    ``((o_unnorm, m, l), new_cache)``.

    Bit-identity: the kernel merges the fresh token (pre-cast to the arena
    dtype, exactly as the scatter casts it) into its target block's tile
    in-register before any score math, so attention over the un-written
    arena equals write-then-attend term-by-term; the ref branch simply
    scatters first and runs the dense-view oracle."""
    use_ref, interpret = _resolve_impl(impl)
    new_cache = _kvcache._decode_scatter(layer_cache, new, pos)
    if use_ref:
        part = _ref.paged_gqa_decode_ref(q, new_cache, pos, scale=scale,
                                         attn_softcap=attn_softcap,
                                         window=window)
        return part, new_cache
    kw = {}
    if "k_scale" in layer_cache:
        kw = dict(k_scale=layer_cache["k_scale"],
                  v_scale=layer_cache["v_scale"],
                  k_scale_new=new["k_scale"][:, 0].astype(jnp.float32),
                  v_scale_new=new["v_scale"][:, 0].astype(jnp.float32))
    part = _paged.paged_gqa_decode(
        q, layer_cache["k"], layer_cache["v"], layer_cache["slot_pos"],
        layer_cache["page_table"], pos, scale=scale,
        attn_softcap=attn_softcap, window=window,
        k_new=new["k"][:, 0].astype(layer_cache["k"].dtype),
        v_new=new["v"][:, 0].astype(layer_cache["v"].dtype),
        interpret=interpret, **kw)
    return part, new_cache


@functools.partial(jax.jit, static_argnames=("scale", "lat", "impl"))
def paged_mla_decode_fused(qcat, layer_cache, new, pos, *, scale: float,
                           lat: int, impl: str = "auto"):
    """Fused decode-write paged MLA (see ``paged_gqa_decode_fused``).
    new: ``ckv`` (B,1,lat) / ``kr`` (B,1,dr).  Returns
    ``((o_unnorm, m, l), new_cache)``."""
    use_ref, interpret = _resolve_impl(impl)
    new_cache = _kvcache._decode_scatter(layer_cache, new, pos)
    if use_ref:
        part = _ref.paged_mla_decode_ref(qcat, new_cache, pos, scale=scale)
        return part, new_cache
    part = _paged.paged_mla_decode(
        qcat, layer_cache["ckv"], layer_cache["kr"],
        layer_cache["slot_pos"], layer_cache["page_table"], pos,
        scale=scale, lat=lat,
        ckv_new=new["ckv"][:, 0].astype(layer_cache["ckv"].dtype),
        kr_new=new["kr"][:, 0].astype(layer_cache["kr"].dtype),
        interpret=interpret)
    return part, new_cache


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "impl"))
def moe_ffn(xbuf, wi, wo, wi_scale=None, wo_scale=None, *,
            act: str = "silu", block_c: int = 128,
            block_f: int = 512, impl: str = "auto"):
    """Grouped gated expert FFN (int8 weights + scales supported).
    impl: auto | pallas | interpret | ref."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        if wi_scale is not None:
            wi = wi.astype(jnp.float32) * wi_scale[:, None, None, None]
            wo = wo.astype(jnp.float32) * wo_scale[:, None, None]
        return _ref.moe_ffn_ref(xbuf, wi, wo, act=act)
    return _moe.moe_ffn(xbuf, wi, wo, wi_scale=wi_scale, wo_scale=wo_scale,
                        act=act, block_c=block_c,
                        block_f=block_f, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "attn_softcap", "scale", "block_q", "block_k",
    "impl"))
def flash_prefill(q, k, v, kv_len=None, *, causal: bool = True,
                  window: int = 0, attn_softcap: float = 0.0, scale=None,
                  block_q: int = 256, block_k: int = 256,
                  impl: str = "auto"):
    """Prefill/training flash attention. impl: auto | pallas | interpret |
    ref (ref = models.common.chunked_attention, the jnp tile-equivalent)."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        from repro.models.common import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 attn_softcap=attn_softcap, scale=scale,
                                 kv_len=kv_len)
    from repro.kernels.flash_prefill import flash_prefill as _fp
    return _fp(q, k, v, causal=causal, window=window,
               attn_softcap=attn_softcap, scale=scale, kv_len=kv_len,
               block_q=block_q, block_k=block_k, interpret=interpret)

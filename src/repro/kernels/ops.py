"""jit'd public wrappers for the Pallas kernels.

Dispatch: on TPU the compiled kernels run natively; elsewhere (this CPU
container) ``interpret=True`` executes the kernel bodies in Python for
correctness validation, and callers that want XLA-optimized CPU execution
use the jnp reference path instead (models pass use_kernels=False by
default off-TPU).  Every dispatcher shares one ``impl`` contract:

  * ``auto``      — Pallas on TPU, the jnp reference path elsewhere;
  * ``pallas``    — the kernel, compiled natively (interpreted off-TPU);
  * ``interpret`` — the kernel body under the Pallas interpreter;
  * ``ref``       — the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gqa_decode as _gqa
from repro.kernels import moe_ffn as _moe
from repro.kernels import paged_decode as _paged
from repro.kernels import ref as _ref

_IMPLS = ("auto", "pallas", "interpret", "ref")


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _resolve_impl(impl: str):
    """The shared on-TPU/interpret dance: returns (use_ref, interpret)."""
    if impl not in _IMPLS:
        raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")
    tpu = on_tpu()
    use_ref = impl == "ref" or (impl == "auto" and not tpu)
    return use_ref, (impl == "interpret") or not tpu


@functools.partial(jax.jit, static_argnames=("scale", "attn_softcap",
                                             "block_w", "impl"))
def gqa_decode(q, k, v, valid, *, scale: float, attn_softcap: float = 0.0,
               k_scale=None, v_scale=None, block_w: int = 512,
               impl: str = "auto"):
    """Flash-decode GQA partials. impl: auto | pallas | interpret | ref.
    int8 KV passes k_scale/v_scale (B,W,Hkv) f32 — dequant folds into the
    tiles in both the kernel and the ref path."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        return _ref.gqa_decode_ref(q, k, v, valid, scale=scale,
                                   attn_softcap=attn_softcap,
                                   k_scale=k_scale, v_scale=v_scale)
    return _gqa.gqa_decode(q, k, v, valid, scale=scale,
                           attn_softcap=attn_softcap, k_scale=k_scale,
                           v_scale=v_scale, block_w=block_w,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "attn_softcap",
                                             "window", "impl"))
def paged_gqa_decode(q, layer_cache, pos, *, scale: float,
                     attn_softcap: float = 0.0, window: int = 0,
                     impl: str = "auto"):
    """Paged flash-decode GQA partials, straight through the page table.

    q: (B,H,D); layer_cache: a paged layer-cache slice — block arena
    leaves ``k``/``v`` (NB, bt, Hkv, D*) (+ ``k_scale``/``v_scale`` for
    int8), ``slot_pos`` (NB, bt), and ``page_table`` (B, MB); pos: (B,)
    decode positions.  Returns the ``attention_partials`` triple.

    impl ``ref`` (and ``auto`` off-TPU) is the dense-view oracle: the
    old ``kvcache.paged_view`` + ``attention_partials`` composition —
    the Pallas path gathers only the mapped blocks instead."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        return _ref.paged_gqa_decode_ref(q, layer_cache, pos, scale=scale,
                                         attn_softcap=attn_softcap,
                                         window=window)
    return _paged.paged_gqa_decode(
        q, layer_cache["k"], layer_cache["v"], layer_cache["slot_pos"],
        layer_cache["page_table"], pos, scale=scale,
        attn_softcap=attn_softcap, window=window,
        k_scale=layer_cache.get("k_scale"),
        v_scale=layer_cache.get("v_scale"), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "lat", "impl"))
def paged_mla_decode(qcat, layer_cache, pos, *, scale: float, lat: int,
                     impl: str = "auto"):
    """Paged absorbed-MLA decode partials through the page table.

    qcat: (B,H,lat+dr) — absorbed latent queries ++ rope queries;
    layer_cache: paged MLA slice (``ckv`` (NB, bt, lat), ``kr``
    (NB, bt, dr), ``slot_pos``, ``page_table``); pos: (B,).  The value
    is the latent itself (Dv = lat).  impl contract as above."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        return _ref.paged_mla_decode_ref(qcat, layer_cache, pos,
                                         scale=scale)
    return _paged.paged_mla_decode(
        qcat, layer_cache["ckv"], layer_cache["kr"],
        layer_cache["slot_pos"], layer_cache["page_table"], pos,
        scale=scale, lat=lat, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "impl"))
def moe_ffn(xbuf, wi, wo, wi_scale=None, wo_scale=None, *,
            act: str = "silu", block_c: int = 128,
            block_f: int = 512, impl: str = "auto"):
    """Grouped gated expert FFN (int8 weights + scales supported).
    impl: auto | pallas | interpret | ref."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        if wi_scale is not None:
            wi = wi.astype(jnp.float32) * wi_scale[:, None, None, None]
            wo = wo.astype(jnp.float32) * wo_scale[:, None, None]
        return _ref.moe_ffn_ref(xbuf, wi, wo, act=act)
    return _moe.moe_ffn(xbuf, wi, wo, wi_scale=wi_scale, wo_scale=wo_scale,
                        act=act, block_c=block_c,
                        block_f=block_f, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "attn_softcap", "scale", "block_q", "block_k",
    "impl"))
def flash_prefill(q, k, v, kv_len=None, *, causal: bool = True,
                  window: int = 0, attn_softcap: float = 0.0, scale=None,
                  block_q: int = 256, block_k: int = 256,
                  impl: str = "auto"):
    """Prefill/training flash attention. impl: auto | pallas | interpret |
    ref (ref = models.common.chunked_attention, the jnp tile-equivalent)."""
    use_ref, interpret = _resolve_impl(impl)
    if use_ref:
        from repro.models.common import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 attn_softcap=attn_softcap, scale=scale,
                                 kv_len=kv_len)
    from repro.kernels.flash_prefill import flash_prefill as _fp
    return _fp(q, k, v, causal=causal, window=window,
               attn_softcap=attn_softcap, scale=scale, kv_len=kv_len,
               block_q=block_q, block_k=block_k, interpret=interpret)

"""Pallas paged flash-decode kernels (TPU target, interpret-validated on
CPU): decode attention that reads K/V **directly through the
``(slot, logical_block) → physical_block`` page table** of the block-
granular KV arena (models.kvcache / core.blockpool), instead of first
gathering a dense ``max_seq``-wide ring view (``kvcache.paged_view``).

Grid layout: one grid step per (batch row, kv head, logical block).  The
page table and the per-row decode positions ride in scalar-prefetch SMEM
(``PrefetchScalarGridSpec``), so each K/V BlockSpec's index map picks the
arena slab ``pt[b, lb]`` *before* the kernel body runs — the block DMA is
issued straight against the physical block, and HBM traffic per step is
``mapped_blocks × block_bytes`` instead of ``B × max_seq`` row bytes.

Arena layout (head-major bt-tiling, ``kvcache.arena_block_axis``): K/V
arrive as ``(Hkv, NB, bt, D)`` and the scale planes as ``(Hkv, NB, bt)``,
so the per-(block, head) BlockSpec slab is a contiguous ``(bt, D)`` tile
whose trailing axes map onto (sublane, lane) natively for every block
size — no transpose sits on the hot path.

Masking invariants (mirrors what ``paged_view`` + ``decode_valid_mask``
compute on the dense view):

  * an **unmapped** logical block (``pt[b, lb] < 0``) clamps its index
    map to physical block 0 and masks the whole block in-kernel — the
    arena's trash block (the scatter target for masked rows) is *never
    read* by the gather side;
  * within a mapped block, validity is the usual
    ``slot_pos >= 0 & slot_pos <= pos`` ring test, evaluated on the
    block's own (1, bt) ``slot_pos`` slab.

**Fused decode-write epilogue**: passing the fresh decode token
(``k_new``/``v_new``, already cast to the arena dtype) merges it into
its target block's tile *in-register* — the tile each grid step computes
on is bit-identical to what the block would hold after
``kvcache.write_decode_paged``, so attention over the un-written arena
equals write-then-attend exactly (including the ring-wrap case, where
the merge shadows the stale token the scatter would overwrite, and the
unmapped case, where the scatter goes to the trash block and the gather
masks it).  The actual arena scatter then runs as part of the same
compiled step (``kernels.ops.paged_*_decode_fused``), not as a separate
dispatch before the kernel.

A running (max, sumexp, accumulator) online-softmax triple lives in VMEM
scratch across the sequential block grid dimension (same structure as
``gqa_decode``), and the kernels return *partials* ``(o_unnorm, m, l)``
— the ``attention_partials`` contract — so the sequence-sharded LSE
combine keeps working.

int8 KV: quantized arenas carry per-(token, head) ``k_scale``/``v_scale``
planes; the kernel folds them per block — ``s = (q·k_int) · k_scale`` and
``acc += (p · v_scale) @ v_int`` — instead of materializing a dequantized
ring (the same folding the jnp ref path applies, so the two agree
term-by-term).

MLA: the absorbed decode form is GQA with one kv head whose key is
``concat(ckv, kr)`` and whose value is ``ckv``; the kernel gathers the
latent and rope leaves per block and computes the score as two partial
dots (``q_lat·ckv + q_rope·kr``) — no concatenated ring is ever built.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_decode_cost(B, H, blocks, bt, D, Dv):
    """pl.CostEstimate for one flash-decode dispatch: the score and value
    contractions over every gathered ring position, exp per score."""
    positions = B * blocks * bt
    return pl.CostEstimate(
        flops=2 * positions * H * (D + Dv),
        bytes_accessed=positions * (D + Dv) * 2 + B * H * (D + Dv) * 4,
        transcendentals=positions * H,
    )


# ---------------------------------------------------------------------------
# GQA (dense or int8 arena)
# ---------------------------------------------------------------------------

def _gqa_kernel(pt_ref, pos_ref,                     # scalar prefetch (SMEM)
                q_ref, k_ref, v_ref, *rest,
                scale: float, attn_softcap: float, window: int,
                blocks_w: int, quantized: bool, fused: bool):
    rest = list(rest)
    kn_ref = vn_ref = kns_ref = vns_ref = None
    if fused:
        kn_ref, vn_ref = rest.pop(0), rest.pop(0)
    if quantized:
        ks_ref, vs_ref = rest.pop(0), rest.pop(0)
        if fused:
            kns_ref, vns_ref = rest.pop(0), rest.pop(0)
    sp_ref, o_ref, m_ref, l_ref, acc, m_s, l_s = rest
    b, w = pl.program_id(0), pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    pos = pos_ref[b]
    sp = sp_ref[0]                                   # (bt,) this block's ring
    k = k_ref[0, 0]                                  # (bt, D) arena dtype
    v = v_ref[0, 0]                                  # (bt, Dv)
    if quantized:
        ks = ks_ref[0, 0]                            # (bt,)
        vs = vs_ref[0, 0]
    if fused:
        # merge the fresh token into its target block's tile in-register:
        # the tile then equals the post-write_decode_paged block exactly
        # (k_new is pre-cast to the arena dtype), so attention over the
        # un-written arena is bit-identical to write-then-attend
        bt = sp.shape[0]
        i = pos % (blocks_w * bt)
        hit = (w == i // bt) & (pt_ref[b, w] >= 0)
        sel = (jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
               == i % bt) & hit                      # (bt, 1)
        k = jnp.where(sel, kn_ref[0, 0][None, :], k)
        v = jnp.where(sel, vn_ref[0, 0][None, :], v)
        sp = jnp.where(sel[:, 0], pos, sp)
        if quantized:
            ks = jnp.where(sel[:, 0], kns_ref[0, 0], ks)
            vs = jnp.where(sel[:, 0], vns_ref[0, 0], vs)
    valid = (pt_ref[b, w] >= 0) & (sp >= 0) & (sp <= pos)
    if window:
        valid &= sp > pos - window

    q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
    s = jax.lax.dot_general(q, k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())))         # (G, bt)
    if quantized:
        s = s * ks[None, :]
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None]) * (s > NEG_INF / 2)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1)
    if quantized:
        p = p * vs[None, :]
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())))   # (G, Dv)
    # keep the TRUE running max (NEG_INF while nothing valid yet): an
    # all-invalid early block must not clamp the max to 0, or a later
    # block with a negative true max would report m = 0 instead of the
    # oracle's max
    m_s[...] = m_new

    @pl.when(w == blocks_w - 1)
    def _fin():
        o_ref[0, 0] = acc[...]
        m_ref[0, 0] = jnp.where(m_s[...] <= NEG_INF / 2, 0.0, m_s[...])
        l_ref[0, 0] = l_s[...]


def paged_gqa_decode(q, k, v, slot_pos, page_table, pos, *, scale: float,
                     attn_softcap: float = 0.0, window: int = 0,
                     k_scale=None, v_scale=None,
                     k_new=None, v_new=None,
                     k_scale_new=None, v_scale_new=None,
                     interpret: bool = True):
    """q: (B,H,D); k/v: (Hkv, NB, bt, D*) head-major block arena (last
    block = trash, never read); slot_pos: (NB, bt) int32; page_table:
    (B, MB) int32 (-1 = unmapped); pos: (B,) int32 query positions.  int8
    arenas pass k_scale/v_scale (Hkv, NB, bt) f32.  The fused decode-write
    epilogue passes the fresh token k_new/v_new (B, Hkv, D*) — already in
    the arena dtype — (+ k_scale_new/v_scale_new (B, Hkv) for int8); it is
    merged into its target block's tile in-register.  Returns partials
    (o_unnorm (B,H,Dv) f32, m (B,H) f32, l (B,H) f32)."""
    B, H, D = q.shape
    Hkv, _, bt, Dv = v.shape
    MB = page_table.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    quantized = k_scale is not None
    fused = k_new is not None
    page_table = page_table.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def idx_q(b, h, w, pt, ps):
        return (b, h, 0, 0)

    def idx_blk(b, h, w, pt, ps):
        # unmapped -> physical block 0, fully masked in-kernel (the trash
        # block at the arena's end is a scatter-only target)
        return (h, jnp.maximum(pt[b, w], 0), 0, 0)

    def idx_scale(b, h, w, pt, ps):
        return (h, jnp.maximum(pt[b, w], 0), 0)

    def idx_sp(b, h, w, pt, ps):
        return (jnp.maximum(pt[b, w], 0), 0)

    def idx_new(b, h, w, pt, ps):
        return (b, h, 0)

    in_specs = [
        pl.BlockSpec((1, 1, G, D), idx_q),
        pl.BlockSpec((1, 1, bt, D), idx_blk),
        pl.BlockSpec((1, 1, bt, Dv), idx_blk),
    ]
    inputs = [qg, k, v]
    if fused:
        in_specs += [pl.BlockSpec((1, 1, D), idx_new),
                     pl.BlockSpec((1, 1, Dv), idx_new)]
        inputs += [k_new, v_new]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, bt), idx_scale),
                     pl.BlockSpec((1, 1, bt), idx_scale)]
        inputs += [k_scale, v_scale]
        if fused:
            in_specs += [pl.BlockSpec((1, 1), lambda b, h, w, pt, ps: (b, h)),
                         pl.BlockSpec((1, 1), lambda b, h, w, pt, ps: (b, h))]
            inputs += [k_scale_new, v_scale_new]
    in_specs.append(pl.BlockSpec((1, bt), idx_sp))
    inputs.append(slot_pos)

    kern = functools.partial(_gqa_kernel, scale=scale,
                             attn_softcap=attn_softcap, window=window,
                             blocks_w=MB, quantized=quantized, fused=fused)
    o, m, l = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, MB),
            in_specs=in_specs,
            out_specs=(
                pl.BlockSpec((1, 1, G, Dv), idx_q),
                pl.BlockSpec((1, 1, G), lambda b, h, w, pt, ps: (b, h, 0)),
                pl.BlockSpec((1, 1, G), lambda b, h, w, pt, ps: (b, h, 0)),
            ),
            scratch_shapes=[
                pltpu.VMEM((G, Dv), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, Hkv, G, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        ),
        cost_estimate=_flash_decode_cost(B, H, MB, bt, D, Dv),
        interpret=interpret,
    )(page_table, pos, *inputs)
    return o.reshape(B, H, Dv), m.reshape(B, H), l.reshape(B, H)


# ---------------------------------------------------------------------------
# MLA (absorbed decode over the latent arena)
# ---------------------------------------------------------------------------

def _mla_kernel(pt_ref, pos_ref,
                q_ref, ckv_ref, kr_ref, *rest,
                scale: float, lat: int, blocks_w: int, fused: bool):
    rest = list(rest)
    cn_ref = rn_ref = None
    if fused:
        cn_ref, rn_ref = rest.pop(0), rest.pop(0)
    sp_ref, o_ref, m_ref, l_ref, acc, m_s, l_s = rest
    b, w = pl.program_id(0), pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    pos = pos_ref[b]
    sp = sp_ref[0]
    ckv = ckv_ref[0]                                 # (bt, lat) arena dtype
    kr = kr_ref[0]                                   # (bt, dr)
    if fused:
        # in-register merge of the fresh latent — see the GQA kernel note
        bt = sp.shape[0]
        i = pos % (blocks_w * bt)
        hit = (w == i // bt) & (pt_ref[b, w] >= 0)
        sel = (jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
               == i % bt) & hit                      # (bt, 1)
        ckv = jnp.where(sel, cn_ref[0][None, :], ckv)
        kr = jnp.where(sel, rn_ref[0][None, :], kr)
        sp = jnp.where(sel[:, 0], pos, sp)
    valid = (pt_ref[b, w] >= 0) & (sp >= 0) & (sp <= pos)

    q = q_ref[0].astype(jnp.float32) * scale         # (H, lat + dr)
    ckv = ckv.astype(jnp.float32)
    kr = kr.astype(jnp.float32)
    # score against concat(ckv, kr) without building the concat: two
    # partial dots over the latent and rope halves
    s = jax.lax.dot_general(q[:, :lat], ckv, (((1,), (1,)), ((), ()))) \
        + jax.lax.dot_general(q[:, lat:], kr, (((1,), (1,)), ((), ())))
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None]) * (s > NEG_INF / 2)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1)
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())))            # (H, lat)
    m_s[...] = m_new          # true running max; see the GQA kernel note

    @pl.when(w == blocks_w - 1)
    def _fin():
        o_ref[0] = acc[...]
        m_ref[0] = jnp.where(m_s[...] <= NEG_INF / 2, 0.0, m_s[...])
        l_ref[0] = l_s[...]


def paged_mla_decode(qcat, ckv, kr, slot_pos, page_table, pos, *,
                     scale: float, lat: int,
                     ckv_new=None, kr_new=None, interpret: bool = True):
    """Absorbed MLA decode over the latent block arena.  qcat:
    (B, H, lat + dr) — absorbed latent queries ++ rope queries; ckv:
    (NB, bt, lat); kr: (NB, bt, dr); slot_pos: (NB, bt); page_table:
    (B, MB); pos: (B,).  The fused decode-write epilogue passes the fresh
    latents ckv_new (B, lat) / kr_new (B, dr) in the arena dtype.  The
    attended value is the latent itself, so the partials come back as
    (o_unnorm (B,H,lat) f32, m, l)."""
    B, H, _ = qcat.shape
    _, bt, _ = ckv.shape
    dr = kr.shape[-1]
    MB = page_table.shape[1]
    fused = ckv_new is not None
    page_table = page_table.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def idx_blk2(b, w, pt, ps):
        return (jnp.maximum(pt[b, w], 0), 0, 0)

    in_specs = [
        pl.BlockSpec((1, H, lat + dr), lambda b, w, pt, ps: (b, 0, 0)),
        pl.BlockSpec((1, bt, lat), idx_blk2),
        pl.BlockSpec((1, bt, dr), idx_blk2),
    ]
    inputs = [qcat, ckv, kr]
    if fused:
        in_specs += [pl.BlockSpec((1, lat), lambda b, w, pt, ps: (b, 0)),
                     pl.BlockSpec((1, dr), lambda b, w, pt, ps: (b, 0))]
        inputs += [ckv_new, kr_new]
    in_specs.append(pl.BlockSpec(
        (1, bt), lambda b, w, pt, ps: (jnp.maximum(pt[b, w], 0), 0)))
    inputs.append(slot_pos)

    kern = functools.partial(_mla_kernel, scale=scale, lat=lat,
                             blocks_w=MB, fused=fused)
    o, m, l = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, MB),
            in_specs=in_specs,
            out_specs=(
                pl.BlockSpec((1, H, lat), lambda b, w, pt, ps: (b, 0, 0)),
                pl.BlockSpec((1, H), lambda b, w, pt, ps: (b, 0)),
                pl.BlockSpec((1, H), lambda b, w, pt, ps: (b, 0)),
            ),
            scratch_shapes=[
                pltpu.VMEM((H, lat), jnp.float32),
                pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H,), jnp.float32),
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, H, lat), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ),
        cost_estimate=_flash_decode_cost(B, H, MB, bt, lat + dr, lat),
        interpret=interpret,
    )(page_table, pos, *inputs)
    return o, m, l

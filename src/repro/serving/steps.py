"""Serving step functions: prefill and decode (serve_step).

These are the functions the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` shapes, and the engine jits for real serving.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import ExecPolicy, forward, unembed


def make_prefill_step(cfg: ModelConfig,
                      policy: Optional[ExecPolicy] = None) -> Callable:
    """(params, tokens, extras...) -> last-position logits.
    The prefill_* dry-run shapes lower this without a cache (pure
    prompt-processing throughput); the engine variant below fills one."""

    def prefill_step(params, tokens, **extras):
        out = forward(cfg, params, tokens, mode="train", policy=policy,
                      **extras)
        logits = unembed(cfg, params, out["hidden"][:, -1])
        return logits

    return prefill_step


def make_prefill_fill_step(cfg: ModelConfig,
                           policy: Optional[ExecPolicy] = None) -> Callable:
    """Engine path: also writes the KV cache."""

    def prefill_step(params, tokens, cache, **extras):
        out = forward(cfg, params, tokens, cache=cache, mode="prefill",
                      policy=policy, **extras)
        logits = unembed(cfg, params, out["hidden"][:, -1])
        return logits, out["cache"]

    return prefill_step


def make_serve_step(cfg: ModelConfig,
                    policy: Optional[ExecPolicy] = None) -> Callable:
    """One decode step: (params, cache, tokens (B,1)) ->
    (next_token (B,), logits (B,V), new_cache).  Greedy head; the engine
    applies temperature sampling on the returned logits instead when
    configured."""

    def serve_step(params, cache, tokens):
        out = forward(cfg, params, tokens, cache=cache, mode="decode",
                      policy=policy)
        logits = unembed(cfg, params, out["hidden"][:, -1])
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, out["cache"]

    return serve_step

"""Serving step functions: prefill, decode (serve_step), and the masked
multi-token ``decode_chunk`` used by the continuous-batching engine.

These are the functions the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` shapes, and the engine jits for real serving.

Expert-granular paging (core.paging.PagedWeights with expert manifests)
changes the step signatures: each step takes a trailing ``expert_state``
pytree ({key: (pool, resident_map)} — the device residency snapshot) and
returns per-layer expert activation counts so the engine's host-side
residency cache can learn popularity and account H2D traffic.
``_expert_granular`` is the single switch deciding which shape a factory
produces.

Block-granular paged KV changes no signatures at all: the cache pytree
the engine composes per dispatch carries the shared block arena plus a
``page_table`` leaf per paged period position, and
``models.attention`` dispatches decode writes/gathers on its presence
(``kvcache.is_paged``).  ``decode_chunk`` therefore runs unchanged over
dense and paged pools — the masked-row semantics (frozen ``pos``,
garbage scatter at the frozen slot) land in the trash block when a row
maps no blocks there.  Prefill (monolithic fill AND staged chunks)
always runs on a dense scratch; the paged pool is only ever written by
the slot-insert ops, with blocks booked host-side by core.blockpool.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import paging
from repro.models.model import ExecPolicy, forward, unembed
from repro.serving.sampling import sample


def _expert_granular(paged_blocks) -> bool:
    return (isinstance(paged_blocks, paging.PagedWeights)
            and bool(paged_blocks.expert_manifests))


def make_prefill_step(cfg: ModelConfig,
                      policy: Optional[ExecPolicy] = None) -> Callable:
    """(params, tokens, extras...) -> last-position logits.
    The prefill_* dry-run shapes lower this without a cache (pure
    prompt-processing throughput); the engine variant below fills one."""

    def prefill_step(params, tokens, **extras):
        out = forward(cfg, params, tokens, mode="train", policy=policy,
                      **extras)
        logits = unembed(cfg, params, out["hidden"][:, -1])
        return logits

    return prefill_step


def make_prefill_fill_step(cfg: ModelConfig,
                           policy: Optional[ExecPolicy] = None,
                           *, paged_blocks=None) -> Callable:
    """Engine path: also writes the KV cache.  `lens` (B,) are the true
    per-row prompt lengths: logits are taken at each row's own final
    position (hidden[:, -1] would read the zero-padded tail for any row
    shorter than the bucket width) and the cache's pos is set per row."""

    expert = _expert_granular(paged_blocks)

    def prefill_step(params, tokens, cache, lens, expert_state=None):
        out = forward(cfg, params, tokens, cache=cache, mode="prefill",
                      policy=policy, paged_blocks=paged_blocks,
                      expert_state=expert_state)
        cache = out["cache"]
        cache["pos"] = lens.astype(jnp.int32)
        idx = jnp.maximum(lens - 1, 0)
        hidden = jnp.take_along_axis(
            out["hidden"], idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = unembed(cfg, params, hidden)
        if expert:
            return logits, cache, out["expert_counts"]
        return logits, cache

    return prefill_step


def make_prefill_chunk(cfg: ModelConfig, policy: Optional[ExecPolicy] = None,
                       *, paged_blocks=None) -> Callable:
    """Chunked-prefill admission step (the CGOPipe overlap path): process
    ONE fixed-width chunk of a prompt at the offset recorded in
    cache["pos"], writing its KV into the ring incrementally and carrying
    hidden state to the final-position logits.

    (params, tokens (B,C), cache, fill_len (B,) i32) -> (logits, cache)

    `fill_len` is the number of true tokens in this chunk (< C only for
    the final chunk); the returned logits are taken at the chunk's last
    true position, so the call covering the end of the prompt yields
    exactly the logits a monolithic prefill would produce there.  The
    returned cache's pos advances by fill_len — feeding chunks back in
    sequence drains a prompt of any length through one compiled shape per
    chunk-width bucket."""

    expert = _expert_granular(paged_blocks)

    def prefill_chunk(params, tokens, cache, fill_len, expert_state=None):
        out = forward(cfg, params, tokens, cache=cache, mode="chunk_prefill",
                      policy=policy, paged_blocks=paged_blocks,
                      fill_len=fill_len, expert_state=expert_state)
        idx = jnp.maximum(fill_len - 1, 0)
        hidden = jnp.take_along_axis(
            out["hidden"], idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = unembed(cfg, params, hidden)
        if expert:
            return logits, out["cache"], out["expert_counts"]
        return logits, out["cache"]

    return prefill_chunk


def make_serve_step(cfg: ModelConfig,
                    policy: Optional[ExecPolicy] = None) -> Callable:
    """One decode step: (params, cache, tokens (B,1)) ->
    (next_token (B,), logits (B,V), new_cache).  Greedy head; the engine
    applies temperature sampling on the returned logits instead when
    configured."""

    def serve_step(params, cache, tokens):
        out = forward(cfg, params, tokens, cache=cache, mode="decode",
                      policy=policy)
        logits = unembed(cfg, params, out["hidden"][:, -1])
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, out["cache"]

    return serve_step


def make_decode_chunk(cfg: ModelConfig, policy: Optional[ExecPolicy] = None,
                      *, paged_blocks=None, temperature: float = 0.0,
                      eos_id: int = 1, chunk: int = 8,
                      token_groups: Optional[int] = None) -> Callable:
    """Masked multi-token decode for the slot-pool engine: `chunk` decode
    steps under one ``lax.scan`` so Python/dispatch overhead is amortized
    between admission checks, with a per-row *active* mask so drained /
    free slots are carried along at fixed shape without emitting tokens or
    advancing their cache position.

    (params, cache, tok (B,1), active (B,) bool, rem (B,) i32, key) ->
    (cache, tok, active, rem, toks (chunk,B) i32, emitted (chunk,B) bool)

    Per step, an active row samples a token, decrements its remaining
    quota, and goes inactive on EOS or quota exhaustion; the emitted mask
    marks exactly the (step, row) pairs whose token belongs to a request.
    Inactive rows keep their `pos` (restored after the forward), which is
    what isolates them from active neighbors; the fixed-shape forward
    still scatters a KV write at their frozen `pos % W` slot each step,
    so a drained row's cache content is garbage until `reset_slot` +
    refill — it must never be read without that reset.

    Expert-granular paging adds a trailing ``expert_state`` arg (the
    residency snapshot, constant across the chunk) and a trailing
    ``counts`` output ({key: (chunk, n_steps, E)} — per inner step, so
    the host accounting books each step's distinct activations against
    the snapshot it actually read).

    token_groups=G (module-based batching): B is G·ubatch — the engine
    concatenates G rotation groups' slot caches and the MoE FFN stages
    all G groups' routed tokens against one expert-span read per layer
    step.  counts then gains a group axis: {key: (chunk, n_steps, G, E)}.
    """

    expert = _expert_granular(paged_blocks)

    def decode_chunk(params, cache, tok, active, rem, key,
                     expert_state=None):
        def body(carry, _):
            cache, tok, active, rem, key = carry
            pos0 = cache["pos"]
            out = forward(cfg, params, tok, cache=cache, mode="decode",
                          policy=policy, paged_blocks=paged_blocks,
                          expert_state=expert_state,
                          token_groups=token_groups)
            logits = unembed(cfg, params, out["hidden"][:, -1])
            key, sub = jax.random.split(key)
            nxt = sample(logits, sub, temperature=temperature)
            new_cache = out["cache"]
            new_cache["pos"] = jnp.where(active, new_cache["pos"], pos0)
            emitted = active
            rem2 = rem - emitted.astype(jnp.int32)
            active2 = active & (nxt != eos_id) & (rem2 > 0)
            tok2 = jnp.where(active, nxt, tok[:, 0])[:, None]
            ys = (nxt, emitted) + ((out["expert_counts"],) if expert else ())
            return (new_cache, tok2, active2, rem2, key), ys

        (cache, tok, active, rem, key), ys = jax.lax.scan(
            body, (cache, tok, active, rem, key), None, length=chunk)
        if expert:
            toks, emitted, counts = ys
            return cache, tok, active, rem, toks, emitted, counts
        toks, emitted = ys
        return cache, tok, active, rem, toks, emitted

    return decode_chunk

"""Request scheduler: queue + admission via the paper's Algorithm 2, and
per-slot lifecycle tracking for the continuous-batching slot-pool engine.

Two admission modes:

  * batch (``admit``): the original Algorithm-2 pass — turns the whole
    queue into μ-sized micro-batches with balanced token counts under the
    KV-cache budget (static engine mode);
  * incremental (``admit_to_slots``): FCFS placement of single requests
    into freed slots via Algorithm 2's balance criterion
    (core.batching.place_request), used by the continuous engine to refill
    drained slots mid-flight.

Reservation policy (continuous mode), ``reserve_mode``:

  * ``"worst"`` — every live request reserves its full remaining quota.
    Admission alone guarantees a group's KV footprint can never exceed
    ``cache_tokens``.
  * ``"ewma"`` — EOS-aware: live requests reserve the *expected* remaining
    generation length, fed by a running EWMA of observed generation
    lengths (core.batching.GenLenEWMA).  Admission is optimistic, so the
    engine must call ``enforce_budget`` before each decode chunk; when the
    optimism was wrong, the youngest request in the group is *preempted* —
    its slot freed and the request re-queued at its FCFS position.  A
    preempted request keeps its transcript: re-admission prefills
    prompt + generated-so-far (recompute preemption), so greedy output is
    unchanged.

Slot lifecycle: FREE → PREFILL → DECODE → DRAINED → FREE.  A slot is one
batch row of one rotation group's pooled KV cache; `Slot.history` records
every request id the slot has served (slot recycling is observable).
`Slot.prefill_pos` is the staged-admission sub-state: how many prompt
tokens have been chunk-prefilled so far (overlapped admission drains a
long prompt through PREFILL across many engine ticks while other slots
keep decoding).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.batching import (GenLenEWMA, Request, batch_requests,
                                 place_request, round_to_blocks)


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False
    aborted: bool = False
    preemptions: int = 0             # times evicted + re-queued (ewma mode)
    priority: int = 0                # 0 = most important; higher = shed first
    shed: bool = False               # aborted by degraded-mode backpressure

    @property
    def input_len(self) -> int:
        return len(self.prompt)

    @property
    def effective_prompt(self) -> np.ndarray:
        """What (re-)admission must prefill: the prompt plus everything
        generated before a preemption.  Greedy re-prefill of this prefix
        reproduces the request's continuation exactly (the final-position
        logits are the logits that produced the next token)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def footprint(self) -> int:
        """KV tokens this request occupies once its pending token lands:
        prompt + generated so far (invariant across preemptions)."""
        return self.input_len + len(self.generated)

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


class SlotState(enum.Enum):
    FREE = "free"
    PREFILL = "prefilling"
    DECODE = "decoding"
    DRAINED = "drained"


@dataclass
class Slot:
    gid: int                          # rotation group (micro-batch) index
    row: int                          # batch row within the group's cache
    state: SlotState = SlotState.FREE
    req: Optional[ServeRequest] = None
    prefill_pos: int = 0              # prompt tokens chunk-prefilled so far
    history: List[int] = field(default_factory=list)   # rids served


class Scheduler:
    def __init__(self, *, ubatch: int, num_ubs: int, cache_tokens: int,
                 gen_len: int, max_input_len: Optional[int] = None,
                 on_long_prompt: str = "reject",
                 reserve_mode: str = "worst", ewma_alpha: float = 0.25,
                 block_tokens: Optional[int] = None):
        self.ubatch = ubatch
        self.num_ubs = num_ubs
        self.cache_tokens = cache_tokens
        self.gen_len = gen_len
        self.max_input_len = max_input_len
        # block-granular paged KV: a request occupies whole arena blocks,
        # so every budget charge rounds up to the block boundary (None =
        # dense max_seq-wide pool, token-exact accounting as before)
        self.block_tokens = block_tokens
        assert on_long_prompt in ("reject", "truncate")
        self.on_long_prompt = on_long_prompt
        assert reserve_mode in ("worst", "ewma")
        self.reserve_mode = reserve_mode
        self.gen_ewma = GenLenEWMA(ewma_alpha)
        # SLO-shed backpressure (degradation ladder's bottom rung): when
        # set, NEW work with priority >= shed_priority is rejected at
        # admission — load already admitted keeps its slots, so shedding
        # never perturbs in-flight transcripts
        self.shed_priority: Optional[int] = None
        self.shed_count = 0
        self._rid = itertools.count()
        self.queue: List[ServeRequest] = []
        self.requests: Dict[int, ServeRequest] = {}
        self.slots: List[List[Slot]] = [
            [Slot(g, r) for r in range(ubatch)] for g in range(num_ubs)]

    # ------------------------------------------------------------- submit
    def _shed(self, req: ServeRequest) -> bool:
        """Degraded-mode backpressure: reject the lowest-priority new
        work while the ladder sits at admission_shed."""
        if self.shed_priority is None or req.priority < self.shed_priority:
            return False
        req.aborted = True
        req.done = True
        req.shed = True
        self.shed_count += 1
        return True

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: int = 0) -> int:
        rid = next(self._rid)
        prompt = np.asarray(prompt, np.int32)
        req = ServeRequest(rid, prompt, max_new_tokens, priority=priority)
        self.requests[rid] = req
        if self._shed(req):
            return rid
        if self.max_input_len is not None and \
                len(prompt) + max_new_tokens > self.max_input_len:
            # prompt + generation must fit the per-slot ring width: a longer
            # prompt crashes at prefill, and generation past the ring wraps
            # it and silently evicts the earliest context
            keep = self.max_input_len - max_new_tokens
            if self.on_long_prompt == "truncate" and keep >= 1:
                req.prompt = prompt[:keep]
            else:
                req.aborted = True
                req.done = True
                return rid
        self.queue.append(req)
        return rid

    # -------------------------------------------------- batch admission
    def admit(self, max_groups: Optional[int] = None
              ) -> List[List[ServeRequest]]:
        """Run Algorithm 2 over the current queue; returns micro-batches of
        ServeRequests (≤ max_groups ≤ num_ubs batches of ≤ ubatch requests).
        `max_groups` lets the engine cap admission to the rotation capacity
        it actually has free, keeping the KV pool at its fixed budget."""
        cap = self.num_ubs if max_groups is None \
            else min(max_groups, self.num_ubs)
        if self.shed_priority is not None:
            # degraded-mode shed (same rule as admit_to_slots): only new
            # work that has not generated anything is sheddable
            self.queue = [r for r in self.queue
                          if r.generated or not self._shed(r)]
        if not self.queue or cap <= 0:
            return []
        algo_reqs = [Request(r.rid, r.input_len, r.max_new_tokens)
                     for r in self.queue]
        mbs, aborted = batch_requests(algo_reqs, self.num_ubs, self.ubatch,
                                      self.gen_len, self.cache_tokens)
        aborted_ids = set()
        for r in aborted:
            if r.input_len + self.gen_len > self.cache_tokens:
                # cannot fit even an empty partition under Algorithm 2's
                # uniform gen_len reservation, so batch mode can never
                # place it: abort permanently instead of re-queueing
                # forever (continuous mode reserves per-request quotas
                # instead and would admit some of these)
                req = self.requests[r.rid]
                req.aborted = True
                req.done = True
            else:
                aborted_ids.add(r.rid)         # deferred to a later round
        admitted: List[List[ServeRequest]] = []
        for mb in mbs[:cap]:
            admitted.append([self.requests[r.rid] for r in mb.requests])
        admitted_ids = {r.rid for g in admitted for r in g}
        self.queue = [r for r in self.queue
                      if not r.aborted and (r.rid in aborted_ids
                                            or r.rid not in admitted_ids)]
        return admitted

    # -------------------------------------------- incremental admission
    def _reserve(self, req: ServeRequest) -> int:
        """Generation tokens reserved for a live (or candidate) request
        beyond its current footprint: full remaining quota in "worst"
        mode, EWMA-expected remaining (≥ 1, ≤ quota) in "ewma" mode."""
        worst = req.remaining
        if self.reserve_mode == "worst":
            return worst
        expected = self.gen_ewma.expected(req.max_new_tokens)
        return max(1, min(worst, expected - len(req.generated)))

    def _charge(self, tokens: int) -> int:
        """Budget charge of a footprint: block-rounded when the paged
        arena is in play (whole blocks are occupied), exact otherwise."""
        return round_to_blocks(tokens, self.block_tokens)

    def group_load(self, gid: int) -> Tuple[int, int]:
        """(token footprint + reservations over occupied slots, live
        request count).  Footprints are actual (prompt + generated so
        far); reservations follow reserve_mode — so under "ewma" the load
        of a long-running request grows as it outlives the estimate."""
        toks = cnt = 0
        for s in self.slots[gid]:
            if s.state in (SlotState.PREFILL, SlotState.DECODE) and s.req:
                toks += self._charge(s.req.footprint + self._reserve(s.req))
                cnt += 1
        return toks, cnt

    def admit_to_slots(self) -> List[Slot]:
        """FCFS continuous admission: place queued requests into free slots
        using Algorithm 2's balance criterion with per-request reservations
        (exact remaining quota, or the EWMA expectation in "ewma" mode —
        not the batch-mode uniform gen_len bound).  Marks chosen slots
        PREFILL and returns them; the engine prefills (monolithically or in
        staged chunks) and flips them to DECODE."""
        assigned: List[Slot] = []
        while self.queue:
            req = self.queue[0]
            # degraded-mode shed: reject queued low-priority work that
            # has not started (never a preempted request — its partial
            # transcript must survive re-admission untouched)
            if self.shed_priority is not None and not req.generated \
                    and self._shed(req):
                self.queue.pop(0)
                continue
            # would it fit an *empty* partition — at worst case?  If not
            # it never will (preemption cannot shrink a solo request):
            # abort instead of livelocking at the queue head, and do it
            # in BOTH reservation modes — an optimistic "ewma" placement
            # of a worst-case-unfittable request would just preempt-thrash
            # until its quota ran out or an early EOS rescued it.  The
            # per-row ring bound (max_input_len) is normally enforced at
            # submit; re-checking here keeps recompute preemption safe
            # (effective_prompt grows with the transcript) for callers
            # that skipped the submit guard.
            worst = req.footprint + req.remaining
            if self._charge(worst) > self.cache_tokens or \
                    (self.max_input_len is not None
                     and worst > self.max_input_len):
                self.queue.pop(0)
                req.aborted = True
                req.done = True
                continue
            loads = [self.group_load(g) for g in range(self.num_ubs)]
            sums = [t for t, _ in loads]     # reservations already included
            counts = [c for _, c in loads]
            open_mask = [any(s.state == SlotState.FREE for s in grp)
                         for grp in self.slots]
            # the candidate's whole-block charge rides in as input_len
            # (reserve folded in) so paged admission books arena blocks
            gid = place_request(
                self._charge(req.footprint + self._reserve(req)),
                sums, counts, gen_len=0, reserve=0,
                cache_size=self.cache_tokens, open_mask=open_mask)
            if gid is None:
                break                      # wait for a slot/budget to free
            slot = next(s for s in self.slots[gid]
                        if s.state == SlotState.FREE)
            self.queue.pop(0)
            slot.req = req
            slot.state = SlotState.PREFILL
            slot.prefill_pos = 0
            slot.history.append(req.rid)
            assigned.append(slot)
        return assigned

    # ------------------------------------------ EOS-aware budget guard
    def enforce_budget(self, gid: int, chunk: int) -> List[ServeRequest]:
        """Pre-decode guard for optimistic ("ewma") reservations: ensure
        the group's footprint cannot exceed cache_tokens even if every
        decoding row emits its next `chunk` tokens.  While it could,
        preempt the youngest decoding request (recompute preemption:
        slot freed, request re-queued at its FCFS position with its
        transcript intact).  Returns the preempted requests.  Under
        "worst" reservations admission already guarantees the bound and
        this is a no-op."""
        preempted: List[ServeRequest] = []
        while True:
            live = [s for s in self.slots[gid]
                    if s.state in (SlotState.PREFILL, SlotState.DECODE)
                    and s.req]
            decoding = [s for s in live if s.state == SlotState.DECODE]
            occ_need = sum(
                self._charge(s.req.footprint
                             + (min(chunk, s.req.remaining)
                                if s.state == SlotState.DECODE else 0))
                for s in live)
            if occ_need <= self.cache_tokens or not decoding:
                return preempted
            victim = max(decoding, key=lambda s: s.req.rid)   # youngest
            preempted.append(victim.req)
            self.preempt(victim)

    def preempt(self, slot: Slot) -> None:
        """Evict a decoding request: free its slot and re-queue it at its
        FCFS position (every queued request was submitted later than any
        admitted one, so ordering by rid restores first-come order)."""
        assert slot.state == SlotState.DECODE and slot.req is not None
        req = slot.req
        req.preemptions += 1
        slot.state = SlotState.DRAINED
        self.release(slot)
        i = 0
        while i < len(self.queue) and self.queue[i].rid < req.rid:
            i += 1
        self.queue.insert(i, req)

    # ---------------------------------------------------- slot lifecycle
    def start_decode(self, slot: Slot) -> None:
        assert slot.state == SlotState.PREFILL
        slot.state = SlotState.DECODE

    def prefill_progress(self, slot: Slot, n_tokens: int) -> None:
        """Record that `n_tokens` more prompt tokens of the staged
        admission have been chunk-prefilled into the slot's cache row."""
        assert slot.state == SlotState.PREFILL
        slot.prefill_pos += n_tokens

    def drain(self, slot: Slot) -> None:
        """Row finished (quota reached or EOS): decode output is masked
        from here on; the slot awaits reset + reuse."""
        assert slot.state in (SlotState.PREFILL, SlotState.DECODE)
        slot.state = SlotState.DRAINED

    def release(self, slot: Slot) -> None:
        """Slot re-enters the free pool; its cache row stays masked until
        the next admission's slot-insert fully overwrites it."""
        assert slot.state == SlotState.DRAINED
        slot.state = SlotState.FREE
        slot.req = None
        slot.prefill_pos = 0

    def finish(self, slot: Slot) -> None:
        """Request completed (quota met or EOS): mark done, feed the
        generation-length EWMA, and recycle the slot."""
        assert slot.req is not None
        slot.req.done = True
        self.gen_ewma.observe(len(slot.req.generated))
        self.drain(slot)
        self.release(slot)

    def has_live_slots(self) -> bool:
        return any(s.state in (SlotState.PREFILL, SlotState.DECODE)
                   for grp in self.slots for s in grp)

"""Request scheduler: queue + admission via the paper's Algorithm 2, and
per-slot lifecycle tracking for the continuous-batching slot-pool engine.

Two admission modes:

  * batch (``admit``): the original Algorithm-2 pass — turns the whole
    queue into μ-sized micro-batches with balanced token counts under the
    KV-cache budget (static engine mode);
  * incremental (``admit_to_slots``): FCFS placement of single requests
    into freed slots via Algorithm 2's balance criterion
    (core.batching.place_request), used by the continuous engine to refill
    drained slots mid-flight.

Slot lifecycle: FREE → PREFILL → DECODE → DRAINED → FREE.  A slot is one
batch row of one rotation group's pooled KV cache; `Slot.history` records
every request id the slot has served (slot recycling is observable).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.batching import Request, batch_requests, place_request


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False
    aborted: bool = False

    @property
    def input_len(self) -> int:
        return len(self.prompt)


class SlotState(enum.Enum):
    FREE = "free"
    PREFILL = "prefilling"
    DECODE = "decoding"
    DRAINED = "drained"


@dataclass
class Slot:
    gid: int                          # rotation group (micro-batch) index
    row: int                          # batch row within the group's cache
    state: SlotState = SlotState.FREE
    req: Optional[ServeRequest] = None
    history: List[int] = field(default_factory=list)   # rids served


class Scheduler:
    def __init__(self, *, ubatch: int, num_ubs: int, cache_tokens: int,
                 gen_len: int, max_input_len: Optional[int] = None,
                 on_long_prompt: str = "reject"):
        self.ubatch = ubatch
        self.num_ubs = num_ubs
        self.cache_tokens = cache_tokens
        self.gen_len = gen_len
        self.max_input_len = max_input_len
        assert on_long_prompt in ("reject", "truncate")
        self.on_long_prompt = on_long_prompt
        self._rid = itertools.count()
        self.queue: List[ServeRequest] = []
        self.requests: Dict[int, ServeRequest] = {}
        self.slots: List[List[Slot]] = [
            [Slot(g, r) for r in range(ubatch)] for g in range(num_ubs)]

    # ------------------------------------------------------------- submit
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = next(self._rid)
        prompt = np.asarray(prompt, np.int32)
        req = ServeRequest(rid, prompt, max_new_tokens)
        self.requests[rid] = req
        if self.max_input_len is not None and \
                len(prompt) + max_new_tokens > self.max_input_len:
            # prompt + generation must fit the per-slot ring width: a longer
            # prompt crashes at prefill, and generation past the ring wraps
            # it and silently evicts the earliest context
            keep = self.max_input_len - max_new_tokens
            if self.on_long_prompt == "truncate" and keep >= 1:
                req.prompt = prompt[:keep]
            else:
                req.aborted = True
                req.done = True
                return rid
        self.queue.append(req)
        return rid

    # -------------------------------------------------- batch admission
    def admit(self, max_groups: Optional[int] = None
              ) -> List[List[ServeRequest]]:
        """Run Algorithm 2 over the current queue; returns micro-batches of
        ServeRequests (≤ max_groups ≤ num_ubs batches of ≤ ubatch requests).
        `max_groups` lets the engine cap admission to the rotation capacity
        it actually has free, keeping the KV pool at its fixed budget."""
        cap = self.num_ubs if max_groups is None \
            else min(max_groups, self.num_ubs)
        if not self.queue or cap <= 0:
            return []
        algo_reqs = [Request(r.rid, r.input_len, r.max_new_tokens)
                     for r in self.queue]
        mbs, aborted = batch_requests(algo_reqs, self.num_ubs, self.ubatch,
                                      self.gen_len, self.cache_tokens)
        aborted_ids = set()
        for r in aborted:
            if r.input_len + self.gen_len > self.cache_tokens:
                # cannot fit even an empty partition under Algorithm 2's
                # uniform gen_len reservation, so batch mode can never
                # place it: abort permanently instead of re-queueing
                # forever (continuous mode reserves per-request quotas
                # instead and would admit some of these)
                req = self.requests[r.rid]
                req.aborted = True
                req.done = True
            else:
                aborted_ids.add(r.rid)         # deferred to a later round
        admitted: List[List[ServeRequest]] = []
        for mb in mbs[:cap]:
            admitted.append([self.requests[r.rid] for r in mb.requests])
        admitted_ids = {r.rid for g in admitted for r in g}
        self.queue = [r for r in self.queue
                      if not r.aborted and (r.rid in aborted_ids
                                            or r.rid not in admitted_ids)]
        return admitted

    # -------------------------------------------- incremental admission
    def group_load(self, gid: int) -> Tuple[int, int]:
        """(peak token footprint: prompt + full generation quota per live
        row — already-generated tokens occupy cache, the rest is reserved —
        live request count) over occupied slots."""
        toks = cnt = 0
        for s in self.slots[gid]:
            if s.state in (SlotState.PREFILL, SlotState.DECODE) and s.req:
                toks += s.req.input_len + s.req.max_new_tokens
                cnt += 1
        return toks, cnt

    def admit_to_slots(self) -> List[Slot]:
        """FCFS continuous admission: place queued requests into free slots
        using Algorithm 2's balance criterion with exact per-request
        reservations (live rows reserve their remaining quota, the
        candidate its own max_new_tokens — not the batch-mode uniform
        gen_len bound).  Marks chosen slots PREFILL and returns them; the
        engine prefills and flips them to DECODE."""
        assigned: List[Slot] = []
        while self.queue:
            req = self.queue[0]
            loads = [self.group_load(g) for g in range(self.num_ubs)]
            sums = [t for t, _ in loads]     # reservations already included
            counts = [c for _, c in loads]
            open_mask = [any(s.state == SlotState.FREE for s in grp)
                         for grp in self.slots]
            gid = place_request(req.input_len, sums, counts,
                                gen_len=0, reserve=req.max_new_tokens,
                                cache_size=self.cache_tokens,
                                open_mask=open_mask)
            if gid is None:
                # would it fit an *empty* partition?  If not it never will:
                # abort instead of livelocking at the head of the queue.
                if req.input_len + req.max_new_tokens > self.cache_tokens:
                    self.queue.pop(0)
                    req.aborted = True
                    req.done = True
                    continue
                break                      # wait for a slot/budget to free
            slot = next(s for s in self.slots[gid]
                        if s.state == SlotState.FREE)
            self.queue.pop(0)
            slot.req = req
            slot.state = SlotState.PREFILL
            slot.history.append(req.rid)
            assigned.append(slot)
        return assigned

    # ---------------------------------------------------- slot lifecycle
    def start_decode(self, slot: Slot) -> None:
        assert slot.state == SlotState.PREFILL
        slot.state = SlotState.DECODE

    def drain(self, slot: Slot) -> None:
        """Row finished (quota reached or EOS): decode output is masked
        from here on; the slot awaits reset + reuse."""
        assert slot.state in (SlotState.PREFILL, SlotState.DECODE)
        slot.state = SlotState.DRAINED

    def release(self, slot: Slot) -> None:
        """Slot re-enters the free pool; its cache row stays masked until
        the next admission's slot-insert fully overwrites it."""
        assert slot.state == SlotState.DRAINED
        slot.state = SlotState.FREE
        slot.req = None

    def has_live_slots(self) -> bool:
        return any(s.state in (SlotState.PREFILL, SlotState.DECODE)
                   for grp in self.slots for s in grp)

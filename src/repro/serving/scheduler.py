"""Request scheduler: queue + admission via the paper's Algorithm 2.

Turns a stream of variable-length requests into μ-sized micro-batches with
balanced token counts under the KV-cache budget, defers what doesn't fit,
and tracks request lifecycle (queued → active → finished).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.batching import MicroBatch, Request, batch_requests


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False

    @property
    def input_len(self) -> int:
        return len(self.prompt)


class Scheduler:
    def __init__(self, *, ubatch: int, num_ubs: int, cache_tokens: int,
                 gen_len: int):
        self.ubatch = ubatch
        self.num_ubs = num_ubs
        self.cache_tokens = cache_tokens
        self.gen_len = gen_len
        self._rid = itertools.count()
        self.queue: List[ServeRequest] = []
        self.requests: Dict[int, ServeRequest] = {}

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = next(self._rid)
        req = ServeRequest(rid, np.asarray(prompt, np.int32), max_new_tokens)
        self.queue.append(req)
        self.requests[rid] = req
        return rid

    def admit(self) -> List[List[ServeRequest]]:
        """Run Algorithm 2 over the current queue; returns micro-batches of
        ServeRequests (≤ num_ubs batches of ≤ ubatch requests)."""
        if not self.queue:
            return []
        algo_reqs = [Request(r.rid, r.input_len, r.max_new_tokens)
                     for r in self.queue]
        mbs, aborted = batch_requests(algo_reqs, self.num_ubs, self.ubatch,
                                      self.gen_len, self.cache_tokens)
        aborted_ids = {r.rid for r in aborted}
        admitted: List[List[ServeRequest]] = []
        for mb in mbs[:self.num_ubs]:
            admitted.append([self.requests[r.rid] for r in mb.requests])
        admitted_ids = {r.rid for g in admitted for r in g}
        self.queue = [r for r in self.queue
                      if r.rid in aborted_ids or r.rid not in admitted_ids]
        return admitted

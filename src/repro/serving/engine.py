"""Offloading-aware batch-inference engine (the paper's system, §4).

Execution structure per the paper:
  * requests → Algorithm 2 → `num_ubs` micro-batches of μ rows each
    (Scheduler);
  * zig-zag order: prefill on the accelerator per micro-batch, KV kept in
    the (ring) cache;
  * decode: micro-batches rotate in CGOPipe launch order — while μ-batch j
    runs its accelerator half, batch j+1's attention inputs and the next
    layer's weight *pages* are in flight (on TPU the pages live in host
    memory and stream; on this CPU container the same jitted step consumes
    the page pool in-scan, and the overlap schedule itself is validated by
    core.cgopipe's simulator);
  * per-row positions & slot-position masks make right-padded prompts
    exact (no attention to pad slots).

`paged=True` routes weights through core.paging (pack_block_groups) —
the 2×W_L double-buffer lives in XLA's scan pipelining on TPU.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import paging
from repro.core.policy import Policy
from repro.models import kvcache
from repro.models.model import ExecPolicy, forward, unembed
from repro.serving.sampling import sample
from repro.serving.scheduler import Scheduler, ServeRequest


@dataclass
class EngineConfig:
    ubatch: int = 4                   # μ rows per micro-batch
    num_ubs: int = 2                  # micro-batches in rotation
    max_seq: int = 128
    temperature: float = 0.0
    paged: bool = False               # paged-weight streaming path
    page_elems: int = 1 << 16
    eos_id: int = 1
    seed: int = 0


class _ActiveBatch:
    def __init__(self, requests: List[ServeRequest], cache, last_tokens):
        self.requests = requests
        self.cache = cache
        self.last_tokens = last_tokens       # (μ,1) next input token


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 policy: Optional[ExecPolicy] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.policy = policy
        self.scheduler = Scheduler(
            ubatch=ecfg.ubatch, num_ubs=ecfg.num_ubs,
            cache_tokens=ecfg.max_seq * ecfg.ubatch, gen_len=32)
        self.active: List[_ActiveBatch] = []
        self.key = jax.random.key(ecfg.seed)
        self.paged_blocks = None
        if ecfg.paged:
            self.paged_blocks = paging.pack_block_groups(
                params["blocks"], ecfg.page_elems)
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn)
        self.steps = 0
        self.tokens_out = 0

    # -------------------------------------------------------- jitted fns
    def _prefill_fn(self, params, tokens, cache, lens):
        out = forward(self.cfg, params, tokens, cache=cache, mode="prefill",
                      policy=self.policy, paged_blocks=self.paged_blocks)
        cache = out["cache"]
        cache["pos"] = lens.astype(jnp.int32)       # per-row true lengths
        idx = jnp.maximum(lens - 1, 0)
        hidden = jnp.take_along_axis(
            out["hidden"], idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = unembed(self.cfg, params, hidden)
        return logits, cache

    def _decode_fn(self, params, cache, tokens, key):
        out = forward(self.cfg, params, tokens, cache=cache, mode="decode",
                      policy=self.policy, paged_blocks=self.paged_blocks)
        logits = unembed(self.cfg, params, out["hidden"][:, -1])
        tok = sample(logits, key, temperature=self.ecfg.temperature)
        return tok, out["cache"]

    # ----------------------------------------------------------- public
    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        return self.scheduler.submit(np.asarray(prompt, np.int32),
                                     max_new_tokens)

    def _admit(self):
        for group in self.scheduler.admit():
            mu = self.ecfg.ubatch
            # bucket the padded prompt length so prefill compiles once per
            # bucket, not once per distinct length
            S = max(r.input_len for r in group)
            S = min(-(-S // 16) * 16, self.ecfg.max_seq)
            toks = np.zeros((mu, S), np.int32)
            lens = np.zeros((mu,), np.int32)
            for i, r in enumerate(group):
                toks[i, :r.input_len] = r.prompt
                lens[i] = r.input_len
            # rows beyond len(group) are padding rows (len 0 → masked)
            cache = kvcache.init_cache(self.cfg, mu, self.ecfg.max_seq)
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          cache, jnp.asarray(lens))
            self.key, k = jax.random.split(self.key)
            first = sample(logits, k, temperature=self.ecfg.temperature)
            first = np.asarray(first)
            for i, r in enumerate(group):
                r.generated.append(int(first[i]))
            nxt = jnp.asarray(first[:, None])
            self.active.append(_ActiveBatch(list(group), cache, nxt))

    def step(self) -> bool:
        """One engine tick: admit new work, then one decode step for every
        active micro-batch in CGOPipe rotation order.  Returns True if any
        work was done."""
        self._admit()
        if not self.active:
            return False
        for ab in list(self.active):      # rotation: ub_0, ub_1, ... (Alg. 1)
            self.key, k = jax.random.split(self.key)
            tok, ab.cache = self._decode(self.params, ab.cache,
                                         ab.last_tokens, k)
            tok_np = np.asarray(tok)
            for i, r in enumerate(ab.requests):
                if not r.done:
                    r.generated.append(int(tok_np[i]))
                    self.tokens_out += 1
                    if (len(r.generated) >= r.max_new_tokens
                            or tok_np[i] == self.ecfg.eos_id):
                        r.done = True
            ab.last_tokens = jnp.asarray(tok_np[:, None])
            if all(r.done for r in ab.requests):
                self.active.remove(ab)
        self.steps += 1
        return True

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        while self.step() and self.steps < max_steps:
            pass
        return {rid: r.generated for rid, r in self.scheduler.requests.items()}

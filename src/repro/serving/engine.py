"""Offloading-aware inference engine (the paper's system, §4) with a
continuous-batching slot pool.

Slot-pool architecture (default, ``mode="continuous"``):

  * one persistent KV pool of ``num_ubs × ubatch`` slots is allocated at
    engine construction — ``num_ubs`` rotation groups (the CGOPipe
    micro-batches) of ``ubatch`` batch rows each.  A slot is one row of
    one group's cache; it is recycled in place (models.kvcache
    ``reset_slot`` / ``insert_slot``) without touching its neighbors;
  * the Scheduler tracks per-slot lifecycle (free → prefilling → decoding
    → drained) and admits *individual* requests into freed slots
    mid-flight via Algorithm 2's balance criterion
    (core.batching.place_request) — the effective batch stays saturated
    under the fixed cache budget instead of waiting for whole
    micro-batches to retire;
  * admission prefills a request either monolithically at a bucketed
    prompt width (batch 1, compiled once per bucket), or — with
    ``overlap=True`` — as a *staged chunked prefill*: the prompt drains
    through fixed-width chunks (compiled once per chunk bucket), one
    chunk per engine tick, interleaved with every group's decode chunk.
    This is Algorithm 1's CGOPipe idea applied at request level: a long
    admission no longer stalls the decode groups, and prefill shapes stay
    fixed so novel prompt lengths never trigger fresh XLA compiles on the
    serving path.  Each chunk runs on a double-buffered batch-1 scratch
    cache and lands in the pool row immediately via a partial slot insert
    at the row offset (kvcache.insert_slot_span), keeping per-tick copy
    work bounded and the pool cache donated on the hot path;
  * decode runs one jit-stable fixed-shape chunk per rotation group
    (serving.steps.``decode_chunk``): ``decode_chunk`` tokens under an
    inner ``lax.scan`` with a per-row *active* mask, so finished rows are
    masked — they emit nothing and their cache position is frozen —
    rather than resampled, and Python/dispatch overhead is amortized
    between admission checks;
  * reservations are worst-case remaining quota by default, or EOS-aware
    (``reserve_mode="ewma"``): expected generation lengths from a running
    EWMA, with recompute preemption when the optimism was wrong (the
    scheduler's ``enforce_budget`` runs before every group decode);
  * groups still rotate in CGOPipe launch order (Algorithm 1): while
    group j runs its accelerator half, group j+1's attention inputs and
    the next layer's weight pages are in flight (on TPU the pages live in
    host memory and stream; on this CPU container the same jitted step
    consumes the page pool in-scan).

``mode="static"`` keeps the original whole-micro-batch semantics — a
group is admitted as a unit and retired only when every row finishes —
as the baseline that benchmarks/bench_engine.py compares against.  All
modes share the same masked decode step (static uses chunk size 1 so it
can retire groups every token), so greedy outputs per request are
bit-identical across static / continuous / overlapped admission.

``paged=True`` routes weights through core.paging (pack_block_groups) —
the 2×W_L double-buffer lives in XLA's scan pipelining on TPU.

``expert_paged=True`` switches to the expert-granular path
(pack_block_groups_split): the layer scan streams only each layer's
*shared* span (attention/norm/router), the MoE expert weights are
fetched router-gated per layer — resident spans read in place from a
fixed device pool sized by ``w_gpu_ratio`` (core.residency), misses
streamed from the host store — and, while group j's decode chunk is in
flight, the engine prefetches the expert set group j+1's router gated
last chunk (the request-level analogue of Algorithm 1's j+2 lookahead),
drained in ``paging.transfer_plan`` slices so the H2D work rides
alongside every rotation position's compute.  ``weight_traffic()``
reports the accounted bytes + hit/miss counters.

``module_batch=True`` decouples the attention and expert phases
(module-based batching, the MoE-Gen direction): ``module_groups``
rotation groups decode through ONE combined dispatch per accumulation
window — attention + router run for every group's rows back-to-back,
the MoE layers stage all groups' routed tokens into per-(layer, expert)
buckets, and each activated expert's span streams exactly once per
window (``core.residency.observe_window`` books hits/misses per-window,
not per-group).  Greedy transcripts stay bit-identical to the lockstep
schedule; ``weight_traffic()`` reports the per-phase breakdown and the
measured amortization factor.

See DESIGN.md for the slot pool + admission walkthrough, the paged
weights / expert residency section, and §7 for the two-phase
module-batched schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as dc_replace
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import blockpool, offload, paging, residency
from repro.core.batching import blocks_for_tokens
from repro.kernels import ops as kernel_ops
from repro.models import kvcache
from repro.models.model import ExecPolicy
from repro.runtime import faults as faults_mod
from repro.runtime.transfer import TransferEngine
from repro.runtime.watchdog import Watchdog
from repro.serving import steps as serve_steps
from repro.serving.sampling import sample
from repro.serving.scheduler import Scheduler, ServeRequest, Slot, SlotState


@dataclass
class EngineConfig:
    ubatch: int = 4                   # μ rows per micro-batch / slot group
    num_ubs: int = 2                  # rotation groups in the slot pool
    max_seq: int = 128
    temperature: float = 0.0
    paged: bool = False               # paged-weight streaming path
    page_elems: int = 1 << 16
    eos_id: int = 1
    seed: int = 0
    mode: str = "continuous"          # "continuous" | "static"
    decode_chunk: int = 8             # tokens per inner scan (continuous)
    on_long_prompt: str = "reject"    # "reject" | "truncate" (> max_seq)
    overlap: bool = False             # staged chunked-prefill admission
    prefill_chunk: int = 32           # chunk width for overlapped prefill
    reserve_mode: str = "worst"       # "worst" | "ewma" (EOS-aware)
    cache_tokens: Optional[int] = None  # per-group KV policy budget;
    # default = the physical pool slice (max_seq × ubatch).  A tighter
    # budget (e.g. from the HRM policy) is what makes EOS-aware
    # reservations bite: more concurrent admissions, preemption on miss.
    # ------------------------------------ expert-granular paged weights
    expert_paged: bool = False        # per-(layer, expert) spans + residency
    w_gpu_ratio: float = 0.25         # r_w — sizes the resident expert pool
    expert_slots: Optional[int] = None  # explicit pool size (spans) override
    prefetch: bool = True             # router-ahead prefetch for group j+1
    residency_alpha: float = 0.25     # expert-popularity EWMA step
    residency_victim_quota: int = 1   # demand misses may evict this many
                                      # victims per chunk (cold-start aid)
    # intra-pass predictive prefetch: a per-layer-transition logistic
    # gate predictor (core.residency.GatePredictor, fit online on the
    # scan's activation counts) scores the experts the dispatching
    # group's NEXT chunk will activate at layers i+1..i+lookahead, and
    # enqueues the non-resident ones into the same transfer_plan-sliced
    # pending queue as the router-ahead prefetch (first-come dedupe).
    # Gated under the master `prefetch` switch: prefetch=False disables
    # every lookahead path.
    predict: bool = True
    predict_lookahead: int = 2        # layer shifts predicted per dispatch
    predict_topk: Optional[int] = None  # experts kept per predicted layer
                                      # (default: source activation breadth)
    # intra-pass transfer draining: the pending queue's transfer_plan
    # slices drain BETWEEN the forward passes of one dispatched chunk,
    # so (a) a span the in-flight drain admitted is resident from the
    # chunk's second pass onward, and (b) a demand-missed span streams
    # once and stays staged for the rest of the chunk (later passes hit
    # instead of re-streaming it every step — the PR 3 lockstep model).
    # False restores the frozen-snapshot accounting (the router-ahead
    # baseline the predict/replicate bench sweep compares against).
    intra_pass: bool = True
    # hot-expert replication: this fraction of the residency pool may be
    # pinned persistently to the popularity-EWMA top spans (hysteresis
    # exit at replica_exit × the enter bar) — see ExpertResidency
    replicate_frac: float = 0.0
    replica_exit: float = 0.5
    # ---------------------------------------- block-granular paged KV (r_c)
    kv_paged: bool = False            # shared block arena + page tables
    block_tokens: int = 16            # ring positions per KV block
    kv_gpu_ratio: float = 1.0         # r_c — sizes the device arena; the
                                      # remainder lives in the host tier
    kv_prefetch: bool = True          # stream the next rotation group's
                                      # spilled blocks back in
                                      # paging.transfer_plan slices
    # ------------------------------------ module-based batching (MoE-Gen)
    module_batch: bool = False        # decoupled attention/expert phases:
    # decode `module_groups` rotation groups through ONE combined dispatch
    # per accumulation window — attention/router run per row as before,
    # the MoE layers stage every group's routed tokens against a single
    # expert-span read per layer step, so streamed weight bytes amortize
    # over the window instead of one micro-batch
    module_groups: Optional[int] = None   # groups per window (default: all
                                      # num_ubs; capped at num_ubs)
    module_stage_tokens: Optional[int] = None  # staging-buffer row budget:
    # when G·ubatch would exceed it the window shrinks toward lockstep
    # (capacity overflow never drops tokens)
    # ------------------------------------ fault plane / degradation ladder
    # (runtime.faults / runtime.transfer — see DESIGN.md §10).  Faults may
    # cost throughput but never change tokens: every knob below only moves
    # where bytes stream from and when, never what the jitted step computes
    fault_plan: Optional[object] = None   # runtime.faults.FaultPlan — the
    # injected fault schedule (None = nothing fires; the chokepoints stay
    # wired through the same always-present injector)
    degrade: bool = True                  # degradation ladder armed
    degrade_down_after: int = 3           # consecutive faults per rung down
    degrade_up_after: int = 16            # healthy-op streak per rung up
                                          # (> down_after: hysteresis)
    shed_priority: int = 1                # bottom rung sheds new admissions
                                          # with priority >= this
    max_retries: int = 4                  # bounded-retry budget per cycle
    backoff_s: float = 0.0                # real backoff sleep base (0: none)
    watchdog: bool = True                 # per-dispatch EWMA deadline
    watchdog_policy: str = "log"          # log | skip | abort — "skip" ≡
    # "log" on the serving path (the chunk has already landed when the
    # deadline is scored; the violation still feeds the ladder)
    watchdog_factor: float = 8.0
    watchdog_min_s: float = 0.25


class _SlotGroup:
    """Device-side state of one rotation group: its slice of the KV pool
    plus the last sampled token per row (the next decode input)."""

    def __init__(self, cache, ubatch: int):
        self.cache = cache
        self.last_tok = np.zeros((ubatch,), np.int32)
        # expert-paged: the expert set this group's router gated on the
        # last step of its previous chunk ({key: (L, E) bool}) — the
        # router-ahead prefetch prediction for its next chunk
        self.pred: Dict[str, np.ndarray] = {}


class _ActiveBatch:
    """Static mode: a micro-batch admitted (and retired) as a unit."""

    def __init__(self, requests: List[ServeRequest], cache, last_tokens,
                 gid: Optional[int] = None):
        self.requests = requests
        self.cache = cache
        self.last_tokens = last_tokens       # (μ,) next input token
        self.pred: Dict[str, np.ndarray] = {}
        self.gid = gid                       # paged-KV slot group (kv_paged)


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 policy: Optional[ExecPolicy] = None):
        assert ecfg.mode in ("continuous", "static")
        assert ecfg.watchdog_policy in ("log", "skip", "abort")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.policy = policy
        # ---------------------------- fault plane (runtime.faults, §10)
        self.faults = faults_mod.FaultInjector(ecfg.fault_plan)
        self._ladder = (faults_mod.DegradationLadder(
            down_after=ecfg.degrade_down_after,
            up_after=ecfg.degrade_up_after) if ecfg.degrade else None)
        self._xfer = TransferEngine(
            self.faults, max_retries=ecfg.max_retries,
            backoff_s=ecfg.backoff_s, ladder=self._ladder)
        self._watchdog = (Watchdog(
            deadline_factor=ecfg.watchdog_factor,
            min_deadline_s=ecfg.watchdog_min_s,
            policy=ecfg.watchdog_policy) if ecfg.watchdog else None)
        self._degraded_no_predict = False
        self.scheduler = Scheduler(
            ubatch=ecfg.ubatch, num_ubs=ecfg.num_ubs,
            cache_tokens=ecfg.cache_tokens or ecfg.max_seq * ecfg.ubatch,
            gen_len=32, max_input_len=ecfg.max_seq,
            on_long_prompt=ecfg.on_long_prompt,
            reserve_mode=ecfg.reserve_mode,
            block_tokens=ecfg.block_tokens if ecfg.kv_paged else None)
        self.active: List[_ActiveBatch] = []          # static mode only
        self.key = jax.random.key(ecfg.seed)
        self.paged_blocks = None
        # -------------------------------- expert-granular paged weights
        self.residency: Dict[str, residency.ExpertResidency] = {}
        self._expert_pool: Dict[str, jax.Array] = {}
        # prefetch queue entries are (key, layer, expert, cause,
        # priority) with cause ∈ {"router", "predicted"}; the dedupe set
        # keys on (key, layer, expert) so the two lookahead paths never
        # enqueue (hence never fetch) the same span twice — router-ahead
        # enqueues first and wins ties.  priority (predicted score ×
        # predictor accuracy; None for router entries) feeds the
        # residency victim test — see ExpertResidency.admit
        self._pending: List[Tuple[str, int, int, str, Optional[float]]] = []
        self._pending_set: set = set()
        self._predictors: Dict[str, residency.GatePredictor] = {}
        self._fwd_passes = 0          # forward passes dispatched (traffic)
        if ecfg.expert_paged:
            pw = paging.pack_block_groups_split(params["blocks"],
                                                ecfg.page_elems)
            if not pw.expert_manifests:
                raise ValueError("expert_paged requires a MoE config "
                                 "(no routed-expert leaves found)")
            self.paged_blocks = pw
            for key, em in pw.expert_manifests.items():
                slots = (ecfg.expert_slots if ecfg.expert_slots is not None
                         else residency.slots_from_ratio(
                             ecfg.w_gpu_ratio, em.num_layers,
                             em.num_experts))
                self.residency[key] = residency.ExpertResidency(
                    em.num_layers, em.num_experts, capacity=slots,
                    span_bytes=em.span_bytes, alpha=ecfg.residency_alpha,
                    victim_quota=ecfg.residency_victim_quota,
                    replicate_frac=ecfg.replicate_frac,
                    replica_exit=ecfg.replica_exit,
                    protect_ttl=max(2, ecfg.num_ubs))
                if ecfg.predict and ecfg.prefetch:
                    self._predictors[key] = residency.GatePredictor(
                        em.num_layers, em.num_experts)
                self._expert_pool[key] = jnp.zeros(
                    (max(1, slots), em.pages_per_expert, em.page_elems),
                    pw.expert_pages[key].dtype)
            self._pool_write = jax.jit(
                lambda pool, span, slot: pool.at[slot].set(span),
                donate_argnums=(0,))
        elif ecfg.paged:
            self.paged_blocks = paging.pack_block_groups(
                params["blocks"], ecfg.page_elems)
        # ---------------------------------- block-granular paged KV (r_c)
        # dense-equivalent device bytes of the max_seq-wide slot pool: the
        # baseline every paged-KV report compares against
        dense_abs = kvcache.abstract_cache(cfg, ecfg.ubatch, ecfg.max_seq)
        self._kv_dense_bytes = ecfg.num_ubs * sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(dense_abs))
        self._kv: Optional[blockpool.BlockPool] = None
        self._kv_arena: Dict[str, Dict] = {}
        self._kv_keys: Tuple[str, ...] = ()
        if ecfg.kv_paged:
            if ecfg.max_seq % ecfg.block_tokens:
                raise ValueError("max_seq must be a multiple of "
                                 "block_tokens for the paged KV pool")
            self._kv_keys = kvcache.paged_period_keys(cfg)
            if not self._kv_keys:
                raise ValueError("kv_paged requires at least one "
                                 "full-attention kv/mla period position")
            mb = ecfg.max_seq // ecfg.block_tokens    # blocks per slot
            n_slots = ecfg.num_ubs * ecfg.ubatch
            total = n_slots * mb
            # r_c sizes the arena; the floor keeps one admission's worst
            # case (one slot continuous, one micro-batch static) mappable
            # so progress is always possible — kv_traffic() reports the
            # bytes actually allocated, never the un-clamped ratio
            floor = mb * (ecfg.ubatch if ecfg.mode == "static" else 1)
            device_blocks = min(total, max(
                floor, int(round(ecfg.kv_gpu_ratio * total))))
            self._kv_arena = kvcache.init_paged_arena(
                cfg, device_blocks, ecfg.block_tokens)
            self._kv_trash = device_blocks
            # per-leaf block axis: head-major leaves (k/v/scales) carry the
            # block dimension at stacked axis 2, the rest at axis 1
            block_bytes = sum(
                int(a.nbytes) // a.shape[kvcache.arena_block_axis(
                    name, stacked=True)]
                for g in self._kv_arena.values() for name, a in g.items())
            self._kv = blockpool.BlockPool(n_slots, mb, device_blocks,
                                           block_bytes, faults=self.faults)

            def _host_shape(name, a):
                ax = kvcache.arena_block_axis(name, stacked=True)
                return a.shape[:ax] + (total,) + a.shape[ax + 1:]

            # host tier: big enough to hold every spillable block.  When
            # the backend exposes pinned_host memory the tier lives there
            # as jax arrays (spills/fetches lower to async DMA against
            # pinned pages); otherwise it falls back to pageable numpy
            # (offload emits one structured warning the first time).
            try:
                self._kv_pinned_shd = offload.pinned_host_sharding(
                    faults=self.faults)
            except faults_mod.HostMemoryError:
                # injected placement failure: fall back to the pageable
                # tier now; the ladder's re-promotion path re-probes
                self._kv_pinned_shd = None
                if self._ladder is not None:
                    self._ladder.force_at_least("pageable_host",
                                                site="host_alloc")
            self._kv_pinned = self._kv_pinned_shd is not None
            if self._kv_pinned:
                self._kv_host = {
                    key: {name: jax.device_put(
                        jnp.zeros(_host_shape(name, a), a.dtype),
                        self._kv_pinned_shd)
                        for name, a in g.items()}
                    for key, g in self._kv_arena.items()}
                self._build_host_write(self._kv_pinned_shd)
            else:
                self._kv_host = {
                    key: {name: np.zeros(_host_shape(name, a), a.dtype)
                          for name, a in g.items()}
                    for key, g in self._kv_arena.items()}
            self._kv_read = jax.jit(
                lambda a, i, ax: jnp.take(a, i, axis=ax),
                static_argnums=(2,))
            self._kv_write = jax.jit(
                lambda a, i, v, ax: a.at[(slice(None),) * ax + (i,)].set(v),
                static_argnums=(3,), donate_argnums=(0,))
            self._kv_clear = jax.jit(lambda sp, idx: sp.at[:, idx].set(-1),
                                     donate_argnums=(0,))
            self._kv_pending: List[Tuple[int, int]] = []
            self._kv_pending_set: set = set()
            self._static_gids: List[int] = list(range(ecfg.num_ubs))
            # decode-path gather accounting: the page-table-native kernel
            # reads each row's *mapped* blocks per step; the dense view
            # (kvcache.paged_view) gathered the full max_seq ring for
            # every row of the group
            self._kv_gather_steps = 0
            self._kv_gathered_blocks = 0
            self._kv_view_blocks = 0
            # constant byte terms for kv_traffic(): the arena itself, the
            # dense remainder (window/SSM/prologue/xattn rings), and the
            # page tables
            rem_abs = jax.eval_shape(
                lambda: kvcache.init_cache(cfg, ecfg.ubatch, ecfg.max_seq,
                                           skip_keys=self._kv_keys))
            self._kv_device_bytes = (
                sum(int(a.nbytes) for g in self._kv_arena.values()
                    for a in g.values())
                + ecfg.num_ubs * sum(
                    int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in jax.tree.leaves(rem_abs))
                + int(self._kv.dev.nbytes))
            # resolve impl='auto' against the measured dense-vs-paged
            # crossover once, host-side (the impl string stays a static
            # jit arg): the occupancy proxy is the device-resident
            # fraction of the block pool — at high residency the dense
            # view's simpler addressing can beat the per-block gather on
            # real devices (benchmarks/bench_transfer.py measures where)
            if policy is not None and policy.paged_attn_impl == "auto":
                kernel_ops.load_paged_crossover()
                self.policy = policy = dc_replace(
                    policy, paged_attn_impl=kernel_ops.paged_auto_impl(
                        device_blocks / total))
        self._prefill = jax.jit(serve_steps.make_prefill_fill_step(
            cfg, policy, paged_blocks=self.paged_blocks))
        chunk = ecfg.decode_chunk if ecfg.mode == "continuous" else 1
        # the pool cache is donated on the hot path so slot writes and
        # chunk decodes update it in place instead of copying the pool
        self._decode_chunk = jax.jit(serve_steps.make_decode_chunk(
            cfg, policy, paged_blocks=self.paged_blocks,
            temperature=ecfg.temperature, eos_id=ecfg.eos_id, chunk=chunk),
            donate_argnums=(1,))
        # ------------------------------ module-based batching windows
        self._mg = 1
        self._decode_window_fn = None
        if ecfg.module_batch:
            mg = ecfg.module_groups or ecfg.num_ubs
            mg = max(1, min(mg, ecfg.num_ubs))
            if ecfg.module_stage_tokens is not None:
                # the staging buffer bounds how many groups' routed tokens
                # accumulate per window; overflow shrinks the window
                # toward lockstep instead of dropping tokens
                mg = max(1, min(mg, ecfg.module_stage_tokens // ecfg.ubatch))
            self._mg = mg
            if mg > 1:
                self._decode_window_fn = jax.jit(
                    serve_steps.make_decode_chunk(
                        cfg, policy, paged_blocks=self.paged_blocks,
                        temperature=ecfg.temperature, eos_id=ecfg.eos_id,
                        chunk=chunk, token_groups=mg),
                    donate_argnums=(1,))
        # continuous rotation order, windowed: full windows run combined,
        # the remainder groups fall back to lockstep individually
        self._windows = [list(range(i, min(i + self._mg, ecfg.num_ubs)))
                         for i in range(0, ecfg.num_ubs, self._mg)]
        # configured window width: the degradation ladder's lockstep rung
        # clamps self._mg toward 1 and re-promotion restores this
        self._mg_base = self._mg
        self._insert = jax.jit(kvcache.insert_slot, donate_argnums=(0,))
        # the persistent slot pool: allocated once, recycled per slot
        self.groups: List[_SlotGroup] = []
        self._prefill_scratch = None
        if ecfg.mode == "continuous":
            # with kv_paged the paged period positions live in the shared
            # arena; each group holds only the dense remainder (pos,
            # window/SSM rings, prologue, cross-attention)
            self.groups = [
                _SlotGroup(kvcache.init_cache(cfg, ecfg.ubatch, ecfg.max_seq,
                                              skip_keys=self._kv_keys),
                           ecfg.ubatch)
                for _ in range(ecfg.num_ubs)]
            # batch-1 admission-prefill input: _prefill is functional, so
            # this stays pristine and is reused for every admission
            self._prefill_scratch = kvcache.init_cache(cfg, 1, ecfg.max_seq)
        # ------------------------------ overlapped (chunked) admission
        self._staged: List[Slot] = []      # PREFILL slots, FIFO
        self._stage_scratch = None         # scratch of the in-flight head
        self._free_scratches = []
        if ecfg.overlap:
            if ecfg.mode != "continuous":
                raise ValueError("overlap admission requires continuous mode")
            specs = list(cfg.period) + list(cfg.prologue or ())
            if cfg.encoder_layers or \
                    any(s.cache_kind() == "ssm" for s in specs):
                raise ValueError(
                    "overlapped chunked-prefill admission needs "
                    "attention-only configs (no SSM / encoder layers)")
            self._prefill_chunk = jax.jit(serve_steps.make_prefill_chunk(
                cfg, policy, paged_blocks=self.paged_blocks),
                donate_argnums=(2,))
            self._insert_span = jax.jit(
                kvcache.insert_slot_span, static_argnames=("length",),
                donate_argnums=(0,))
            self._reset = jax.jit(kvcache.reset_slot, donate_argnums=(0,))
            # double-buffered: the next admission's first chunk dispatches
            # against one scratch while the previous one's reset drains
            self._free_scratches = [
                kvcache.init_cache(cfg, 1, ecfg.max_seq) for _ in range(2)]
        self.steps = 0
        self.tokens_out = 0

    # ----------------------------------------------------------- public
    def submit(self, prompt, max_new_tokens: int = 16,
               priority: int = 0) -> int:
        return self.scheduler.submit(np.asarray(prompt, np.int32),
                                     max_new_tokens, priority=priority)

    def step(self) -> bool:
        """One engine tick: admit new work, then decode every rotation
        group in CGOPipe launch order (Algorithm 1).  Continuous mode
        decodes a `decode_chunk`-token masked chunk per group and recycles
        slots that drain; with ``overlap=True`` admission itself is staged
        — one prompt chunk is prefilled per tick, round-robin with the
        decode chunks.  Static mode decodes one token per active
        micro-batch and retires whole groups.  Returns True if any work
        was done."""
        self._ladder_tick()       # safe point: no dispatch in flight
        if self.ecfg.mode == "static":
            return self._step_static()
        return self._step_continuous()

    def run_until_idle(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        while self.step() and self.steps < max_steps:
            pass
        return {rid: r.generated for rid, r in self.scheduler.requests.items()}

    # ----------------------------------------------------- shared pieces
    def _bucket(self, input_len: int) -> int:
        # bucket the padded prompt length so prefill compiles once per
        # bucket, not once per distinct length
        return min(-(-input_len // 16) * 16, self.ecfg.max_seq)

    def _chunk_bucket(self, rem: int) -> int:
        # next power of two capped at the full chunk width — mid-prompt
        # chunks always get the full width, the final partial chunk a
        # smaller bucket, so a C-wide config compiles ≤ log2(C)+1 shapes
        w = 1
        while w < rem:
            w <<= 1
        return min(w, self.ecfg.prefill_chunk)

    # ---------------------------------- expert residency (data+control)
    def _expert_state(self):
        """Snapshot of the residency data plane for one jitted call: the
        device pool plus the (layer, expert) → slot map.  The jit holds
        this snapshot, so control-plane mutations after dispatch can
        never corrupt an in-flight chunk."""
        return {k: (self._expert_pool[k],
                    jnp.asarray(self.residency[k].slot_of))
                for k in self.residency}

    def _copy_span(self, key: str, l: int, e: int, slot: int) -> None:
        # mandatory once residency assigned the slot: the dispatch
        # snapshot says the span is resident, so its bytes must land —
        # injected faults are retried by the transfer engine
        span = self.paged_blocks.expert_pages[key][l, e]

        def _fill():
            self._expert_pool[key] = self._pool_write(
                self._expert_pool[key], span, jnp.int32(slot))

        self._xfer.run_mandatory("expert_copy", _fill,
                                 nbytes=self.residency[key].span_bytes,
                                 on_hostmem=self._demote_host_tier)

    def _resident_snap(self) -> Dict[str, np.ndarray]:
        """Residency mask at dispatch time — what the jitted call's map
        snapshot says is resident; later admissions must not be booked as
        hits for this call's steps."""
        return {k: (r.slot_of >= 0).copy()
                for k, r in self.residency.items()}

    def _account_counts(self, counts, holder=None, snap=None,
                        holders=None, hidden=None) -> None:
        """Book a call's expert activation counts ({key: (..., P, E)}):
        per forward pass, hits/misses against the residency snapshot the
        pass actually read, then demand-admit the missed spans — hottest
        first, so the miss stream doubles as cache fill.  Updates
        `holder.pred` with the last pass's gating (the router-ahead
        prediction for that group's next chunk).

        ``hidden`` ({key: (L, E) bool}) marks the spans whose prefetch
        landed *while this call was in flight* (captured right after the
        sync, before the post-landing drain): a miss on such a span paid
        its bytes but its stream overlapped the dispatched compute, so
        it books as a hidden (stall-free) miss — the per-layer residue
        is the miss-stall estimate ``weight_traffic()`` reports.

        Each booked forward pass also takes one online SGD step of the
        cross-layer gate predictor (host numpy — no retrace), and, when
        replication is on, the replica set is reconciled against the
        refreshed popularity EWMA (promotions copy their spans in).

        With ``intra_pass`` the working resident mask evolves ACROSS the
        chunk's passes instead of staying the frozen dispatch snapshot:
        a demand-missed span streams once and stays staged for the rest
        of the chunk (later passes hit it — the pending queue's
        transfer_plan slices drain between the scan's passes, and the
        pass-local staging buffer holds what already streamed), and the
        spans the in-flight drain admitted count resident from the
        second pass onward.  This changes only WHEN bytes are charged —
        the computation reads identical weights either way.

        With ``holders`` (a module-batched window) the count arrays carry
        a group axis ({key: (..., P, G, E)}): each forward pass books ONE
        per-window union observation (``observe_window`` — an expert span
        streams at most once per window regardless of how many groups
        routed to it), and each group's holder gets its own last-pass
        prediction so router-ahead prefetch stays per group."""
        for key, arr in counts.items():
            r = self.residency[key]
            r.begin_chunk()          # refresh the demand-evict victim quota
            a = np.asarray(arr)
            mask = snap[key] if snap is not None else None
            hid = hidden.get(key) if hidden is not None else None
            gp = self._predictors.get(key)
            intra = self.ecfg.intra_pass and mask is not None
            cur = mask.copy() if intra else mask
            want: Dict[Tuple[int, int], bool] = {}

            def book(si, observe_fn, activated, token_counts):
                nonlocal cur
                if intra and si == 1 and hid is not None:
                    # in-flight admissions have landed by the second pass
                    cur = cur | hid
                missed = observe_fn(activated, token_counts=token_counts,
                                    resident_mask=cur, hidden_mask=hid)
                for pair in missed:
                    want[pair] = True
                    if intra:
                        # streamed once, staged for the rest of the chunk
                        cur[pair] = True

            if holders is not None:
                steps = a.reshape(-1, *a.shape[-3:])      # (n_fwd, P, G, E)
                for si, s in enumerate(steps):
                    per_g = np.moveaxis(s, 1, 0)          # (G, P, E)
                    book(si, r.observe_window, per_g > 0, per_g)
                    if gp is not None:
                        for g_counts in per_g:            # fit per group
                            gp.fit_step(g_counts)
            else:
                steps = a.reshape(-1, *a.shape[-2:])      # (n_fwd, P, E)
                for si, s in enumerate(steps):
                    book(si, r.observe, s > 0, s)
                    if gp is not None:
                        gp.fit_step(s)
            for l, e in want:
                # misses fill free slots only; popularity-driven
                # replacement is the router-ahead prefetch path's job
                slot = r.admit(l, e, demand=True, allow_evict=False)
                if slot is not None:
                    self._copy_span(key, l, e, slot)
            if r.replicate_frac > 0.0:
                for l, e, slot in r.update_replicas():
                    self._copy_span(key, l, e, slot)
            if holder is not None:
                holder.pred[key] = steps[-1] > 0
            if holders is not None:
                last = steps[-1]                          # (P, G, E)
                for g, h in enumerate(holders):
                    h.pred[key] = last[:, g, :] > 0

    def _next_gids(self, gid) -> List[int]:
        """The rotation group(s) decoding next: gid+1 for a lockstep
        group, the following window for a module-batched one."""
        if isinstance(gid, int):
            return [(gid + 1) % self.ecfg.num_ubs]
        g0 = (max(gid) + 1) % self.ecfg.num_ubs
        return [(g0 + j) % self.ecfg.num_ubs for j in range(len(gid))]

    def _enqueue_prediction(self, gid) -> None:
        """Queue the expert set group ``gid+1``'s router gated on the last
        step of its previous chunk (the request-level analogue of
        Algorithm 1's j+2 weight lookahead), hottest-first.  For a
        module-batched window `gid` is the window's gid list and the
        predictions of the NEXT window's groups are queued."""
        for g in self._next_gids(gid):
            nxt = self.groups[g]
            for key, act in nxt.pred.items():
                r = self.residency[key]
                pairs = [(int(l), int(e)) for l, e in zip(*np.nonzero(act))
                         if not r.is_resident(l, e)]
                pairs.sort(key=lambda p: -r.popularity[p])
                for p in pairs:
                    t = (key, *p)
                    if t not in self._pending_set:
                        self._pending.append((*t, "router", None))
                        self._pending_set.add(t)

    def _enqueue_gate_predictions(self, holders) -> None:
        """Intra-pass lookahead: from each dispatching holder's last
        observed gating, the cross-layer GatePredictor scores the experts
        layers i+1..i+lookahead will activate in that holder's NEXT chunk
        and queues the non-resident ones earliest-deadline-first
        (``paging.predicted_drain_order`` — a span must land before the
        scan's consuming layer step).  The entries join the SAME pending
        queue as the router-ahead group-j+1 prefetch and dedupe against
        it first-come (router-ahead enqueues first), so a span predicted
        by both paths is fetched exactly once.  Predicted admissions are
        eviction-protected until first use (residency ``protect_ttl``).

        Suspended (``predict=False`` semantics) while the degradation
        ladder sits at or below its no_predict rung."""
        if self._degraded_no_predict:
            return
        for h in holders:
            for key, act in h.pred.items():
                gp = self._predictors.get(key)
                if gp is None:
                    continue
                r = self.residency[key]
                preds = gp.predict(act,
                                   lookahead=self.ecfg.predict_lookahead,
                                   topk=self.ecfg.predict_topk)
                pairs = [(l, e) for l, e, _ in preds]
                scores = [s for _, _, s in preds]
                for i in paging.predicted_drain_order(pairs, scores):
                    l, e = pairs[i]
                    if r.is_resident(l, e):
                        continue
                    t = (key, l, e)
                    if t not in self._pending_set:
                        # short-horizon priority: the predicted
                        # activation probability discounted by the
                        # predictor's measured accuracy
                        self._pending.append(
                            (*t, "predicted", scores[i] * gp.acc))
                        self._pending_set.add(t)

    def _plan_slice(self, pending: List, gid) -> Tuple[List, List]:
        """This rotation position's ``paging.transfer_plan`` slice of a
        pending transfer queue (shared by the weight and KV prefetch
        drains); returns (chosen, keep).  A module-batched window passes
        its gid list and drains the union of its positions' slices
        (``paging.window_plan``) — the window spans those interleave
        slots, so its in-flight compute covers all of them.

        Fault chokepoint ("plan_drain"): an injected *partial* completes
        only a prefix of the slice (the rest re-queues), a *fail* defers
        the whole slice, a *stall* books a deadline violation — all three
        only delay advisory prefetch work, so tokens are untouched."""
        positions = [gid] if isinstance(gid, int) else list(gid)
        take = set(paging.window_plan(len(pending), self.ecfg.num_ubs,
                                      positions))
        chosen = [t for i, t in enumerate(pending) if i in take]
        keep = [t for i, t in enumerate(pending) if i not in take]
        ev = self.faults.fire("plan_drain")
        if ev is not None and chosen:
            if ev.kind == "partial":
                k = int(len(chosen) * ev.frac)
                chosen, deferred = chosen[:k], chosen[k:]
                keep = deferred + keep
                self._xfer.book_retry("plan_drain")
            elif ev.kind in ("fail", "exhaust", "hostmem"):
                keep = chosen + keep
                chosen = []
                self._xfer.book_retry("plan_drain")
            elif ev.kind == "stall":
                self._xfer.book_stall("plan_drain")
        return chosen, keep

    def _drain_prefetch(self, gid, *, retry_refused: bool) -> None:
        """Transfer this rotation position's ``paging.transfer_plan``
        slice of the pending prefetch queue into the pool.  While a chunk
        is in flight every resident span is pinned, so only free slots
        fill (H2D overlapping compute); refused entries are re-queued to
        retry after the chunk lands (``retry_refused=True``) or dropped
        (the cache is hotter than the prediction)."""
        if not self._pending:
            return
        chosen, keep = self._plan_slice(self._pending, gid)
        requeued = []
        for key, l, e, cause, pri in chosen:
            r = self.residency[key]
            if r.is_resident(l, e):
                self._pending_set.discard((key, l, e))
                continue
            # prefetch: charges span bytes
            slot = r.admit(l, e, cause=cause, priority=pri)
            if slot is not None:
                self._copy_span(key, l, e, slot)
                self._pending_set.discard((key, l, e))
            elif retry_refused:
                requeued.append((key, l, e, cause, pri))
            else:
                self._pending_set.discard((key, l, e))
        self._pending = keep + requeued

    def weight_traffic(self) -> Dict[str, float]:
        """Accounted H2D weight traffic (DESIGN.md §2: on this container
        traffic is modeled, not physically moved).  Whole-layer paging
        streams every group's full span each forward pass; the
        expert-granular path streams the shared spans plus the
        missed/prefetched expert spans booked by core.residency.

        Per-phase breakdown (module-based batching observability):
        ``attn_phase_bytes`` is what the attention phase streams (the
        shared attention/norm/router spans, once per forward pass — a
        window's pass serves all its groups), ``expert_phase_bytes`` is
        the expert-span traffic of the expert phase (misses + prefetch),
        ``bytes_per_token_amortized`` = total / tokens emitted, and
        ``module_groups_effective`` is the MEASURED amortization —
        lockstep-equivalent misses / per-window union misses — so the
        1/G claim is counter-verified, not inferred."""
        out: Dict[str, float] = {"fwd_passes": self._fwd_passes,
                                 "tokens_out": self.tokens_out,
                                 "module_batch": self._mg > 1,
                                 "module_groups": self._mg}
        if self.residency:
            pw = self.paged_blocks
            shared = sum(pw.shared_layer_bytes(k) * pw.manifests[k].num_layers
                         for k in pw.manifests)
            expert_full = sum(
                em.span_bytes * em.num_experts * em.num_layers
                for em in pw.expert_manifests.values())
            c = [r.counters for r in self.residency.values()]
            misses = sum(x.misses for x in c)
            lockstep = sum(x.lockstep_misses for x in c)
            pred_pf = sum(x.predicted_prefetches for x in c)
            out.update(
                mode="expert_paged",
                shared_bytes=shared * self._fwd_passes,
                expert_bytes=sum(x.h2d_bytes for x in c),
                hits=sum(x.hits for x in c),
                misses=misses,
                prefetches=sum(x.prefetches for x in c),
                evictions=sum(x.evictions for x in c),
                hit_rate=(sum(x.hits for x in c)
                          / max(1, sum(x.fetches for x in c))),
                # hit attribution by staging cause (sums to hits) and the
                # predictor/replication observability the policy consumes
                demand_hits=sum(x.demand_hits for x in c),
                router_hits=sum(x.router_hits for x in c),
                predicted_hits=sum(x.predicted_hits for x in c),
                replicated_hits=sum(x.replicated_hits for x in c),
                predicted_prefetches=pred_pf,
                predicted_used=sum(x.predicted_used for x in c),
                prefetch_accuracy=(sum(x.predicted_used for x in c)
                                   / max(1, pred_pf)),
                predictor_accuracy=(
                    float(np.mean([gp.acc
                                   for gp in self._predictors.values()]))
                    if self._predictors else 0.0),
                replications=sum(x.replications for x in c),
                replica_spans=sum(len(r.replicas)
                                  for r in self.residency.values()),
                # stall split: misses whose stream hid behind the
                # consuming dispatch's compute vs those that stalled it,
                # with the stalled bytes resolved per layer (the roofline
                # report divides by link bandwidth for stall time)
                hidden_misses=sum(x.hidden_misses for x in c),
                stall_misses=sum(x.stall_misses for x in c),
                miss_stall_bytes=int(sum(r.miss_stall_bytes.sum()
                                         for r in self.residency.values())),
                miss_stall_bytes_per_layer={
                    k: [int(b) for b in r.miss_stall_bytes]
                    for k, r in self.residency.items()},
                # what whole-layer streaming would have moved for the
                # same passes (shared + every expert span every layer)
                whole_layer_bytes=(shared + expert_full) * self._fwd_passes,
                module_groups_effective=(lockstep / misses if misses
                                         else float(self._mg)),
            )
            out["h2d_bytes"] = out["shared_bytes"] + out["expert_bytes"]
            out["attn_phase_bytes"] = out["shared_bytes"]
            out["expert_phase_bytes"] = out["expert_bytes"]
        elif self.ecfg.paged:
            _, manifests = self.paged_blocks
            per_pass = sum(
                m.pages_per_layer * m.page_elems * m.num_layers
                * np.dtype(m.dtype).itemsize for m in manifests.values())
            out.update(mode="paged", h2d_bytes=per_pass * self._fwd_passes,
                       attn_phase_bytes=per_pass * self._fwd_passes,
                       expert_phase_bytes=0,
                       module_groups_effective=float(self._mg))
        else:
            out.update(mode="resident", h2d_bytes=0, attn_phase_bytes=0,
                       expert_phase_bytes=0,
                       module_groups_effective=float(self._mg))
        out["bytes_per_token_amortized"] = (out["h2d_bytes"]
                                            / max(1, self.tokens_out))
        return out

    # ------------------------------ block-granular paged KV (data+control)
    def _slot_of(self, slot) -> int:
        return slot.gid * self.ecfg.ubatch + slot.row

    def _compose_kv(self, dense_cache: Dict, gid) -> Dict:
        """Assemble the jit-call cache for slot group `gid` (or, for a
        module-batched window, the gid list — the page table then covers
        every window row, group-major): its dense per-slot leaves plus
        the shared block arena and a fresh device page-table snapshot for
        the rows.  The control plane is host-side (core.blockpool); every
        dispatch reads the map at call time, mirroring the
        expert-residency snapshot discipline."""
        b = self.ecfg.ubatch
        gids = [gid] if isinstance(gid, int) else list(gid)
        pt = self._kv.device_table(
            [g * b + r for g in gids for r in range(b)])
        ptj = jnp.asarray(np.ascontiguousarray(
            np.broadcast_to(pt[None], (self.cfg.num_periods,) + pt.shape)))
        cache = dict(dense_cache)
        for key, g in self._kv_arena.items():
            cache[key] = {**g, "page_table": ptj}
        return cache

    def _absorb_kv(self, cache: Dict) -> Dict:
        """Take the (possibly donated-and-rebuilt) arena arrays back out
        of a returned cache; the remainder is the group's dense part."""
        out = dict(cache)
        for key in self._kv_arena:
            g = dict(out.pop(key))
            g.pop("page_table")
            self._kv_arena[key] = g
        return out

    def _kv_spill_op(self, pb: int, hb: int) -> None:
        for key, g in self._kv_arena.items():
            h = self._kv_host[key]
            for name in g:
                ax = kvcache.arena_block_axis(name, stacked=True)
                blk = self._kv_read(g[name], jnp.int32(pb), ax)
                if self._kv_pinned:             # D2H into the pinned tier
                    h[name] = self._kv_host_write(
                        h[name], jnp.int32(hb), blk, ax)
                else:
                    h[name][(slice(None),) * ax + (hb,)] = np.asarray(blk)

    def _kv_fetch_op(self, hb: int, pb: int) -> None:
        for key, g in self._kv_arena.items():
            h = self._kv_host[key]
            for name in list(g):
                ax = kvcache.arena_block_axis(name, stacked=True)
                blk = (self._kv_read(h[name], jnp.int32(hb), ax)
                       if self._kv_pinned else jnp.asarray(
                           h[name][(slice(None),) * ax + (hb,)]))
                g[name] = self._kv_write(g[name], jnp.int32(pb), blk, ax)

    def _kv_exec(self, ops) -> None:
        """Execute a BlockPool plan in order: ``spill`` copies an arena
        block out to the host store (D2H), ``fetch`` copies a host block
        back in (H2D), ``alloc`` marks a fresh block (its slot_pos plane
        is cleared in one batched scatter at the end — stale positions
        from the previous owner must never satisfy a validity mask).

        Spill/fetch ops run through the retrying transfer engine: a plan
        already committed to the pool's map, so its bytes MUST land
        (mandatory, not advisory).  Faults fire before the copy closure
        runs, so a retried op never re-executes a donated-buffer write."""
        fresh = []
        nb = self._kv.block_bytes
        for op in ops:
            if op[0] == "spill":
                _, _s, _lb, pb, hb = op
                self._xfer.run_mandatory(
                    "kv_spill", lambda pb=pb, hb=hb: self._kv_spill_op(pb, hb),
                    nbytes=nb, on_hostmem=self._demote_host_tier)
            elif op[0] == "fetch":
                _, _s, _lb, hb, pb = op
                self._xfer.run_mandatory(
                    "kv_fetch", lambda hb=hb, pb=pb: self._kv_fetch_op(hb, pb),
                    nbytes=nb, on_hostmem=self._demote_host_tier)
            else:                                       # ("alloc", s, lb, pb)
                fresh.append(op[3])
        if fresh:
            # pad to a power-of-two bucket (aimed at the trash block) so
            # the clear scatter compiles a handful of shapes, not one per
            # allocation count
            n = 1
            while n < len(fresh):
                n <<= 1
            idx = np.full((n,), self._kv_trash, np.int32)
            idx[:len(fresh)] = fresh
            idxj = jnp.asarray(idx)
            for key, g in self._kv_arena.items():
                g["slot_pos"] = self._kv_clear(g["slot_pos"], idxj)

    def _kv_ensure(self, fn):
        """Run a BlockPool ensure closure on a path whose refusal is
        fatal or mode-changing (arena-floor asserts / lockstep
        fallbacks follow the call): injected pool exhaustions are
        retried until a genuine answer comes back, so a chaos schedule
        can never trip a floor assert or force a spurious fallback."""
        while True:
            ops, ok, nxt = fn()
            self._kv_exec(ops)
            if ok or not self._kv.last_refusal_injected:
                return ops, ok, nxt
            self._xfer.book_retry("kv_pool")

    def _kv_sweep(self) -> None:
        """Release arena/host blocks of any slot that fell back to FREE
        outside the engine's own retire path (budget preemption)."""
        for grp in self.scheduler.slots:
            for s in grp:
                if s.state == SlotState.FREE:
                    idx = self._slot_of(s)
                    if self._kv.slot_in_use(idx):
                        self._kv.free_slot(idx)

    def _kv_prepare_group(self, gid, chunk: int) -> None:
        """Pre-dispatch guard for the paged pool: every decoding row's
        mapped blocks must be device-resident (attention gathers its
        whole history) and the blocks its next `chunk` tokens will write
        must be mapped.  Cold blocks of other slots spill to the host
        tier to make room; on arena exhaustion the youngest decoding
        request in the group is preempted (recompute preemption — blocks
        freed, request re-queued with its transcript intact).  Retries
        resume each slot at its first unsatisfied block, so every needed
        block books exactly one hit or miss per preparation.

        A module-batched window passes its gid list: all of its groups'
        decoding rows dispatch in ONE combined call, so the protect set —
        and the residency requirement — spans the whole window (preparing
        a later group must never spill an earlier one's just-prepared
        blocks)."""
        gids = [gid] if isinstance(gid, int) else list(gid)
        slots = [s for g in gids for s in self.scheduler.slots[g]]
        booked: Dict[int, int] = {}          # slot idx -> blocks satisfied
        inj_retries = 0
        while True:
            decoding = [s for s in slots if s.state == SlotState.DECODE]
            protect = [self._slot_of(s) for s in decoding]
            ok = True
            for s in decoding:
                idx = self._slot_of(s)
                need = self._kv.blocks_needed(
                    s.req.footprint + min(chunk, s.req.remaining),
                    self.ecfg.block_tokens)
                if booked.get(idx, 0) >= need:
                    continue
                ops, ok, nxt = self._kv.ensure_range(
                    idx, booked.get(idx, 0), need, protect)
                self._kv_exec(ops)
                booked[idx] = nxt
                if not ok:
                    break
            if ok:
                return
            if self._kv.last_refusal_injected:
                # an injected pool-exhaustion refusal, not a real one:
                # retry the draw before paying a preemption.  With a lone
                # decoding slot retries are unbounded (there is no victim
                # to preempt, and the plan's faults are transient by
                # construction); otherwise an exhausted budget books an
                # abort and falls through to genuine recompute preemption.
                inj_retries += 1
                self._xfer.book_retry("kv_pool")
                if inj_retries <= self.ecfg.max_retries \
                        or len(decoding) <= 1:
                    continue
                self._xfer.book_abort("kv_pool")
            assert len(decoding) > 1, \
                "single request exceeds the KV arena (device_blocks floor)"
            victim = max(decoding, key=lambda s: s.req.rid)   # youngest
            self.scheduler.preempt(victim)
            self._kv.free_slot(self._slot_of(victim))
            booked.pop(self._slot_of(victim), None)
            inj_retries = 0

    def _kv_enqueue_prefetch(self, gid) -> None:
        """Queue the next rotation group's spilled blocks (the KV
        analogue of Algorithm 1's weight lookahead): while group `gid`'s
        chunk is in flight, group gid+1's history can stream back.  A
        module-batched window passes its gid list and queues the whole
        next window's spilled blocks."""
        for g in self._next_gids(gid):
            for s in self.scheduler.slots[g]:
                if s.state != SlotState.DECODE:
                    continue
                idx = self._slot_of(s)
                for lb in self._kv.host_resident_blocks(idx):
                    t = (idx, lb)
                    if t not in self._kv_pending_set:
                        self._kv_pending.append(t)
                        self._kv_pending_set.add(t)

    def _kv_drain_prefetch(self, gid) -> None:
        """Promote this rotation position's ``paging.transfer_plan``
        slice of the pending block queue into free arena blocks (no
        demotions on the prefetch path — mirroring residency's
        miss-fills-free-slots rule); entries that became stale or found
        no free block fall back to the demand path."""
        if not self._kv_pending:
            return
        chosen, self._kv_pending = self._plan_slice(self._kv_pending, gid)
        self._kv_pending_set.difference_update(chosen)
        for idx, lb in chosen:
            op = self._kv.prefetch(idx, lb)
            if op is not None:
                self._kv_exec([op])

    def _kv_note_gather(self, gid, steps: int) -> None:
        """Book the decode-path KV gather of one dispatched chunk: the
        paged flash-decode kernels read each row's mapped blocks once per
        decode step (per layer), so gathered bytes scale with the page
        table's mapped-block count — not with ``max_seq`` as the dense
        ``paged_view`` materialization did.  A module-batched window
        passes its gid list (its dispatch gathers every window row)."""
        b = self.ecfg.ubatch
        gids = [gid] if isinstance(gid, int) else list(gid)
        rows = [g * b + r for g in gids for r in range(b)]
        mapped = sum(self._kv.n_mapped(r) for r in rows)
        self._kv_gather_steps += steps
        self._kv_gathered_blocks += mapped * steps
        self._kv_view_blocks += len(rows) * self._kv.blocks_per_slot * steps

    def kv_traffic(self) -> Dict[str, float]:
        """Device-KV accounting: bytes the KV pool actually occupies on
        device vs the dense max_seq-wide equivalent, plus the host-tier
        stream counters (same modeled-traffic discipline as
        ``weight_traffic``)."""
        out: Dict[str, float] = {"tokens_out": self.tokens_out,
                                 "dense_equiv_bytes": self._kv_dense_bytes}
        if self._kv is None:
            out.update(mode="kv_dense",
                       device_kv_bytes=self._kv_dense_bytes,
                       h2d_bytes=0, d2h_bytes=0)
            return out
        arena_bytes = sum(int(a.nbytes) for g in self._kv_arena.values()
                          for a in g.values())
        c = self._kv.counters
        out.update(
            mode="kv_paged",
            block_tokens=self.ecfg.block_tokens,
            device_blocks=self._kv.device_blocks,
            peak_blocks_in_use=self._kv.peak_in_use,
            arena_utilization=(self._kv.peak_in_use
                               / max(1, self._kv.device_blocks)),
            device_kv_bytes=self._kv_device_bytes,
            arena_bytes=arena_bytes,
            hits=c.hits, misses=c.misses, prefetches=c.prefetches,
            spills=c.spills, allocs=c.allocs, frees=c.frees,
            h2d_bytes=c.h2d_bytes, d2h_bytes=c.d2h_bytes,
            hit_rate=c.hit_rate,
        )
        # what the decode hot path actually reads per step (mapped blocks
        # through the page table) vs what the dense paged_view gather
        # materialized (the group's full max_seq-wide ring) — this is the
        # quantity hrm.kv_block_hit_rate's traffic term models
        bb = self._kv.block_bytes
        steps = max(1, self._kv_gather_steps)
        out.update(
            gathered_bytes=self._kv_gathered_blocks * bb,
            gathered_bytes_per_step=self._kv_gathered_blocks * bb / steps,
            paged_view_bytes_per_step=self._kv_view_blocks * bb / steps,
            gather_reduction_vs_view=(self._kv_view_blocks
                                      / max(1, self._kv_gathered_blocks)),
        )
        return out

    # ------------------- fault plane: host tier / ladder / watchdog (§10)
    def _build_host_write(self, shd) -> None:
        # (re)built whenever the pinned tier (re)appears: the donated
        # scatter must carry the tier's sharding so D2H spills land in
        # pinned pages, not wherever the donation was last placed
        self._kv_host_write = jax.jit(
            lambda h, i, v, ax: h.at[(slice(None),) * ax + (i,)].set(v),
            static_argnums=(3,), donate_argnums=(0,), out_shardings=shd)

    def _demote_host_tier(self) -> None:
        """Reversible fall-back of the KV host tier from pinned jax
        arrays to pageable numpy — the HostMemoryError handler and the
        ladder's pageable_host rung.  Idempotent; block bytes are
        preserved, so spilled histories survive the demotion."""
        if self._ladder is not None:
            self._ladder.force_at_least("pageable_host", site="host_alloc")
        if self._kv is None or not self._kv_pinned:
            return
        self._kv_host = {
            key: {name: np.asarray(a) for name, a in g.items()}
            for key, g in self._kv_host.items()}
        self._kv_pinned = False

    def _repromote_host_tier(self) -> None:
        """Ladder re-promotion out of pageable_host: clear the offload
        module's one-shot warning latch, re-probe the pinned memory
        space and — if the probe succeeds — lift the host tier back into
        pinned jax arrays.  Stays pageable when the probe still fails
        (the rung flips back healthy; bytes keep flowing either way)."""
        if self._kv is None or self._kv_pinned:
            return
        offload.reset_host_probe()
        try:
            shd = offload.pinned_host_sharding(warn=False,
                                               faults=self.faults)
        except faults_mod.HostMemoryError:
            shd = None
        if shd is None:
            return                        # probe still failing: stay pageable
        self._kv_host = {
            key: {name: jax.device_put(jnp.asarray(a), shd)
                  for name, a in g.items()}
            for key, g in self._kv_host.items()}
        self._build_host_write(shd)
        self._kv_pinned = True
        self._kv_pinned_shd = shd

    def _set_module_groups(self, mg: int) -> None:
        """Clamp/restore the module-batch window width (the ladder's
        lockstep rung).  PR 6's transcript guarantee — windowed ≡
        lockstep bit-for-bit — is what makes this rung token-safe."""
        mg = max(1, min(int(mg), self._mg_base))
        if mg == self._mg:
            return
        self._mg = mg
        self._windows = [
            list(range(i, min(i + mg, self.ecfg.num_ubs)))
            for i in range(0, self.ecfg.num_ubs, mg)]

    def _ladder_tick(self) -> None:
        if self._ladder is not None and self._ladder.pending():
            self._ladder.apply(self._enact_rung, tick=self.steps)

    def _enact_rung(self, old: int, new: int, direction: str) -> None:
        """Apply ONE ladder rung's side effect (called from apply() at
        the step() safe point — no dispatch in flight).  Every rung is
        reversible, and none can change sampled tokens: each only moves
        where bytes stream from and when — except admission_shed, which
        by design drops work the submitter marked sheddable."""
        rung = faults_mod.LADDER_LEVELS[max(old, new)]
        down = direction == "down"
        if rung == "pageable_host":
            if down:
                self._demote_host_tier()
            else:
                self._repromote_host_tier()
        elif rung == "no_predict":
            self._degraded_no_predict = down
        elif rung == "lockstep":
            self._set_module_groups(1 if down else self._mg_base)
        elif rung == "residency_shrunk":
            for r in self.residency.values():
                if down:
                    r.drop_replicas()
                    r.set_limit(max(1, r.capacity // 2))
                else:
                    r.set_limit(None)
        elif rung == "admission_shed":
            self.scheduler.shed_priority = (
                self.ecfg.shed_priority if down else None)

    def _watchdog_end(self) -> None:
        """Close one dispatch's deadline window: injected 'dispatch'
        stalls charge virtual seconds (deterministic chaos, no real
        sleeps); a violation feeds the ladder like any other fault."""
        if self._watchdog is None:
            return
        virt = self.faults.stall_s("dispatch")
        ok = self._watchdog.step_end(extra_s=virt)
        if not ok and self._ladder is not None:
            self._ladder.note_fault("dispatch")

    def fault_traffic(self) -> Dict[str, object]:
        """Fault-plane observability, weight_traffic()-style: injected
        fault counts, transfer retry/abort/stall counters, dispatch
        deadline violations, shed admissions, and the degradation
        ladder's current level + transition history."""
        out: Dict[str, object] = {
            "injected": dict(self.faults.counts),
            "injected_total": self.faults.total(),
            "shed_requests": self.scheduler.shed_count,
            "host_tier_pinned": bool(getattr(self, "_kv_pinned", False)),
            "module_groups_now": self._mg,
            "predict_suspended": self._degraded_no_predict,
            "dispatch_slow_steps": (self._watchdog.slow_steps
                                    if self._watchdog is not None else 0),
        }
        out.update(self._xfer.stats())
        if self._ladder is not None:
            out.update(level=self._ladder.level,
                       level_name=self._ladder.level_name,
                       demotions=self._ladder.demotions,
                       promotions=self._ladder.promotions,
                       degradation_events=list(self._ladder.events))
        else:
            out.update(level=0, level_name="healthy", demotions=0,
                       promotions=0, degradation_events=[])
        return out

    def _decode_group(self, cache, last_tok, active, rem, *, holder=None,
                      gid: Optional[int] = None):
        """Run one masked decode chunk; returns (cache, new_last_tok,
        still_active, toks (T,B), emitted (T,B)) as host arrays where
        relevant.  On the expert-paged path: pins every resident span for
        the duration of the dispatch (the chunk may read any of them in
        place), issues the router-ahead prefetch for the next rotation
        group while the chunk is in flight, then books the returned
        activation counts."""
        self.key, k = jax.random.split(self.key)
        args = (self.params, cache, jnp.asarray(last_tok[:, None]),
                jnp.asarray(active), jnp.asarray(rem), k)
        chunk = self.ecfg.decode_chunk if self.ecfg.mode == "continuous" else 1
        self._fwd_passes += chunk
        if self._watchdog is not None:
            self._watchdog.step_start()
        if self.residency:
            snap = self._resident_snap()
            for r in self.residency.values():
                r.pin_resident()
            cache, tok, act2, _, toks, emitted, counts = self._decode_chunk(
                *args, self._expert_state())
            prefetching = (self.ecfg.prefetch and gid is not None
                           and self.groups)
            if prefetching:
                # in flight: fill free slots for group gid+1's predicted
                # set (H2D overlaps the dispatched compute), then the
                # gate predictor's intra-pass lookahead for THIS group's
                # next chunk (deduped against the router-ahead entries)
                self._enqueue_prediction(gid)
                if self._predictors and holder is not None:
                    self._enqueue_gate_predictions([holder])
                self._drain_prefetch(gid, retry_refused=True)
            res = (cache, np.array(tok)[:, 0], np.asarray(act2),
                   np.asarray(toks), np.asarray(emitted))   # sync
            self._watchdog_end()
            # spans that became resident between dispatch and landing:
            # their H2D stream overlapped this chunk's compute, so a
            # miss on them is a hidden (stall-free) miss
            hidden = {k: ((r.slot_of >= 0) & ~snap[k])
                      for k, r in self.residency.items()}
            for r in self.residency.values():
                r.unpin_all()
            if prefetching:
                # landed: retry the refused slice, evictions now allowed
                self._drain_prefetch(gid, retry_refused=False)
            self._account_counts(counts, holder=holder, snap=snap,
                                 hidden=hidden)
            return res
        cache, tok, act2, _, toks, emitted = self._decode_chunk(*args)
        res = (cache, np.array(tok)[:, 0], np.asarray(act2),
               np.asarray(toks), np.asarray(emitted))   # sync
        self._watchdog_end()
        return res

    def _decode_window(self, cache, last_tok, active, rem, *, holders, gids):
        """Module-batched analogue of ``_decode_group``: ONE combined
        masked decode chunk over a window of G rotation groups (G·ubatch
        rows, group-major).  Attention/norms are per-row so every row's
        numerics match its lockstep dispatch bit-for-bit; the MoE layers
        stage all groups' routed tokens against a single expert-span read
        per layer step.  The forward-pass counter therefore advances by
        `chunk` for the WHOLE window — each shared span (and each missed
        expert span, booked per-window by ``observe_window``) is charged
        once per window, not once per group: that is the amortization.
        Router-ahead prefetch targets the NEXT window's predicted sets
        and drains through the union of this window's transfer_plan
        slices."""
        self.key, k = jax.random.split(self.key)
        args = (self.params, cache, jnp.asarray(last_tok[:, None]),
                jnp.asarray(active), jnp.asarray(rem), k)
        chunk = self.ecfg.decode_chunk if self.ecfg.mode == "continuous" else 1
        self._fwd_passes += chunk
        if self._watchdog is not None:
            self._watchdog.step_start()
        if self.residency:
            snap = self._resident_snap()
            for r in self.residency.values():
                r.pin_resident()
            cache, tok, act2, _, toks, emitted, counts = \
                self._decode_window_fn(*args, self._expert_state())
            prefetching = bool(self.ecfg.prefetch and self.groups)
            if prefetching:
                self._enqueue_prediction(gids)
                if self._predictors:
                    self._enqueue_gate_predictions(holders)
                self._drain_prefetch(gids, retry_refused=True)
            res = (cache, np.array(tok)[:, 0], np.asarray(act2),
                   np.asarray(toks), np.asarray(emitted))   # sync
            self._watchdog_end()
            hidden = {k: ((r.slot_of >= 0) & ~snap[k])
                      for k, r in self.residency.items()}
            for r in self.residency.values():
                r.unpin_all()
            if prefetching:
                self._drain_prefetch(gids, retry_refused=False)
            self._account_counts(counts, holders=holders, snap=snap,
                                 hidden=hidden)
            return res
        cache, tok, act2, _, toks, emitted = self._decode_window_fn(*args)
        res = (cache, np.array(tok)[:, 0], np.asarray(act2),
               np.asarray(toks), np.asarray(emitted))   # sync
        self._watchdog_end()
        return res

    @staticmethod
    def _emit(toks, emitted, row_req):
        """Replay a chunk's emissions into request transcripts.
        row_req[i] is the request owning row i (or None)."""
        count = 0
        for t in range(toks.shape[0]):
            for i, r in enumerate(row_req):
                if r is not None and emitted[t, i]:
                    r.generated.append(int(toks[t, i]))
                    count += 1
        return count

    def _sample_first(self, logits) -> int:
        self.key, k = jax.random.split(self.key)
        return int(np.asarray(
            sample(logits, k, temperature=self.ecfg.temperature))[0])

    def _run_prefill(self, step_fn, *args):
        """Shared prefill wrapper (monolithic fill AND staged chunk)
        absorbing the expert-paged protocol: one fwd pass booked, the
        residency snapshot taken at dispatch, activation counts
        accounted.  Returns (logits, cache)."""
        self._fwd_passes += 1
        if self.residency:
            snap = self._resident_snap()
            logits, cache, counts = step_fn(self.params, *args,
                                            self._expert_state())
            self._account_counts(counts, snap=snap)
            return logits, cache
        return step_fn(self.params, *args)

    # ------------------------------------------------- continuous mode
    def _admit_continuous(self):
        """Fill freed slots: per admitted request, prefill at its own
        bucket width (batch 1) and slot-write the KV into the pool row.
        Re-admitted (preempted) requests prefill prompt + transcript."""
        for slot in self.scheduler.admit_to_slots():
            r = slot.req
            eff = r.effective_prompt
            S = self._bucket(len(eff))
            toks = np.zeros((1, S), np.int32)
            toks[0, :len(eff)] = eff
            logits, single = self._run_prefill(
                self._prefill, jnp.asarray(toks), self._prefill_scratch,
                jnp.asarray([len(eff)], np.int32))
            first = self._sample_first(logits)
            r.generated.append(first)
            group = self.groups[slot.gid]
            if self._kv is not None:
                # book the prompt's blocks (alloc/fetch/spill-to-make-room)
                # before the slot-insert scatters through the page table
                idx = self._slot_of(slot)
                _, ok, _ = self._kv_ensure(lambda: self._kv.ensure_tokens(
                    idx, len(eff), self.ecfg.block_tokens, (idx,)))
                assert ok, "admission exceeds the KV arena floor"
                pooled = self._insert(self._compose_kv(group.cache, slot.gid),
                                      single, slot.row)
                group.cache = self._absorb_kv(pooled)
            else:
                group.cache = self._insert(group.cache, single, slot.row)
            group.last_tok[slot.row] = first
            if len(r.generated) >= r.max_new_tokens:
                self._retire_slot(slot)          # quota met at prefill
            else:
                self.scheduler.start_decode(slot)

    # -------------------------------------- overlapped (staged) admission
    def _prefill_tick(self) -> bool:
        """Run ONE chunk of the staged admission at the head of the
        prefill queue (request-level CGOPipe: admission work interleaves
        with the groups' decode chunks instead of stalling them)."""
        if not self._staged:
            return False
        slot = self._staged[0]
        r = slot.req
        group = self.groups[slot.gid]
        if self._stage_scratch is None:          # head starts fresh
            self._stage_scratch = self._free_scratches.pop()
            # invalidate the previous occupant's remnants once: span
            # inserts only overwrite their own ring range
            group.cache = self._reset(group.cache, np.int32(slot.row))
        eff = r.effective_prompt
        t = slot.prefill_pos
        rem = len(eff) - t
        width = self._chunk_bucket(rem)
        n = min(rem, width)
        toks = np.zeros((1, width), np.int32)
        toks[0, :n] = eff[t:t + n]
        logits, self._stage_scratch = self._run_prefill(
            self._prefill_chunk, jnp.asarray(toks), self._stage_scratch,
            jnp.asarray([n], np.int32))
        # partial slot insert at the row offset: the chunk lands in the
        # pool immediately, so the final flip to DECODE copies nothing
        if self._kv is not None:
            # only the span's blocks need to be mapped & device-resident
            # for the insert; earlier prompt blocks may stay spilled until
            # the slot flips to DECODE (the chunk attends to the scratch
            # ring, never to the pool row)
            idx = self._slot_of(slot)
            _, ok, _ = self._kv_ensure(lambda: self._kv.ensure_range(
                idx, t // self.ecfg.block_tokens,
                blocks_for_tokens(t + width, self.ecfg.block_tokens),
                (idx,)))
            assert ok, "staged prefill chunk exceeds the KV arena floor"
            pooled = self._insert_span(
                self._compose_kv(group.cache, slot.gid), self._stage_scratch,
                np.int32(slot.row), np.int32(t), length=width)
            group.cache = self._absorb_kv(pooled)
        else:
            group.cache = self._insert_span(
                group.cache, self._stage_scratch, np.int32(slot.row),
                np.int32(t), length=width)
        self.scheduler.prefill_progress(slot, n)
        if slot.prefill_pos >= len(eff):         # final chunk: first token
            first = self._sample_first(logits)
            r.generated.append(first)
            group.last_tok[slot.row] = first
            # recycle the scratch (reset drains while the next admission's
            # first chunk dispatches against the other buffer)
            self._free_scratches.append(
                self._reset(self._stage_scratch, np.int32(0)))
            self._stage_scratch = None
            self._staged.pop(0)
            if len(r.generated) >= r.max_new_tokens:
                self._retire_slot(slot)
            else:
                self.scheduler.start_decode(slot)
        return True

    def _retire_slot(self, slot):
        # no cache reset here: the row stays masked while free, and the
        # next admission's insert_slot overwrites every leaf of the row
        # (kvcache.reset_slot exists for paths that must hand back a
        # clean row without refilling it).  Paged KV: the slot's arena
        # and host blocks return to the free lists; fresh allocations
        # clear their slot_pos plane at map time.
        if self._kv is not None:
            self._kv.free_slot(self._slot_of(slot))
        self.scheduler.finish(slot)

    def _step_continuous(self) -> bool:
        if self.ecfg.overlap:
            self._staged.extend(self.scheduler.admit_to_slots())
            did = self._prefill_tick()
            # cold pool: nothing is decodable yet, so drain prefill chunks
            # back-to-back instead of trickling one per (idle) tick
            while (did and self._staged and not any(
                    s.state == SlotState.DECODE
                    for grp in self.scheduler.slots for s in grp)):
                did = self._prefill_tick()
        else:
            self._admit_continuous()
            did = False
        if not (did or self.scheduler.has_live_slots()):
            return False
        for w in self._windows:                       # CGOPipe rotation
            if len(w) == self._mg and self._mg > 1:
                self._tick_window_continuous(w)
            else:
                # lockstep: remainder groups of a non-divisible rotation,
                # and the whole loop when module batching is off
                for gid in w:
                    self._tick_group_continuous(gid)
        self.steps += 1
        return True

    def _tick_group_continuous(self, gid: int) -> None:
        """One rotation group's decode chunk (the classic lockstep
        schedule: attention and expert FFN at the same ubatch size)."""
        group = self.groups[gid]
        # EOS-aware reservations are optimistic: preempt (recompute)
        # the youngest rows if this chunk could blow the group budget
        self.scheduler.enforce_budget(gid, self.ecfg.decode_chunk)
        if self._kv is not None:
            self._kv_sweep()              # blocks of budget-preempted slots
            # fetch/alloc this group's working set (may preempt more)
            self._kv_prepare_group(gid, self.ecfg.decode_chunk)
        slots = self.scheduler.slots[gid]
        active = np.array([s.state == SlotState.DECODE for s in slots])
        if not active.any():
            return
        rem = np.array(
            [s.req.remaining if s.state == SlotState.DECODE else 0
             for s in slots], np.int32)
        if self._kv is not None:
            self._kv_note_gather(gid, self.ecfg.decode_chunk)
            cache = self._compose_kv(group.cache, gid)
        else:
            cache = group.cache
        cache, group.last_tok, act2, toks, emitted = \
            self._decode_group(cache, group.last_tok, active, rem,
                               holder=group, gid=gid)
        group.cache = (self._absorb_kv(cache)
                       if self._kv is not None else cache)
        self.tokens_out += self._emit(
            toks, emitted, [s.req if s.state == SlotState.DECODE else None
                            for s in slots])
        for i, s in enumerate(slots):
            if s.state == SlotState.DECODE and not act2[i]:
                self._retire_slot(s)
        if self._kv is not None and self.ecfg.kv_prefetch:
            # the KV analogue of the router-ahead weight prefetch:
            # while this group's results land, stream the next
            # group's spilled blocks back in transfer_plan slices
            self._kv_enqueue_prefetch(gid)
            self._kv_drain_prefetch(gid)

    def _tick_window_continuous(self, gids: List[int]) -> None:
        """One module-batched accumulation window: the attention phase
        runs all `gids` groups' rows through ONE combined decode dispatch
        (their slot caches concatenated batch-wise, one shared arena
        composition with a window-wide page table), the expert phase
        inside it streams each activated expert's span exactly once for
        the whole window, and the results are split back per group.  Per
        request the greedy transcript is bit-identical to the lockstep
        schedule — rows are independent through attention, and the MoE
        staging reproduces per-group bucketing exactly."""
        b = self.ecfg.ubatch
        for gid in gids:
            self.scheduler.enforce_budget(gid, self.ecfg.decode_chunk)
        if self._kv is not None:
            self._kv_sweep()
            # the window dispatches combined: the whole window's working
            # set must be device-resident at once (union protect set)
            self._kv_prepare_group(gids, self.ecfg.decode_chunk)
        slot_rows = [self.scheduler.slots[g] for g in gids]
        active = np.array([s.state == SlotState.DECODE
                           for slots in slot_rows for s in slots])
        if not active.any():
            return
        rem = np.array(
            [s.req.remaining if s.state == SlotState.DECODE else 0
             for slots in slot_rows for s in slots], np.int32)
        last = np.concatenate([self.groups[g].last_tok for g in gids])
        dense = kvcache.concat_slot_caches(
            [self.groups[g].cache for g in gids])
        if self._kv is not None:
            self._kv_note_gather(gids, self.ecfg.decode_chunk)
            cache = self._compose_kv(dense, gids)
        else:
            cache = dense
        cache, last2, act2, toks, emitted = self._decode_window(
            cache, last, active, rem,
            holders=[self.groups[g] for g in gids], gids=gids)
        dense_out = self._absorb_kv(cache) if self._kv is not None else cache
        for j, (g, part) in enumerate(zip(
                gids, kvcache.split_slot_cache(dense_out, len(gids)))):
            self.groups[g].cache = part
            self.groups[g].last_tok = last2[j * b:(j + 1) * b]
            slots = slot_rows[j]
            sl = slice(j * b, (j + 1) * b)
            self.tokens_out += self._emit(
                toks[:, sl], emitted[:, sl],
                [s.req if s.state == SlotState.DECODE else None
                 for s in slots])
            for i, s in enumerate(slots):
                if s.state == SlotState.DECODE and not act2[j * b + i]:
                    self._retire_slot(s)
        if self._kv is not None and self.ecfg.kv_prefetch:
            self._kv_enqueue_prefetch(gids)
            self._kv_drain_prefetch(gids)

    # ----------------------------------------------------- static mode
    def _admit_static(self):
        # the pool budget is num_ubs rotation groups: only admit into
        # capacity actually freed by retired micro-batches (with kv_paged
        # every admission additionally books its rows' blocks against the
        # shared arena — the policy budget is enforced by allocation, not
        # by the group cap alone)
        avail = self.ecfg.num_ubs - len(self.active)
        for group in self.scheduler.admit(avail):
            mu = self.ecfg.ubatch
            S = self._bucket(max(r.input_len for r in group))
            toks = np.zeros((mu, S), np.int32)
            lens = np.zeros((mu,), np.int32)
            for i, r in enumerate(group):
                toks[i, :r.input_len] = r.prompt
                lens[i] = r.input_len
            # rows beyond len(group) are padding rows (len 0 → masked)
            cache = kvcache.init_cache(self.cfg, mu, self.ecfg.max_seq)
            logits, cache = self._run_prefill(self._prefill,
                                              jnp.asarray(toks), cache,
                                              jnp.asarray(lens))
            self.key, k = jax.random.split(self.key)
            first = np.asarray(
                sample(logits, k, temperature=self.ecfg.temperature))
            for i, r in enumerate(group):
                r.generated.append(int(first[i]))
                if len(r.generated) >= r.max_new_tokens:
                    r.done = True                 # 1-token request
            gid = None
            if self._kv is not None:
                # land the dense prefill in arena blocks: book each row's
                # prompt, then scatter the rows through the page table
                gid = self._static_gids.pop(0)
                rows = list(range(gid * mu, (gid + 1) * mu))
                for i, r in enumerate(group):
                    _, ok, _ = self._kv_ensure(
                        lambda i=i, r=r: self._kv.ensure_tokens(
                            rows[i], r.input_len, self.ecfg.block_tokens,
                            rows))
                    assert ok, "static micro-batch exceeds the KV arena"
                pooled = self._compose_kv(
                    kvcache.init_cache(self.cfg, mu, self.ecfg.max_seq,
                                       skip_keys=self._kv_keys), gid)
                for i in range(len(group)):
                    pooled = self._insert(pooled, cache, np.int32(i),
                                          np.int32(i))
                cache = self._absorb_kv(pooled)
            self.active.append(_ActiveBatch(
                list(group), cache, np.asarray(first, np.int32), gid))

    def _release_static(self, ab) -> None:
        self.active.remove(ab)
        if self._kv is not None and ab.gid is not None:
            for row in range(ab.gid * self.ecfg.ubatch,
                             (ab.gid + 1) * self.ecfg.ubatch):
                self._kv.free_slot(row)
            self._static_gids.append(ab.gid)

    def _kv_prepare_static(self, ab, active) -> None:
        """Static analogue of `_kv_prepare_group`: every live row's
        blocks device-resident plus its next token's block mapped (no
        preemption — the arena floor guarantees one micro-batch fits;
        other batches' blocks spill to make room)."""
        rows = list(range(ab.gid * self.ecfg.ubatch,
                          (ab.gid + 1) * self.ecfg.ubatch))
        protect = [rows[i] for i in range(len(ab.requests)) if active[i]]
        for i, r in enumerate(ab.requests):
            if not active[i]:
                continue
            _, ok, _ = self._kv_ensure(
                lambda i=i, r=r: self._kv.ensure_tokens(
                    rows[i], r.footprint + 1, self.ecfg.block_tokens,
                    protect))
            assert ok, "static micro-batch exceeds the KV arena"

    def _kv_prepare_window_static(self, window) -> bool:
        """Window analogue of `_kv_prepare_static` with a union protect
        set (preparing a later batch must not spill an earlier one's
        blocks).  The arena floor only guarantees ONE micro-batch fits,
        so this may fail — returns False and the caller falls back to
        lockstep (static mode never preempts)."""
        mu = self.ecfg.ubatch
        protect = [ab.gid * mu + i
                   for ab, active, _ in window
                   for i in range(len(ab.requests)) if active[i]]
        for ab, active, _ in window:
            for i, r in enumerate(ab.requests):
                if not active[i]:
                    continue
                _, ok, _ = self._kv_ensure(
                    lambda ab=ab, i=i, r=r: self._kv.ensure_tokens(
                        ab.gid * mu + i, r.footprint + 1,
                        self.ecfg.block_tokens, protect))
                if not ok:
                    return False
        return True

    def _tick_batch_static(self, ab, active, rem) -> None:
        """One micro-batch's single-token decode (lockstep)."""
        mu = self.ecfg.ubatch
        if self._kv is not None:
            self._kv_prepare_static(ab, active)
            self._kv_note_gather(ab.gid, 1)
            cache = self._compose_kv(ab.cache, ab.gid)
        else:
            cache = ab.cache
        cache, ab.last_tokens, act2, toks, emitted = \
            self._decode_group(cache, np.asarray(ab.last_tokens),
                               active, rem, holder=ab)
        ab.cache = (self._absorb_kv(cache)
                    if self._kv is not None else cache)
        row_req = [ab.requests[i] if i < len(ab.requests) else None
                   for i in range(mu)]
        self.tokens_out += self._emit(toks, emitted, row_req)
        for i, r in enumerate(ab.requests):
            if active[i] and not act2[i]:
                r.done = True
        if all(r.done for r in ab.requests):
            self._release_static(ab)

    def _tick_window_static(self, window) -> bool:
        """One combined single-token dispatch over `_mg` static
        micro-batches (module-based batching in static mode).  With
        paged KV the union working set must fit the arena at once; if it
        does not, returns False and the caller runs the window's batches
        lockstep instead."""
        mu = self.ecfg.ubatch
        abs_ = [ab for ab, _, _ in window]
        if self._kv is not None:
            if not self._kv_prepare_window_static(window):
                return False
            for ab in abs_:
                self._kv_note_gather(ab.gid, 1)
            dense = kvcache.concat_slot_caches([ab.cache for ab in abs_])
            cache = self._compose_kv(dense, [ab.gid for ab in abs_])
        else:
            cache = kvcache.concat_slot_caches([ab.cache for ab in abs_])
        active = np.concatenate([a for _, a, _ in window])
        rem = np.concatenate([r for _, _, r in window])
        last = np.concatenate([np.asarray(ab.last_tokens) for ab in abs_])
        cache, last2, act2, toks, emitted = self._decode_window(
            cache, last, active, rem, holders=abs_,
            gids=[ab.gid for ab in abs_])
        dense_out = self._absorb_kv(cache) if self._kv is not None else cache
        for j, (ab, part) in enumerate(zip(
                abs_, kvcache.split_slot_cache(dense_out, len(abs_)))):
            ab.cache = part
            ab.last_tokens = last2[j * mu:(j + 1) * mu]
            sl = slice(j * mu, (j + 1) * mu)
            row_req = [ab.requests[i] if i < len(ab.requests) else None
                       for i in range(mu)]
            self.tokens_out += self._emit(toks[:, sl], emitted[:, sl],
                                          row_req)
            for i, r in enumerate(ab.requests):
                if window[j][1][i] and not act2[j * mu + i]:
                    r.done = True
            if all(r.done for r in ab.requests):
                self._release_static(ab)
        return True

    def _step_static(self) -> bool:
        self._admit_static()
        if not self.active:
            return False
        mu = self.ecfg.ubatch
        work = []
        for ab in list(self.active):  # rotation: ub_0, ub_1, ... (Alg. 1)
            active = np.zeros((mu,), bool)
            rem = np.zeros((mu,), np.int32)
            for i, r in enumerate(ab.requests):
                if not r.done and len(r.generated) < r.max_new_tokens:
                    active[i] = True
                    rem[i] = r.max_new_tokens - len(r.generated)
            if not active.any():          # e.g. every quota met at prefill
                self._release_static(ab)
                continue
            work.append((ab, active, rem))
        i = 0
        while i < len(work):
            window = work[i:i + self._mg]
            if self._mg > 1 and len(window) == self._mg \
                    and self._tick_window_static(window):
                i += self._mg
            else:
                self._tick_batch_static(*work[i])
                i += 1
        self.steps += 1
        return True
